"""All 10 architectures: smoke forward/train, prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_model
from repro.models import blocks, lm

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_loss(arch):
    api = get_model(arch, smoke=True)
    params = api.init_params(KEY)
    batch = api.sample_batch(2, 64, KEY)
    loss = jax.jit(api.train_loss)(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    api = get_model(arch, smoke=True)
    cfg = api.cfg
    params = api.init_params(KEY)
    batch = api.sample_batch(2, 32, KEY, with_labels=False)
    if cfg.family == "encdec":
        logits, caches = jax.jit(api.prefill)(params, batch)
    else:
        logits, caches = jax.jit(api.prefill)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen25_3b", "gemma3_12b", "jamba_52b",
                                  "mamba2_27b", "grok1_314b", "arctic_480b",
                                  "internvl2_1b", "phi3_mini_38b",
                                  "internlm2_20b"])
def test_prefill_decode_consistency(arch):
    """prefill(S) + decode(S) logits == full forward logits at S."""
    api = get_model(arch, smoke=True)
    cfg = api.cfg
    params = api.init_params(KEY)
    B, S = 2, 64
    batch = api.sample_batch(B, S + 1, KEY, with_labels=False)
    logits_full = jax.jit(
        lambda p, b: lm.forward(p, b, cfg, remat=False))(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S]
    logits_pre, caches = jax.jit(api.prefill)(params, pre)
    off = cfg.frontend_tokens if cfg.frontend != "none" else 0
    ref = logits_full[:, off + S - 1]
    rel = lambda a, b: float(jnp.max(jnp.abs(a - b))
                             / (jnp.max(jnp.abs(a)) + 1e-9))
    assert rel(ref, logits_pre[:, 0]) < 0.02
    caches = blocks.pad_caches(caches, cfg, off + S + 8)
    logits_dec, _ = jax.jit(api.decode_step)(
        params, caches, batch["tokens"][:, S:S + 1], jnp.int32(off + S))
    assert rel(logits_full[:, off + S], logits_dec[:, 0]) < 0.02


def test_encdec_consistency():
    from repro.models import encdec
    api = get_model("seamless_m4t_medium", smoke=True)
    cfg = api.cfg
    params = api.init_params(KEY)
    B, S = 2, 48
    batch = api.sample_batch(B, S + 1, KEY)
    mem = encdec._encode(params, batch["frames"], cfg)
    x = encdec.embed_tokens(params["embed"], batch["tokens"])
    x = encdec._decode_stack(params, x, mem, cfg)
    x = encdec.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits_full = encdec.lm_logits(x, params["embed"], None)
    pre = {"tokens": batch["tokens"][:, :S], "frames": batch["frames"]}
    logits_pre, (self_kv, mem_kv) = jax.jit(api.prefill)(params, pre)
    rel = lambda a, b: float(jnp.max(jnp.abs(a - b))
                             / (jnp.max(jnp.abs(a)) + 1e-9))
    assert rel(logits_full[:, S - 1], logits_pre[:, 0]) < 0.02
    self_kv = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)]), self_kv)
    logits_dec, _ = jax.jit(api.decode_step)(
        params, (self_kv, mem_kv), batch["tokens"][:, S:S + 1], jnp.int32(S))
    assert rel(logits_full[:, S], logits_dec[:, 0]) < 0.02


def test_ssm_chunk_invariance():
    """SSD output must not depend on the chunk size (dual-form identity)."""
    import dataclasses
    from repro.models import ssm

    cfg = get_config("mamba2_27b", smoke=True)
    p = ssm.init_ssm(KEY, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 128, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    outs = []
    for chunk in (16, 32, 64, 128):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        outs.append(np.asarray(ssm.ssm_forward(p, x, c), np.float32))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=5e-2, rtol=5e-2)


def test_ssm_decode_matches_forward():
    """Recurrent decode == chunked forward, token by token."""
    import dataclasses
    from repro.models import ssm

    cfg = dataclasses.replace(get_config("mamba2_27b", smoke=True), ssm_chunk=16)
    p = ssm.init_ssm(KEY, cfg)
    x = jax.random.normal(jax.random.key(2), (1, 32, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    full = np.asarray(ssm.ssm_forward(p, x, cfg), np.float32)
    cache = ssm.init_ssm_cache(cfg, 1)
    outs = []
    for t in range(32):
        o, cache = ssm.ssm_decode_step(p, x[:, t:t + 1], cache, cfg)
        outs.append(np.asarray(o, np.float32))
    dec = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, dec, atol=6e-2, rtol=6e-2)


def test_moe_routes_and_drops():
    """Capacity dispatch: outputs differ from dense-mean; capacity respected."""
    import dataclasses
    from repro.models import mlp as mlp_lib

    cfg = dataclasses.replace(get_config("grok1_314b", smoke=True),
                              capacity_factor=1.0)
    p = mlp_lib.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(3), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y = mlp_lib.moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_param_counts_plausible():
    expect = {"internlm2_20b": (17e9, 23e9), "qwen25_3b": (2.5e9, 3.8e9),
              "phi3_mini_38b": (3.3e9, 4.3e9), "gemma3_12b": (9e9, 14e9),
              "grok1_314b": (290e9, 340e9), "arctic_480b": (430e9, 530e9),
              "jamba_52b": (45e9, 60e9), "mamba2_27b": (2.2e9, 3.2e9)}
    for arch, (lo, hi) in expect.items():
        api = get_model(arch, smoke=False)
        n = api.param_count()
        assert lo <= n <= hi, (arch, n / 1e9)
