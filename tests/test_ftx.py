"""Fault-tolerance layer: stripe store, EC checkpoints, failures, elastic."""
import numpy as np
import pytest

from repro.ftx import CheckpointManager, StripeStore, StoreConfig
from repro.ftx.checkpoint import CheckpointConfig
from repro.ftx.failures import FailureInjector, restripe


@pytest.fixture
def store(tmp_path):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=2048)
    return StripeStore(tmp_path / "s", cfg)


def fill(store, rng, n=6):
    objs = {}
    for i in range(n):
        data = rng.integers(0, 256, int(rng.integers(64, 6000)), dtype=np.uint8)
        store.put(f"o{i}", data.tobytes())
        objs[f"o{i}"] = data
    store.seal()
    store.save_manifest()
    return objs


def test_put_get_roundtrip(store, rng):
    objs = fill(store, rng)
    for k, v in objs.items():
        assert (store.get(k) == v).all()


def test_degraded_read_single(store, rng):
    objs = fill(store, rng)
    store.fail_node(store.stripes[0].node_of_block[0])
    for k, v in objs.items():
        assert (store.get(k) == v).all()
    assert store.telemetry.blocks_read > 0


def test_two_node_repair_local_for_cp(store, rng):
    objs = fill(store, rng)
    st0 = store.stripes[0]
    store.fail_node(st0.node_of_block[0])
    store.fail_node(st0.node_of_block[store.scheme.k])  # a local parity
    tele = store.repair_all()
    assert tele["repairs_global"] == 0  # D+L is the paper's cascading case
    for n in list(store.nodes):
        store.revive_node(n)
    for k, v in objs.items():
        assert (store.get(k) == v).all()


def test_repair_bandwidth_cp_beats_azure(tmp_path, rng):
    """CP-Azure repairs a parity-node loss with fewer block reads."""
    reads = {}
    for scheme in ("azure", "cp-azure"):
        cfg = StoreConfig(scheme=scheme, k=8, r=2, p=2, block_size=1024)
        s = StripeStore(tmp_path / scheme, cfg)
        rng2 = np.random.default_rng(0)
        fill(s, rng2, n=4)
        # fail the node holding G_r of stripe 0
        gr = s.scheme.n - 1
        s.fail_node(s.stripes[0].node_of_block[gr])
        tele = s.repair_all()
        reads[scheme] = tele["blocks_read"]
    assert reads["cp-azure"] < reads["azure"]


def test_checkpoint_roundtrip_with_failures(tmp_path):
    cm = CheckpointManager(tmp_path / "ckpt", CheckpointConfig(
        store=StoreConfig(scheme="cp-uniform", k=6, r=2, p=2,
                          block_size=4096)))
    state = {"w": np.arange(3000, dtype=np.float32).reshape(60, 50),
             "m": np.full(123, 7, np.float64), "step": np.int64(42)}
    cm.save(10, state)
    cm.fail_hosts(10, [1, 2])
    restored, tele = cm.restore(10, state)
    import jax
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert tele["blocks_read"] > 0


def test_checkpoint_retention(tmp_path):
    cm = CheckpointManager(tmp_path / "c", CheckpointConfig(
        store=StoreConfig(k=4, r=1, p=2, block_size=512), keep=2))
    state = {"x": np.zeros(100, np.float32)}
    for s in (1, 2, 3, 4):
        cm.save(s, state)
    assert cm.available() == [3, 4]


def test_failure_injector(store, rng):
    fill(store, rng)
    inj = FailureInjector(store, mttf_hours=10.0, seed=1)
    events = inj.run(hours=30.0)
    assert len(events) > 0
    # unified schema: every failure is paired with its repair-done record
    assert len(inj.failures()) == len(inj.repairs()) > 0
    assert all(r.blocks_read >= 0 for r in inj.repairs())
    assert all(r.t >= r.started_at for r in inj.repairs())


def test_restripe_elastic(tmp_path, rng):
    cfg = StoreConfig(scheme="cp-azure", k=4, r=2, p=2, block_size=1024)
    s = StripeStore(tmp_path / "a", cfg)
    objs = fill(s, rng, n=4)
    new_cfg = StoreConfig(scheme="cp-uniform", k=8, r=2, p=2, block_size=1024)
    s2, tele = restripe(s, new_cfg, tmp_path / "b")
    assert tele["bytes_moved"] > 0
    for k, v in objs.items():
        assert (s2.get(k) == v).all()


def test_hedged_reads(tmp_path, rng):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=1024,
                      hedge=2)
    s = StripeStore(tmp_path / "h", cfg)
    objs = fill(s, rng)
    s.fail_node(s.stripes[0].node_of_block[0])
    for k, v in objs.items():
        assert (s.get(k) == v).all()
