"""Dry-run artifact parsing + roofline term construction."""
import json
from pathlib import Path

import pytest


def test_collective_parser_synthetic():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p0), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%add
  ROOT %rs = f32[16]{0} reduce-scatter(f32[256]{0} %y), dimensions={0}
  %cp = u8[128]{0} collective-permute(u8[128]{0} %z), source_target_pairs={{0,1}}
  %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %w), dimensions={0}
  %dot = f32[4,4]{1,0} dot(f32[4,8] %a, f32[8,4] %b), metadata={op_name="bf16[999,999]"}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == {"in": 2048, "out": 32768, "count": 1}
    assert out["all-reduce"]["out"] == 1024 and out["all-reduce"]["count"] == 1
    assert out["reduce-scatter"] == {"in": 1024, "out": 64, "count": 1}
    assert out["collective-permute"]["out"] == 128
    assert out["all-to-all"]["in"] == 1024
    # the metadata shape literal on the dot line must NOT count
    assert sum(v["out"] for v in out.values()) == 32768 + 1024 + 64 + 128 + 1024


def test_wire_bytes_model():
    from repro.launch.roofline import wire_bytes

    coll = {"all-reduce": {"in": 100, "out": 100, "count": 1},
            "all-gather": {"in": 10, "out": 160, "count": 1},
            "reduce-scatter": {"in": 160, "out": 10, "count": 1},
            "all-to-all": {"in": 80, "out": 80, "count": 1},
            "collective-permute": {"in": 40, "out": 40, "count": 1}}
    # 2*AR.in + AG.out + RS.in + A2A.in + CP.out
    assert wire_bytes(coll) == 2 * 100 + 160 + 160 + 80 + 40


@pytest.mark.skipif(
    not (Path(__file__).parents[1] / "benchmarks/results/dryrun_single.json").exists(),
    reason="dry-run results not generated yet")
def test_dryrun_results_complete():
    """All 40 cells accounted for, both meshes, no errors."""
    base = Path(__file__).parents[1] / "benchmarks/results"
    for mesh in ("single", "multi"):
        d = json.loads((base / f"dryrun_{mesh}.json").read_text())
        assert len(d) == 40, mesh
        errs = [k for k, v in d.items() if "error" in v]
        assert not errs, (mesh, errs)
        skips = [k for k, v in d.items() if "skip" in v]
        assert len(skips) == 7  # long_500k full-attention skips
        for k, v in d.items():
            if "skip" in v:
                assert "long_500k" in k


@pytest.mark.skipif(
    not (Path(__file__).parents[1] / "benchmarks/results/dryrun_single_unrolled.json").exists(),
    reason="unrolled dry-run not generated yet")
def test_roofline_table_builds():
    from repro.launch.roofline import build_table

    rows = build_table()
    assert len(rows) == 40
    live = [r for r in rows if not r.get("skip")]
    assert len(live) == 33
    for r in live:
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert 0 < r["useful"] < 2.0, (r["arch"], r["shape"], r["useful"])
