"""Fleet durability sizing."""
from repro.core.reliability import ReliabilityParams
from repro.ftx.fleet import FleetSpec, evaluate, size_fleet


def test_evaluate_and_rank():
    spec = FleetSpec(nodes=512, state_bytes=1 << 40,
                     target_mttdl_years=1.0,
                     params=ReliabilityParams(detect_hours_single=0.0,
                                              detect_hours_multi=0.0))
    cands = size_fleet(spec, schemes=("azure", "cp-azure"),
                       geometries=[(12, 2, 2), (24, 2, 2)], samples=150)
    assert cands
    # sorted cheapest-overhead first
    assert all(a.overhead <= b.overhead
               for a, b in zip(cands, cands[1:]))
    # wider stripes are cheaper per byte
    wide = [c for c in cands if c.k == 24]
    narrow = [c for c in cands if c.k == 12]
    assert wide and narrow
    assert min(c.overhead for c in wide) < min(c.overhead for c in narrow)


def test_fleet_scales_inverse_with_stripes():
    spec1 = FleetSpec(nodes=64, state_bytes=1 << 34, target_mttdl_years=0.0)
    spec2 = FleetSpec(nodes=64, state_bytes=1 << 36, target_mttdl_years=0.0)
    a = evaluate(spec1, "cp-azure", 12, 2, 2, samples=150)
    b = evaluate(spec2, "cp-azure", 12, 2, 2, samples=150)
    assert b.stripes > a.stripes
    assert b.fleet_mttdl_years < a.fleet_mttdl_years
