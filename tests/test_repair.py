"""Repair algorithms vs the paper's worked examples and evaluation tables."""
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.repair import multi_repair_plan, single_repair_plan
from repro.core.schemes import make_scheme


def ids(s, *labels):
    table = {s.label(b): b for b in range(s.n)}
    return [table[x] for x in labels]


# ---------------------------------------------------------------- §IV-C
class TestCPAzureExamples:
    """The paper's (6,2,2) CP-Azure worked examples."""

    s = make_scheme("cp-azure", 6, 2, 2)

    def test_single_data(self):
        (d1,) = ids(self.s, "D1")
        plan = single_repair_plan(self.s, d1)
        assert plan.cost == 3 and plan.method == "group"

    def test_single_g1_global(self):
        (g1,) = ids(self.s, "G1")
        plan = single_repair_plan(self.s, g1)
        assert plan.cost == 6 and plan.method == "global"

    def test_single_g2_cascade(self):
        (g2,) = ids(self.s, "G2")
        plan = single_repair_plan(self.s, g2)
        assert plan.cost == 2 and plan.method == "cascade"
        assert plan.reads == frozenset(ids(self.s, "L1", "L2"))

    def test_single_local_cascade(self):
        (l1,) = ids(self.s, "L1")
        plan = single_repair_plan(self.s, l1)
        assert plan.cost == 2 and plan.method == "cascade"

    def test_multi_d1_g2(self):
        """Paper: D1+G2 -> D2, D3, L1, L2 (4 blocks)."""
        pat = ids(self.s, "D1", "G2")
        plan = multi_repair_plan(self.s, pat)
        assert plan.feasible and plan.all_local and plan.cost == 4
        assert plan.reads == frozenset(ids(self.s, "D2", "D3", "L1", "L2"))

    def test_multi_two_in_group_plus_parity(self):
        """Paper: D1,D2,L2 -> 6 blocks (global, L2 reuses global reads)."""
        pat = ids(self.s, "D1", "D2", "L2")
        plan = multi_repair_plan(self.s, pat)
        assert plan.feasible and not plan.all_local and plan.cost == 6

    def test_multi_d1_g1(self):
        """Paper: D1,G1 -> 6 blocks."""
        pat = ids(self.s, "D1", "G1")
        plan = multi_repair_plan(self.s, pat)
        assert plan.feasible and plan.cost == 6

    def test_wide_d1_l1_cascading(self):
        """Paper (24,2,2): D1+L1 -> 13 nodes via cascade-then-group."""
        s = make_scheme("cp-azure", 24, 2, 2)
        pat = ids(s, "D1", "L1")
        plan = multi_repair_plan(s, pat)
        assert plan.feasible and plan.all_local and plan.cost == 13


class TestCPUniformExamples:
    """The paper's (6,2,2) CP-Uniform worked examples (groups (D1..D3),
    (D4..D6, G1))."""

    s = make_scheme("cp-uniform", 6, 2, 2)

    def test_group_structure(self):
        assert [len(g.items) for g in self.s.groups] == [3, 4]
        (g1,) = ids(self.s, "G1")
        assert g1 in self.s.groups[1].items

    def test_single_costs(self):
        d1, g1, g2, l1 = ids(self.s, "D1", "G1", "G2", "L1")
        assert single_repair_plan(self.s, d1).cost == 3
        assert single_repair_plan(self.s, g1).cost == 4
        assert single_repair_plan(self.s, g2).cost == 2
        assert single_repair_plan(self.s, l1).cost == 2

    def test_multi_d1_g2(self):
        """Paper: D1,G2 -> D2,D3,L1,L2 (4 blocks)."""
        plan = multi_repair_plan(self.s, ids(self.s, "D1", "G2"))
        assert plan.all_local and plan.cost == 4

    def test_multi_overloaded_group(self):
        """Paper: D1,D2,L2 -> 6 blocks."""
        plan = multi_repair_plan(self.s, ids(self.s, "D1", "D2", "L2"))
        assert plan.feasible and plan.cost == 6


# ------------------------------------------------------------ table match
PAPER_TABLE3 = {
    # (scheme, k, r, p): (ADRC, ARC1)
    ("azure", 6, 2, 2): (3.00, 3.60),
    ("azure", 24, 2, 2): (12.00, 12.86),
    ("azure+1", 6, 2, 2): (6.00, 4.80),
    ("azure+1", 48, 4, 3): (24.00, 22.18),
    ("optimal", 6, 2, 2): (5.00, 5.00),
    ("uniform", 6, 2, 2): (4.00, 4.00),
    ("uniform", 24, 2, 2): (13.00, 13.00),
    ("cp-azure", 6, 2, 2): (3.00, 3.00),
    ("cp-azure", 24, 2, 2): (12.00, 11.36),
    ("cp-azure", 72, 4, 4): (18.00, 19.15),
    ("cp-uniform", 6, 2, 2): (3.50, 3.10),
    ("cp-uniform", 24, 2, 2): (12.50, 11.39),
    ("cp-uniform", 48, 4, 3): (17.00, 15.98),
    ("cp-uniform", 96, 5, 4): (25.00, 24.00),
}


@pytest.mark.parametrize("key,expect", sorted(PAPER_TABLE3.items()))
def test_adrc_arc1_match_paper(key, expect):
    name, k, r, p = key
    s = make_scheme(name, k, r, p)
    adrc, arc1 = expect
    assert abs(M.adrc(s) - adrc) < 0.005
    assert abs(M.arc1(s) - arc1) < 0.005


PAPER_ARC2 = {("azure", 6, 2, 2): 6.00, ("azure", 24, 2, 2): 24.00,
              ("cp-azure", 24, 2, 2): 21.82}


@pytest.mark.parametrize("key,expect", sorted(PAPER_ARC2.items()))
def test_arc2_match_paper(key, expect):
    name, k, r, p = key
    assert abs(M.arc2(make_scheme(name, k, r, p)) - expect) < 0.005


PAPER_PORTIONS = {  # (scheme,k,r,p): (local, effective)
    ("azure", 6, 2, 2): (0.36, 0.00),
    ("azure", 24, 2, 2): (0.45, 0.00),
    ("cp-azure", 6, 2, 2): (0.67, 0.47),
    ("cp-azure", 24, 2, 2): (0.58, 0.20),
    ("cp-uniform", 6, 2, 2): (0.80, 0.53),
    ("cp-uniform", 24, 2, 2): (0.62, 0.21),
    ("uniform", 6, 2, 2): (0.56, 0.00),
}


@pytest.mark.parametrize("key,expect", sorted(PAPER_PORTIONS.items()))
def test_local_portions_match_paper(key, expect):
    name, k, r, p = key
    s = make_scheme(name, k, r, p)
    lp, el = expect
    assert abs(M.local_portion(s) - lp) < 0.005
    assert abs(M.effective_local_portion(s) - el) < 0.005


def test_multi_cost_never_exceeds_k():
    """Paper: multi-node repair accesses at most k blocks."""
    import itertools

    for name in ("cp-azure", "cp-uniform", "azure", "uniform"):
        s = make_scheme(name, 8, 2, 2)
        for pat in itertools.combinations(range(s.n), 2):
            plan = multi_repair_plan(s, pat)
            if plan.feasible:
                assert plan.cost <= s.k, (name, pat, plan)
