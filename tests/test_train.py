"""Training substrate: loss goes down; microbatching is equivalent."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_model
from repro.data.pipeline import DataConfig, make_pipeline
from repro.train.optimizer import AdamWConfig, adamw_init, schedule
from repro.train.train_step import TrainConfig, make_train_step

KEY = jax.random.key(0)


def test_loss_decreases():
    api = get_model("qwen2.5-3b", smoke=True)
    cfg = api.cfg
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=0))
    tc = TrainConfig(opt=AdamWConfig(peak_lr=3e-3, warmup_steps=5,
                                     decay_steps=40))
    params = api.init_params(KEY)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(api, tc), donate_argnums=(0, 1))
    losses = []
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_microbatch_equivalence():
    api = get_model("qwen2.5-3b", smoke=True)
    cfg = api.cfg
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8, seed=1))
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    params = api.init_params(KEY)
    opt = adamw_init(params)
    outs = {}
    for mb in (1, 2, 4):
        tc = TrainConfig(opt=AdamWConfig(peak_lr=1e-3), microbatches=mb)
        step = jax.jit(make_train_step(api, tc))
        p2, _, m = step(params, opt, batch)
        outs[mb] = (float(m["loss"]),
                    np.asarray(jax.tree.leaves(p2)[0], np.float32))
    for mb in (2, 4):
        assert abs(outs[mb][0] - outs[1][0]) < 2e-2
        np.testing.assert_allclose(outs[mb][1], outs[1][1], atol=3e-2)


def test_schedule_shape():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100, 200)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay
    assert lrs[4] >= cfg.peak_lr * cfg.min_lr_ratio - 1e-9


def test_pipeline_determinism():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    a = make_pipeline(cfg).batch_at(7)
    b = make_pipeline(cfg).batch_at(7)
    assert (a["tokens"] == b["tokens"]).all()
    c = make_pipeline(cfg).batch_at(8)
    assert not (a["tokens"] == c["tokens"]).all()
