"""Batched engine + planner: bit-exact vs the per-stripe reference path,
ragged batches, plan-cache behavior, batched kernel lockstep."""
import numpy as np
import pytest

from repro.core.codec import StripeCodec
from repro.core.engine import BatchedCodecEngine
from repro.core.gf import gf_rank
from repro.core.planner import RepairPlanner
from repro.core.schemes import make_scheme

SCHEMES = ("cp-azure", "cp-uniform", "azure")


def _stack(stripes, ids):
    return {b: stripes[:, b, :] for b in ids}


@pytest.fixture(params=SCHEMES)
def pair(request):
    # default_backend() honors REPRO_BACKEND, so the CI backend-matrix legs
    # (REPRO_BACKEND=crs / =mxu) drive this whole module through the
    # bit-plane backends.
    from repro.kernels.ops import default_backend

    s = make_scheme(request.param, 8, 2, 2)
    codec = StripeCodec(s, backend=default_backend())
    engine = BatchedCodecEngine(s, backend=codec.backend, planner=codec.planner)
    return s, codec, engine


# ------------------------------------------------------------------ encode
@pytest.mark.parametrize("S", [1, 3, 5])  # ragged/odd batch sizes included
def test_batched_encode_matches_per_stripe(pair, rng, S):
    s, codec, engine = pair
    data = rng.integers(0, 256, (S, s.k, 96), dtype=np.uint8)
    batch = np.asarray(engine.encode(data))
    loop = np.stack([np.asarray(codec.encode(data[i])) for i in range(S)])
    assert (batch == loop).all()


@pytest.mark.parametrize("backend", ["gf", "crs", "mxu", "ref"])
def test_batched_encode_all_backends(backend, rng):
    s = make_scheme("cp-azure", 6, 2, 2)
    engine = BatchedCodecEngine(s, backend=backend)
    data = rng.integers(0, 256, (4, s.k, 72), dtype=np.uint8)
    batch = np.asarray(engine.encode(data))
    for i in range(4):
        assert (batch[i] == s.encode(data[i])).all(), backend


# ------------------------------------------------------------------ repair
def test_batched_single_repair_every_block(pair, rng):
    s, codec, engine = pair
    S = 4
    data = rng.integers(0, 256, (S, s.k, 64), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    for failed in range(s.n):
        avail = _stack(stripes, [i for i in range(s.n) if i != failed])
        out, plan = engine.repair_single(failed, avail)
        loop = np.stack([
            np.asarray(codec.repair_single(
                failed, {i: stripes[j, i, :] for i in range(s.n)
                         if i != failed})[0]) for j in range(S)])
        assert (np.asarray(out) == stripes[:, failed, :]).all(), failed
        assert (np.asarray(out) == loop).all(), failed


def test_batched_multi_repair_cascade(pair, rng):
    s, codec, engine = pair
    S = 3
    data = rng.integers(0, 256, (S, s.k, 48), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    pattern = frozenset({0, s.k})  # data + local parity: the cascading case
    avail = _stack(stripes, [i for i in range(s.n) if i not in pattern])
    rebuilt, plan = engine.repair_multi(pattern, avail)
    assert set(rebuilt) == set(pattern)
    for b in pattern:
        assert (np.asarray(rebuilt[b]) == stripes[:, b, :]).all(), b
    # one flattened launch: coeff matrix covers every target at once
    compiled = engine.planner.multi_plan(pattern)
    assert compiled.coeffs.shape == (len(pattern), len(compiled.reads))


def test_batched_repair_accepts_dense_availability(pair, rng):
    s, codec, engine = pair
    data = rng.integers(0, 256, (2, s.k, 32), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    out, _ = engine.repair_single(1, stripes)  # (S, n, B) array form
    assert (np.asarray(out) == stripes[:, 1, :]).all()


def test_batched_repair_missing_read_raises(pair, rng):
    s, codec, engine = pair
    data = rng.integers(0, 256, (2, s.k, 32), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    plan = engine.planner.single_plan(0)
    some_read = plan.reads[0]
    avail = _stack(stripes, [i for i in range(1, s.n) if i != some_read])
    with pytest.raises(KeyError):
        engine.repair_single(0, avail)


# ------------------------------------------------------------------ decode
def test_batched_decode_any_rank_k_subset(pair, rng):
    s, codec, engine = pair
    S = 3
    data = rng.integers(0, 256, (S, s.k, 40), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    hits = 0
    for _ in range(8):
        ids = sorted(rng.choice(s.n, s.k, replace=False).tolist())
        if gf_rank(s.gen[ids]) < s.k:
            continue
        hits += 1
        dec = np.asarray(engine.decode(_stack(stripes, ids)))
        assert (dec == data).all()
        loop = np.stack([np.asarray(codec.decode_all(
            {i: stripes[j, i, :] for i in ids})) for j in range(S)])
        assert (dec == loop).all()
    assert hits > 0


# ------------------------------------------------------------- plan cache
def test_plan_cache_hit_miss_counters():
    s = make_scheme("cp-azure", 6, 2, 2)
    planner = RepairPlanner(s)
    assert planner.stats.lookups == 0
    p1 = planner.multi_plan({0, s.k})
    assert planner.stats.misses == 1 and planner.stats.hits == 0
    p2 = planner.multi_plan({s.k, 0})  # order-insensitive key
    assert planner.stats.hits == 1 and planner.stats.misses == 1
    assert p1 is p2
    planner.single_plan(0)
    planner.single_plan(0)
    planner.single_plan(0, policy="min")  # distinct key per policy
    assert planner.stats.misses == 3 and planner.stats.hits == 2


def test_plan_cache_lru_eviction():
    s = make_scheme("cp-azure", 6, 2, 2)
    planner = RepairPlanner(s, maxsize=2)
    planner.single_plan(0)
    planner.single_plan(1)
    planner.single_plan(2)  # evicts block 0's plan
    assert planner.stats.evictions == 1
    planner.single_plan(0)
    assert planner.stats.misses == 4  # recompiled after eviction


def test_planner_shared_between_codec_and_engine(rng):
    s = make_scheme("cp-uniform", 6, 2, 2)
    codec = StripeCodec(s)
    engine = BatchedCodecEngine(s, backend="gf", planner=codec.planner)
    data = rng.integers(0, 256, (2, s.k, 24), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    engine.repair_single(0, stripes)
    baseline = codec.planner.stats.misses
    codec.repair_single(0, {i: stripes[0, i, :] for i in range(1, s.n)})
    assert codec.planner.stats.misses == baseline  # codec reused engine's plan


def test_infeasible_pattern_raises():
    s = make_scheme("azure", 6, 2, 2)
    planner = RepairPlanner(s)
    # k+1 failures can never be decodable (rank < k survives)
    with pytest.raises(RuntimeError):
        planner.multi_plan(set(range(s.k + 1)))


# ------------------------------------------------- batched kernel lockstep
def test_batched_pallas_kernel_lockstep(rng):
    """The batched-grid Pallas kernel (interpreted) matches the table oracle
    exactly — uneven shapes exercise the padding path."""
    from repro.kernels.ops import gf_matmul_batch_op

    for (S, t, R, B) in [(1, 1, 5, 100), (3, 2, 9, 257), (5, 8, 12, 128)]:
        coef = rng.integers(0, 256, (t, R), dtype=np.uint8)
        data = rng.integers(0, 256, (S, R, B), dtype=np.uint8)
        want = np.asarray(gf_matmul_batch_op(coef, data, backend="ref"))
        got = np.asarray(gf_matmul_batch_op(coef, data, backend="gf",
                                            interpret=True, force_pallas=True))
        assert (got == want).all(), (S, t, R, B)
        fast = np.asarray(gf_matmul_batch_op(coef, data, backend="gf"))
        assert (fast == want).all(), (S, t, R, B)


def test_batch_op_rejects_unknown_backend(rng):
    from repro.kernels.ops import gf_matmul_batch_op

    data = rng.integers(0, 256, (2, 3, 16), dtype=np.uint8)
    coef = rng.integers(0, 256, (1, 3), dtype=np.uint8)
    with pytest.raises(ValueError):
        gf_matmul_batch_op(coef, data, backend="nope")


def test_batch_op_all_backends_bit_identical(rng):
    """Every registered backend — including the bit-plane pair, which used
    to be silently downgraded — runs the batched matmul bit-identically."""
    from repro.kernels.ops import BACKENDS, gf_matmul_batch_op

    coef = rng.integers(0, 256, (3, 5), dtype=np.uint8)
    data = rng.integers(0, 256, (4, 5, 200), dtype=np.uint8)
    want = np.asarray(gf_matmul_batch_op(coef, data, backend="ref"))
    for backend in BACKENDS:
        got = np.asarray(gf_matmul_batch_op(coef, data, backend=backend))
        assert (got == want).all(), backend


def test_batch_op_rejects_wrong_bitmatrix_shape(rng):
    from repro.kernels.ops import gf_matmul_batch_op

    coef = rng.integers(0, 256, (2, 3), dtype=np.uint8)
    data = rng.integers(0, 256, (2, 3, 16), dtype=np.uint8)
    bad = np.zeros((16, 16), dtype=np.uint8)   # want (16, 24)
    with pytest.raises(ValueError):
        gf_matmul_batch_op(coef, data, backend="crs", bitmatrix=bad)


def test_bit_plane_batched_kernels_lockstep(rng):
    """The stripe-grid crs/mxu Pallas kernels (interpreted, force_pallas)
    match the table oracle exactly, including the B-padding path."""
    from repro.kernels.ops import gf_matmul_batch_op

    for (S, t, R, B) in [(1, 1, 5, 104), (3, 2, 9, 264), (4, 4, 7, 128)]:
        coef = rng.integers(0, 256, (t, R), dtype=np.uint8)
        data = rng.integers(0, 256, (S, R, B), dtype=np.uint8)
        want = np.asarray(gf_matmul_batch_op(coef, data, backend="ref"))
        for backend in ("crs", "mxu"):
            got = np.asarray(gf_matmul_batch_op(
                coef, data, backend=backend, interpret=True,
                force_pallas=True))
            assert (got == want).all(), (backend, S, t, R, B)


# -------------------------------------------------------- store integration
def test_store_batched_repair_bit_identical_and_ragged(tmp_path, rng):
    """Fleet repair through the store: batched and looped paths agree on
    disk contents; batch_stripes=2 forces ragged last chunks."""
    from repro.ftx import (RepairOptions, StoreConfig, StripeStore,
                           repair_failed_nodes)

    def build(root):
        cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=1024,
                          batch_stripes=2)
        store = StripeStore(root, cfg)
        r = np.random.default_rng(7)
        for i in range(5):
            store.put(f"o{i}", r.integers(0, 256, 5000, dtype=np.uint8).tobytes())
        store.seal()
        return store

    sa, sb = build(tmp_path / "a"), build(tmp_path / "b")
    node = sa.stripes[0].node_of_block[0]

    rep = repair_failed_nodes(sa, [node], options=RepairOptions(batched=True))
    assert rep.stripes_repaired > 0
    assert rep.plan_cache["misses"] >= 1

    sb.fail_node(node)
    sb.repair_all(options=RepairOptions(batched=False))
    sb.revive_node(node)

    for sid in sa.stripes:
        for b in range(sa.scheme.n):
            pa = sa._block_path(sid, b)
            pb = sb._block_path(sid, b)
            assert pa.read_bytes() == pb.read_bytes(), (sid, b)


def test_store_unrecoverable_raises_ioerror_both_paths(tmp_path):
    """Batched and looped repair_all share the IOError contract on an
    unrecoverable stripe (batched must not leak planner RuntimeErrors)."""
    from repro.ftx import RepairOptions, StoreConfig, StripeStore

    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=512)
    store = StripeStore(tmp_path / "s", cfg)
    store.put("o", bytes(2000))
    store.seal()
    # Down 5 blocks of stripe 0: beyond p+r, never decodable.
    for b in range(5):
        store.fail_node(store.stripes[0].node_of_block[b])
    for batched in (True, False):
        with pytest.raises(IOError):
            store.repair_all(options=RepairOptions(batched=batched))
