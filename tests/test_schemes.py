"""Scheme construction invariants: cascade identity, distance, coverage."""
import itertools

import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import gf
from repro.core.schemes import PAPER_PARAMS, SCHEMES, make_scheme

ALL = sorted(SCHEMES)
SMALL = [(6, 2, 2), (12, 2, 2), (16, 3, 2), (20, 3, 5), (9, 3, 3), (10, 2, 3)]


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("krp", SMALL)
def test_construction_invariants(name, krp):
    k, r, p = krp
    if name == "azure+1" and p < 2:
        pytest.skip("azure+1 needs p>=2")
    s = make_scheme(name, k, r, p)
    assert s.n == k + r + p
    # data rows are identity
    assert (s.gen[:k] == np.eye(k, dtype=np.uint8)).all()
    # every local parity row equals its group composition
    for g in s.groups:
        row = np.zeros(k, np.uint8)
        for b, c in zip(g.items, g.coeffs):
            row ^= gf.gf_mul(np.uint8(c), s.gen[b])
        assert (row == s.gen[g.parity]).all(), (name, g.gid)
    # cascade: XOR of local parities == G_r
    if s.cascade is not None:
        acc = np.zeros(k, np.uint8)
        for b in s.cascade.members[:-1]:
            acc ^= s.gen[b]
        assert (acc == s.gen[s.cascade.members[-1]]).all()
    # every data block is covered by exactly one group for non-optimal
    covered = [0] * k
    for g in s.groups:
        for b in g.items:
            if b < k:
                covered[b] += 1
    assert all(c >= 1 for c in covered)


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("krp", [(6, 2, 2), (9, 3, 3), (12, 2, 2)])
def test_guaranteed_tolerance_exhaustive(name, krp):
    """Every pattern of size <= scheme.tolerance is decodable (exhaustive)."""
    k, r, p = krp
    s = make_scheme(name, k, r, p)
    t = s.tolerance
    untouched = make_scheme(name, k, r, p)
    for f in range(1, t + 1):
        for pat in itertools.combinations(range(s.n), f):
            alive = [b for b in range(s.n) if b not in pat]
            assert gf.gf_rank(untouched.gen[alive]) == k, (name, pat)


@pytest.mark.parametrize("name", ["cp-azure", "cp-uniform"])
def test_cp_distance_is_exactly_r_plus_1(name):
    """CP-LRCs tolerate any r failures but not all r+1 (paper §IV)."""
    s = make_scheme(name, 6, 2, 2)
    bad = 0
    for pat in itertools.combinations(range(s.n), s.r + 1):
        alive = [b for b in range(s.n) if b not in pat]
        if gf.gf_rank(s.gen[alive]) < s.k:
            bad += 1
    assert bad > 0  # minimum distance exactly r+1


def test_cp_spread_failures_decodable():
    """r+i failures decodable when i failures land in i distinct groups."""
    s = make_scheme("cp-azure", 12, 2, 3)
    # 2 globals + one data failure per distinct group
    g0 = s.groups[0].items[0]
    g1 = s.groups[1].items[0]
    pat = frozenset([g0, g1] + list(s.global_ids)[:2])
    assert s.decodable(pat)


@given(st.sampled_from(ALL), st.integers(0, 3))
@settings(max_examples=24, deadline=None)
def test_paper_params_construct(name, idx):
    lbl = list(PAPER_PARAMS)[idx]
    k, r, p = PAPER_PARAMS[lbl]
    s = make_scheme(name, k, r, p)
    assert s.n == k + r + p
    assert len(s.groups) == p


@pytest.mark.parametrize("krp", [(7, 2, 2), (11, 3, 2), (13, 2, 3)])
def test_non_divisible_parameters(krp):
    """k % p != 0 and (k+r-1) % p != 0 still construct and hold identities."""
    k, r, p = krp
    for name in ("azure", "optimal", "uniform", "cp-azure", "cp-uniform"):
        s = make_scheme(name, k, r, p)
        sizes = [len(g.items) for g in s.groups]
        assert max(sizes) - min(sizes) <= 1 or name in ("uniform",)
