"""Async pipelined repair: bit-identity with the synchronous path, overlap
telemetry, mid-pipeline failure injection, and the benchmark/CI plumbing
that gates it.

The 1-device cases always run; the sharded-pipeline case runs in the
forced-8-device CI leg (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np
import pytest

from _prop import given, settings, st
from repro.ftx import (FailureInjector, RepairOptions, StoreConfig,
                       StripeStore, repair_failed_nodes)

REPO = Path(__file__).resolve().parent.parent

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _build(root, *, stripes=40, block_size=512, batch_stripes=8, window=4,
           threads=4, **kw):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2,
                      block_size=block_size, batch_stripes=batch_stripes,
                      pipeline_window=window, prefetch_threads=threads, **kw)
    store = StripeStore(root, cfg)
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * cfg.k * block_size, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


def _all_blocks(store):
    return {(sid, b): store._block_path(sid, b).read_bytes()
            for sid in store.stripes for b in range(store.scheme.n)}


# ------------------------------------------------------------ bit-identity
def test_pipelined_bit_identical_single_node(tmp_path):
    sa = _build(tmp_path / "a")
    sb = _build(tmp_path / "b")
    node = sa.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(sa, [node], options=RepairOptions(pipeline=True))
    rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False))
    assert rep.pipelined and not rep_b.pipelined
    assert rep.windows > 1 and rep_b.windows == 0
    assert rep.stripes_repaired == rep_b.stripes_repaired > 0
    # same disk traffic and identical simulated (bandwidth-model) time: the
    # pipeline changes wall-clock only
    assert rep.blocks_read == rep_b.blocks_read
    assert rep.sim_seconds == pytest.approx(rep_b.sim_seconds)
    assert rep.repairs_local == rep_b.repairs_local
    assert _all_blocks(sa) == _all_blocks(sb)


def test_pipelined_bit_identical_multi_node(tmp_path):
    sa = _build(tmp_path / "a")
    sb = _build(tmp_path / "b")
    n0 = sa.stripes[0].node_of_block[0]
    n1 = sa.stripes[0].node_of_block[sa.scheme.k]   # a local parity's node
    rep = repair_failed_nodes(sa, [n0, n1], options=RepairOptions(pipeline=True))
    rep_b = repair_failed_nodes(sb, [n0, n1], options=RepairOptions(pipeline=False))
    assert rep.stripes_repaired == rep_b.stripes_repaired > 0
    assert rep.blocks_read == rep_b.blocks_read
    assert _all_blocks(sa) == _all_blocks(sb)


def test_pipeline_ragged_windows_and_window_override(tmp_path):
    """A window size that doesn't divide the pattern groups leaves ragged
    tail windows; bytes must not care."""
    sa = _build(tmp_path / "a", stripes=30, window=3)
    sb = _build(tmp_path / "b", stripes=30)
    node = sa.stripes[0].node_of_block[2]
    sa.fail_node(node)
    tele = sa.repair_all(options=RepairOptions(window=3))
    sa.revive_node(node)
    assert tele["pipelined"] and tele["windows"] >= len(sa.stripes) // 3 - 1
    sb.fail_node(node)
    sb.repair_all(options=RepairOptions(pipeline=False))
    sb.revive_node(node)
    assert _all_blocks(sa) == _all_blocks(sb)


# ------------------------------------------------------------- telemetry
def test_pipeline_span_telemetry_observable(tmp_path):
    store = _build(tmp_path / "s", io_stall_scale=0.02)
    node = store.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(store, [node], options=RepairOptions(pipeline=True))
    assert rep.pipelined
    assert rep.read_seconds > 0
    assert rep.compute_seconds > 0
    assert rep.write_seconds >= 0
    assert rep.overlap_seconds >= 0
    assert 0.0 <= rep.overlap_ratio <= 1.0
    assert store.engine.last_exec_seconds > 0
    # sync path accounts the same spans, serially (overlap telemetry ~0)
    rep_b = repair_failed_nodes(store, [node], options=RepairOptions(pipeline=False))
    assert rep_b.read_seconds > 0 and rep_b.compute_seconds > 0
    assert rep_b.windows == 0 and rep_b.replans == 0


def test_sync_fallback_config_knob(tmp_path):
    """pipeline_window=0 in the config disables pipelining by default;
    an explicit pipeline=True still opts in."""
    store = _build(tmp_path / "s", window=0)
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    tele = store.repair_all()
    assert not tele["pipelined"]
    tele = store.repair_all(options=RepairOptions(pipeline=True))
    assert tele["pipelined"]
    store.revive_node(node)


def test_pipelined_unrecoverable_raises_ioerror(tmp_path):
    store = _build(tmp_path / "s", stripes=10)
    for b in range(5):                      # beyond p+r: never decodable
        store.fail_node(store.stripes[0].node_of_block[b])
    with pytest.raises(IOError):
        store.repair_all(options=RepairOptions(pipeline=True))


def test_partial_repair_before_unrecoverable_pattern(tmp_path):
    """Mixed failures: pattern groups sorted before the first unrecoverable
    one still repair (on both paths, identically) before the IOError."""
    def build(root):
        cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=512,
                          batch_stripes=8, pipeline_window=4)
        store = StripeStore(root, cfg, num_nodes=20)
        payload = np.random.default_rng(3).integers(
            0, 256, 8 * cfg.k * cfg.block_size, dtype=np.uint8)
        store.put("blob", payload.tobytes())
        store.seal()
        return store

    sa, sb = build(tmp_path / "a"), build(tmp_path / "b")
    # Nodes 9-13 hold 5 blocks of stripe 1 (unrecoverable, n-k=4), but only
    # one block of stripe 0 — whose group sorts first and must repair.
    for store, pipe in ((sa, True), (sb, False)):
        assert len(store._down_blocks(1) | {0}) <= 1  # sanity: all up
        for node in range(9, 14):
            store.fail_node(node)
        assert len(store._down_blocks(1)) == 5
        assert len(store._down_blocks(0)) == 1
        with pytest.raises(IOError):
            store.repair_all(options=RepairOptions(pipeline=pipe))
        repaired = store.telemetry.repairs_local + store.telemetry.repairs_global
        assert repaired == 1, "the feasible group sorted first must repair"
    assert _all_blocks(sa) == _all_blocks(sb)


def test_failure_injector_pipeline_knob(tmp_path):
    store = _build(tmp_path / "s", stripes=10)
    inj = FailureInjector(store, mttf_hours=2.0, seed=1, pipeline=True)
    events = inj.run(hours=1.0)
    assert events                            # rate makes >=1 overwhelmingly likely
    blob = store.get("blob")
    assert blob.size == 10 * store.cfg.k * store.cfg.block_size


# ------------------------------------------------- mid-pipeline failures
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 9), st.sampled_from(["prefetch", "launch"]),
       st.integers(1, 9), st.integers(1, 4))
def test_node_failure_between_prefetch_and_launch_bit_identical(
        fail_at, stage, offset, window):
    """A node dying after a window's prefetch was submitted (or right
    before its launch) must re-plan or fall back cleanly, and every block
    the repair touched must still be bit-identical to the pre-failure
    truth — which is exactly what the synchronous path would produce, since
    both decode the same exact GF system."""
    with tempfile.TemporaryDirectory() as tmp:
        store = _build(Path(tmp) / "s", stripes=20, window=window)
        truth = _all_blocks(store)
        node = store.stripes[0].node_of_block[0]
        second = (node + offset) % store.num_nodes
        if second == node:
            second = (node + 1) % store.num_nodes
        store.fail_node(node)
        fired = []

        def hook(hook_stage, index):
            if hook_stage == stage and index == fail_at and not fired:
                fired.append(index)
                store.fail_node(second)

        tele = store.repair_all(options=RepairOptions(pipeline=True, pipeline_hook=hook))
        assert tele["pipelined"]
        store.revive_node(node)
        store.revive_node(second)
        assert _all_blocks(store) == truth


# ------------------------------------------------------------- sharding
def test_window_alignment_helpers():
    from repro.dist.stripes import align_stripe_window, stripe_axis_span

    assert stripe_axis_span(None) == 1
    assert align_stripe_window(13, None) == 13
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.dist.sharding import with_rules
    with with_rules(mesh) as mr:
        assert stripe_axis_span(mr) == 1
        assert align_stripe_window(13, mr) == 13


@multidevice
def test_window_alignment_rounds_to_device_span():
    from repro.dist.sharding import with_rules
    from repro.dist.stripes import align_stripe_window, stripe_axis_span

    with with_rules(jax.make_mesh((8, 1), ("data", "model"))) as mr:
        assert stripe_axis_span(mr) == 8
        assert align_stripe_window(20, mr) == 16     # keeps 8-way launches
        assert align_stripe_window(8, mr) == 8
        assert align_stripe_window(5, mr) == 5       # sub-span: degrades


@multidevice
def test_pipelined_sharded_repair_bit_identical(tmp_path):
    """The pipeline's launches shard over the mesh (devices=8) and stay
    bit-identical to the unsharded synchronous path."""
    from repro.dist.sharding import with_rules

    sa = _build(tmp_path / "a", stripes=80, window=8)
    sb = _build(tmp_path / "b", stripes=80)
    node = sa.stripes[0].node_of_block[0]
    with with_rules(jax.make_mesh((8, 1), ("data", "model"))):
        rep = repair_failed_nodes(sa, [node], options=RepairOptions(pipeline=True))
    assert rep.pipelined
    assert rep.devices == 8
    # round-robin placement makes every pattern group 8 stripes -> every
    # window is one full-span launch
    assert rep.device_launches == 8 * rep.launches
    rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False))
    assert rep_b.devices == 1
    assert _all_blocks(sa) == _all_blocks(sb)


# ------------------------------------------------------- CI plumbing
def _run_bench_cli(*args):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *args],
                          cwd=REPO, env=env, capture_output=True, text=True)


def test_run_list_prints_sections_and_exits_zero():
    proc = _run_bench_cli("--list")
    assert proc.returncode == 0
    from benchmarks.run import SECTIONS

    assert proc.stdout.split() == list(SECTIONS)


def test_run_only_typo_exits_nonzero():
    proc = _run_bench_cli("--only", "definitely_not_a_benchmark")
    assert proc.returncode != 0
    assert "unknown benchmark section" in proc.stderr


def test_run_only_typo_in_list_exits_nonzero():
    proc = _run_bench_cli("--only", "repair_costs,bogus_name")
    assert proc.returncode != 0
    assert "bogus_name" in proc.stderr


def test_check_regression_gate(tmp_path):
    from benchmarks.check_regression import main

    results = tmp_path / "results"
    results.mkdir()
    baseline = tmp_path / "baseline.json"

    def write(speedup, us):
        (results / "batched_repair.json").write_text(json.dumps({
            "min_single_speedup_at_S32": speedup,
            "rows": [{"single_batched_us_per_stripe": us,
                      "multi_speedup": speedup}],
        }))
        (results / "pipelined_repair.json").write_text(json.dumps({
            "min_speedup_at_acceptance": speedup,
            "rows": [{"stripes_per_sec_pipe": 1e6 / us}],
        }))

    write(8.0, 100.0)
    common = ["--results", str(results), "--baseline", str(baseline),
              "--sections", "batched_repair,pipelined_repair"]
    assert main(["--update-baseline", *common]) == 0
    assert main(common) == 0                       # identical results pass
    write(8.0 * 0.8, 100.0 / 0.8)                  # -20%: inside tolerance
    assert main(common) == 0
    write(8.0 * 0.5, 100.0 / 0.5)                  # -50%: regression
    assert main(common) == 1
    write(8.0, 100.0)
    assert main(["--tolerance", "0.6", *common]) == 0   # looser gate passes
    # reseeding one section must merge, not drop the others' floors
    assert main(["--update-baseline", "--results", str(results),
                 "--baseline", str(baseline),
                 "--sections", "batched_repair"]) == 0
    kept = json.loads(baseline.read_text())["sections"]
    assert "pipelined_repair" in kept and "batched_repair" in kept
    (results / "pipelined_repair.json").unlink()        # missing section
    assert main(common) == 1
