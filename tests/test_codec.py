"""Stripe codec: encode / repair / decode roundtrips, property-based."""
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.codec import StripeCodec
from repro.core.schemes import SCHEMES, make_scheme

ALL = sorted(SCHEMES)


@pytest.mark.parametrize("name", ALL)
def test_single_repair_every_block(name, rng):
    s = make_scheme(name, 6, 2, 2)
    codec = StripeCodec(s)
    data = rng.integers(0, 256, (6, 64), dtype=np.uint8)
    stripe = np.asarray(codec.encode(data))
    for b in range(s.n):
        avail = {i: stripe[i] for i in range(s.n) if i != b}
        blk, plan = codec.repair_single(b, avail)
        assert (np.asarray(blk) == stripe[b]).all(), (name, b, plan.method)


@given(st.sampled_from(ALL), st.integers(0, 10_000), st.integers(1, 2))
@settings(max_examples=40, deadline=None)
def test_property_within_tolerance_always_repairs(name, seed, nfail):
    """Any failure pattern of size <= tolerance repairs bit-exactly."""
    rng = np.random.default_rng(seed)
    s = make_scheme(name, 8, 2, 2)
    nfail = min(nfail, s.tolerance)
    codec = StripeCodec(s)
    data = rng.integers(0, 256, (8, 40), dtype=np.uint8)
    stripe = np.asarray(codec.encode(data))
    failed = frozenset(rng.choice(s.n, nfail, replace=False).tolist())
    avail = {i: stripe[i] for i in range(s.n) if i not in failed}
    rebuilt, plan = codec.repair_multi(failed, avail)
    assert plan.feasible
    for b in failed:
        assert (np.asarray(rebuilt[b]) == stripe[b]).all()


@given(st.sampled_from(ALL), st.integers(0, 10_000), st.integers(3, 4))
@settings(max_examples=30, deadline=None)
def test_property_decodable_iff_rank(name, seed, nfail):
    """Beyond the guarantee: repair succeeds exactly when rank says so."""
    rng = np.random.default_rng(seed)
    s = make_scheme(name, 8, 2, 2)
    codec = StripeCodec(s)
    data = rng.integers(0, 256, (8, 24), dtype=np.uint8)
    stripe = np.asarray(codec.encode(data))
    failed = frozenset(rng.choice(s.n, nfail, replace=False).tolist())
    avail = {i: stripe[i] for i in range(s.n) if i not in failed}
    if s.decodable(failed):
        rebuilt, _ = codec.repair_multi(failed, avail)
        for b in failed:
            assert (np.asarray(rebuilt[b]) == stripe[b]).all()
    else:
        with pytest.raises(RuntimeError):
            codec.repair_multi(failed, avail)


@pytest.mark.parametrize("name", ["cp-azure", "cp-uniform"])
@pytest.mark.parametrize("backend", ["gf", "crs", "mxu", "ref"])
def test_encode_backends_match(name, backend, rng):
    s = make_scheme(name, 12, 3, 3)
    codec = StripeCodec(s, backend=backend)
    data = rng.integers(0, 256, (12, 80), dtype=np.uint8)
    stripe = np.asarray(codec.encode(data))
    want = s.encode(data)  # numpy planning-tier ground truth
    assert (stripe == want).all(), backend


def test_decode_all_any_rank_k_subset(rng):
    s = make_scheme("cp-uniform", 6, 2, 2)
    codec = StripeCodec(s)
    data = rng.integers(0, 256, (6, 48), dtype=np.uint8)
    stripe = np.asarray(codec.encode(data))
    for _ in range(10):
        ids = sorted(rng.choice(s.n, s.k, replace=False).tolist())
        from repro.core.gf import gf_rank

        if gf_rank(s.gen[ids]) < s.k:
            continue
        dec = np.asarray(codec.decode_all({i: stripe[i] for i in ids}))
        assert (dec == data).all()
