"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exact."""
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.gf import gf_matmul, matrix_to_bitmatrix
from repro.kernels import ref as R
from repro.kernels.bitmatrix_encode import bitmatrix_encode, mod2_matmul_encode
from repro.kernels.gf256_matmul import gf256_matmul
from repro.kernels.ops import crs_encode_op, encode_op, gf_matmul_op

SHAPES = [(2, 4, 128), (4, 6, 256), (8, 24, 512), (9, 96, 128), (3, 17, 384)]


@pytest.mark.parametrize("m,k,b", SHAPES)
def test_gf256_matmul_kernel(m, k, b, rng):
    coef = rng.integers(0, 256, (m, k), dtype=np.uint8)
    data = rng.integers(0, 256, (k, b), dtype=np.uint8)
    want = gf_matmul(coef, data)
    got = np.asarray(gf_matmul_op(coef, data, backend="gf"))
    assert (got == want).all()


@pytest.mark.parametrize("m,k,b", SHAPES)
def test_refs_agree(m, k, b, rng):
    coef = rng.integers(0, 256, (m, k), dtype=np.uint8)
    data = rng.integers(0, 256, (k, b), dtype=np.uint8)
    want = gf_matmul(coef, data)
    r1 = np.asarray(R.gf256_matmul_ref(jnp.asarray(coef), jnp.asarray(data)))
    r2 = np.asarray(R.gf256_matmul_shift_ref(jnp.asarray(coef),
                                             jnp.asarray(data)))
    assert (r1 == want).all() and (r2 == want).all()


@pytest.mark.parametrize("m,k,b", SHAPES)
@pytest.mark.parametrize("backend", ["crs", "mxu"])
def test_bitmatrix_kernels(m, k, b, backend, rng):
    coef = rng.integers(0, 256, (m, k), dtype=np.uint8)
    data = rng.integers(0, 256, (k, b), dtype=np.uint8)
    want = gf_matmul(coef, data)
    got = np.asarray(encode_op(coef, data, backend=backend))
    assert (got == want).all(), backend


@given(st.integers(1, 6), st.integers(2, 12), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_property_all_backends_agree(m, k, nwords, seed):
    """Any (m, k, B): every backend computes the same parity bytes."""
    rng = np.random.default_rng(seed)
    b = nwords * 8
    coef = rng.integers(1, 256, (m, k), dtype=np.uint8)
    data = rng.integers(0, 256, (k, b), dtype=np.uint8)
    want = gf_matmul(coef, data)
    for backend in ("gf", "crs", "mxu", "ref"):
        got = np.asarray(encode_op(coef, data, backend=backend))
        assert (got == want).all(), backend


@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_packetize_roundtrip(k, words, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, 256, (k, words * 8), dtype=np.uint8)
    pk = R.packetize(jnp.asarray(blocks))
    assert pk.shape == (k * 8, words)
    back = np.asarray(R.unpacketize(pk))
    assert (back == blocks).all()


def test_kernel_tile_sweep(rng):
    """BlockSpec tiling sweep: result invariant to tile choices."""
    coef = rng.integers(0, 256, (8, 16), dtype=np.uint8)
    data = rng.integers(0, 256, (16, 1024), dtype=np.uint8)
    want = gf_matmul(coef, data)
    for tm in (1, 2, 4, 8):
        for tb in (128, 256, 512, 1024):
            got = np.asarray(gf256_matmul(jnp.asarray(coef), jnp.asarray(data),
                                          tile_m=tm, tile_b=tb, interpret=True))
            assert (got == want).all(), (tm, tb)
    bm = jnp.asarray(matrix_to_bitmatrix(coef))
    pk = R.packetize(jnp.asarray(data))
    want_pk = R.packetize(jnp.asarray(want))
    for tr in (8, 16, 32, 64):
        got = bitmatrix_encode(bm, pk, tile_r=tr, tile_p=64, interpret=True)
        assert (np.asarray(got) == np.asarray(want_pk)).all(), tr
    for tp in (32, 64, 128):
        got = mod2_matmul_encode(bm, pk, tile_p=tp, interpret=True)
        assert (np.asarray(got) == np.asarray(want_pk)).all(), tp
