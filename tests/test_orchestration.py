"""Global repair orchestration (DESIGN.md §14): the cross-window min-cost
assignment's dominance chain, topology-aware rebuild destinations, the
golden failure-trace fixture + replay determinism, and the background
rebalancer — the property layer that pins PR 10's tentpole.

The 1-device cases always run; the multi-device cases run in the
forced-8-device CI leg (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np
import pytest

from _prop import given, settings, st
from repro.dist.placement import PlacementMap, block_loads
from repro.dist.schedule import (greedy_assign, optimize_assignment,
                                 schedule_group)
from repro.dist.sharding import with_rules
from repro.dist.topology import Topology, placement_ok
from repro.ftx import (RepairOptions, StoreConfig, StripeStore, plan_moves,
                       rebalance)
from repro.ftx.events import (NodeFailEvent, dump_trace, from_doc,
                              load_trace, sort_events, to_doc)
from repro.ftx.failures import replay_trace

REPO = Path(__file__).resolve().parent.parent
TRACE = Path(__file__).resolve().parent / "data" / "correlated_trace.json"

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(shape=(8, 1)):
    return jax.make_mesh(shape, ("data", "model"))


def _trace_store(root, *, stripes=40, block=512, num_nodes=24, domains=12,
                 spread_width=2, scheme="cp-azure", policy="spread"):
    """A store on the geometry the committed trace fixture targets:
    2-node racks, so every correlated batch stays within the scheme's
    universal 2-erasure decodability."""
    topo = Topology(num_nodes=num_nodes, num_domains=domains,
                    spread_width=spread_width, seed=7)
    cfg = StoreConfig(scheme=scheme, k=6, r=2, p=2, block_size=block,
                      batch_stripes=8, pipeline_window=8,
                      prefetch_threads=2, placement_policy=policy)
    store = StripeStore(root, cfg, num_nodes=num_nodes, topology=topo)
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * cfg.k * block, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


def _all_blocks(store):
    return {(sid, b): store._block_path(sid, b).read_bytes()
            for sid in store.stripes for b in range(store.scheme.n)}


def _loads(store):
    return block_loads((s.node_of_block for s in store.stripes.values()),
                       store.num_nodes)


def _fake_placement(num_nodes, shards, reads, sids, seed):
    """A synthetic PlacementMap: seeded random node->shard and block->node."""
    rng = np.random.default_rng(seed)
    shard_of = tuple(int(s) for s in rng.integers(0, shards, num_nodes))
    table = {(sid, b): int(rng.integers(num_nodes))
             for sid in sids for b in reads}
    return PlacementMap(shard_of_node=shard_of,
                        node_of=lambda sid, b: table[(sid, b)])


# ----------------------------------------------------- assignment solver
@settings(max_examples=60, deadline=None)
@given(st.integers(2, 6), st.integers(1, 8), st.integers(1, 10),
       st.integers(0, 99999))
def test_global_assignment_dominates_greedy_and_contiguous(span, cap, amax,
                                                           seed):
    """The tentpole dominance chain at the solver level, over random
    affinity matrices: the cycle-canceled assignment is never below the
    greedy or the contiguous one, preserves every column capacity, and
    reaches the same optimum from either warm start (it is exact, not just
    monotone)."""
    rng = np.random.default_rng(seed)
    n = span * cap
    a = rng.integers(0, amax + 1, size=(n, span)).astype(np.int64)

    def total(assign):
        return int(sum(int(a[i, int(d)]) for i, d in enumerate(assign)))

    contiguous = [i // cap for i in range(n)]
    greedy = greedy_assign(a, cap)
    assert sorted(greedy) == contiguous          # capacity: cap per column
    # schedule_chunk's floor: keep the contiguous order unless greedy
    # strictly beats it — the chain's middle link is max(greedy, contig).
    floor = max(total(greedy), total(contiguous))
    opt_g = optimize_assignment(a, greedy)
    opt_c = optimize_assignment(a, contiguous)
    for opt in (opt_g, opt_c):
        assert sorted(int(d) for d in opt) == contiguous
    assert total(opt_g) == total(opt_c)          # warm-start independent
    assert total(opt_g) >= floor                 # global >= greedy >= contig


def test_optimize_assignment_edge_cases():
    empty = optimize_assignment(np.zeros((0, 3), dtype=np.int64), [])
    assert empty.size == 0
    one_col = optimize_assignment(np.ones((4, 1), dtype=np.int64),
                                  [0, 0, 0, 0])
    assert one_col.tolist() == [0, 0, 0, 0]
    # already-optimal start is returned unchanged
    a = np.array([[5, 0], [0, 5]], dtype=np.int64)
    assert optimize_assignment(a, [0, 1]).tolist() == [0, 1]
    # a 2-cycle that pays: both stripes start on their worst column
    assert optimize_assignment(a, [1, 0]).tolist() == [0, 1]


@multidevice
@settings(max_examples=12, deadline=None)
@given(st.integers(2, 4), st.integers(1, 6), st.integers(2, 9),
       st.integers(0, 999))
def test_schedule_group_global_dominates_per_chunk(windows, num_reads,
                                                   shards, seed):
    """Store-free property on random placements: pooling every window into
    one transportation problem never predicts fewer shard-local reads than
    per-chunk greedy, which never predicts fewer than contiguous; the
    output stays a permutation of the group with per-window capacity."""
    with with_rules(_mesh()) as mr:
        sids = [100 + 7 * i for i in range(8 * windows)]
        reads = tuple(range(num_reads))
        pm = _fake_placement(32, shards, reads, sids, seed)
        outs = {mode: schedule_group(sids, reads, pm, mr, step=8, mode=mode)
                for mode in ("none", "locality", "global")}
        tot = {m: sum(c.scheduled_local for c in cs)
               for m, cs in outs.items()}
        assert tot["global"] >= tot["locality"] >= tot["none"]
        for cs_list in outs.values():
            assert sorted(s for cs in cs_list for s in cs.sids) \
                == sorted(sids)                 # group-wide permutation
            assert all(len(cs.sids) == 8 for cs in cs_list)
        # contiguous predictions compare like for like across modes
        assert sum(c.contiguous_local for c in outs["global"]) \
            == tot["none"]
        assert all(c.total_reads == 8 * num_reads for c in outs["global"])


@multidevice
def test_schedule_group_keeps_degraded_tail_chunks():
    """A tail chunk the span does not divide launches degraded and is
    excluded from the pooled assignment under every mode."""
    with with_rules(_mesh()) as mr:
        sids = list(range(20))                  # chunks of 8, 8, 4
        reads = (0, 1, 2)
        pm = _fake_placement(32, 4, reads, sids, 5)
        for mode in ("none", "locality", "global"):
            out = schedule_group(sids, reads, pm, mr, step=8, mode=mode)
            assert len(out) == 3
            assert out[-1].is_identity and out[-1].span == 1
            assert out[-1].sids == tuple(range(16, 20))


# --------------------------------------------------- golden trace fixture
def test_trace_fixture_golden_roundtrip(tmp_path):
    """The committed fixture is byte-stable: doc round-trips are identity,
    dump(load(fixture)) reproduces the exact committed bytes, canonical
    ordering is input-order independent, and the bare-list form loads to
    the same events."""
    committed = TRACE.read_bytes()
    events = load_trace(TRACE)
    assert len(events) == 6
    assert events == sort_events(events)        # loads canonically sorted
    for e in events:
        assert from_doc(to_doc(e)) == e
    out = tmp_path / "again.json"
    dump_trace(events, out)
    assert out.read_bytes() == committed
    dump_trace(list(reversed(events)), out)     # order-independent dump
    assert out.read_bytes() == committed
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps([to_doc(e) for e in reversed(events)]))
    assert load_trace(bare) == events


def test_replay_trace_batches_correlated_failures(tmp_path):
    """Same-timestamp failures repair as one batch: the fixture's six
    events collapse to four batches (two node bursts, one rack, one
    singleton), rack events expand through the topology, and revived
    nodes leave the fleet whole."""
    store = _trace_store(tmp_path / "s", stripes=24)
    events = load_trace(TRACE)
    res = replay_trace(store, events, options=RepairOptions())
    rows = res["batches"]
    assert [r["t"] for r in rows] == [10.0, 250.5, 400.25, 612.75]
    assert rows[0]["nodes"] == [7, 17]
    assert rows[1]["nodes"] == store.topology.nodes_in(2) == [4, 5]
    assert rows[2]["nodes"] == [3]
    assert rows[3]["nodes"] == [20, 21]
    assert all(r["blocks_read"] > 0 for r in rows)
    assert all(s.name == "UP" for s in store.nodes.values())  # revived
    for key in ("blocks_read", "blocks_relocated", "repairs_local"):
        assert res["totals"][key] == sum(r[key] for r in rows)
    # every NodeFailEvent earns a RepairDoneEvent in the emitted log
    fails = [e for e in res["events"] if isinstance(e, NodeFailEvent)]
    assert sorted(e.node for e in fails) == [3, 4, 5, 7, 17, 20, 21]
    bad = [NodeFailEvent(t=1.0, node=99)]
    with pytest.raises(ValueError):
        replay_trace(store, bad)


def test_replay_trace_schedule_modes_bit_identical_one_device(tmp_path):
    """Without a mesh the scheduler is inert (span 1): the global and
    disabled schedules replay to byte-identical stores with coinciding
    predictions."""
    events = load_trace(TRACE)
    stores, res = {}, {}
    for mode in ("global", "none"):
        s = _trace_store(tmp_path / mode, stripes=24)
        res[mode] = replay_trace(s, events,
                                 options=RepairOptions(schedule=mode))
        stores[mode] = s
    assert _all_blocks(stores["global"]) == _all_blocks(stores["none"])
    ga, na = res["global"]["totals"], res["none"]["totals"]
    assert ga["blocks_read"] == na["blocks_read"]
    assert ga["scheduled_local"] == ga["contiguous_local"]
    assert na["scheduled_local"] == na["contiguous_local"]


@multidevice
def test_replay_trace_dominance_chain_8dev(tmp_path):
    """The tentpole acceptance on the committed trace: global strictly
    beats per-chunk greedy strictly beats contiguous on counted scheduled
    shard-local reads, with all three replays byte-identical (assignment
    is a pure permutation; write-back is keyed by sid)."""
    events = load_trace(TRACE)
    stores, totals = {}, {}
    with with_rules(_mesh()):
        for mode in ("global", "locality", "none"):
            s = _trace_store(tmp_path / mode, stripes=160)
            totals[mode] = replay_trace(
                s, events, options=RepairOptions(schedule=mode,
                                                 pipeline=True))["totals"]
            stores[mode] = s
    blocks = _all_blocks(stores["global"])
    assert _all_blocks(stores["locality"]) == blocks
    assert _all_blocks(stores["none"]) == blocks
    g, l, c = (totals[m]["scheduled_local"]
               for m in ("global", "locality", "none"))
    assert g > l > c
    assert totals["global"]["schedule_total"] \
        == totals["none"]["schedule_total"] > 0


def _replay_cli(tmp, tag):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.simulate",
         "--replay", str(TRACE), "--nodes", "24", "--domains", "12",
         "--policy", "spread", "--schedule", "global",
         "--destinations", "topology", "--rebalance",
         "--replay-store", str(tmp / tag)],
        cwd=REPO, env=env, capture_output=True, text=True)


def test_replay_cli_deterministic(tmp_path):
    """Two ``--replay`` runs over the committed trace print byte-identical
    JSON — every reported field is an exact count (simulated seconds are
    rounded to a stable precision)."""
    a = _replay_cli(tmp_path, "a")
    b = _replay_cli(tmp_path, "b")
    assert a.returncode == 0, a.stderr
    assert b.returncode == 0, b.stderr
    assert a.stdout == b.stdout
    doc = json.loads(a.stdout)
    assert doc["trace_events"] == 6
    assert doc["schedule"] == "global"
    assert doc["destinations"] == "topology"
    assert len(doc["batches"]) == 4
    assert doc["totals"]["blocks_read"] > 0
    assert doc["rebalance"]["moved"] == doc["rebalance"]["planned"]


# ------------------------------------------------- rebuild destinations
@pytest.mark.parametrize("scheme", ["cp-azure", "cp-uniform"])
@settings(max_examples=3, deadline=None)
@given(st.sampled_from([0, 3, 7]))
def test_topology_destinations_preserve_invariants(domain, scheme):
    """Permanent loss of two nodes of one domain, on a fleet with spare
    copyset capacity (40 nodes / 8 domains / width 3): topology-aware
    destinations relocate every rebuilt block onto UP nodes, keep the
    spread policy's width bound, keep bytes intact, and leave the
    relocated blocks repairable again after a follow-up failure."""
    with tempfile.TemporaryDirectory() as tmp:
        store = _trace_store(Path(tmp) / "s", stripes=24, num_nodes=40,
                             domains=8, spread_width=3, scheme=scheme)
        topo = store.topology
        payload = np.asarray(store.get("blob")).tobytes()
        before = {sid: list(s.node_of_block)
                  for sid, s in store.stripes.items()}
        victims = topo.nodes_in(domain)[:2]
        for n in victims:
            store.fail_node(n)
        tele = store.repair_all(options=RepairOptions(
            destinations="topology"))
        assert tele["blocks_relocated"] > 0
        up = {n for n, s in store.nodes.items() if s.name == "UP"}
        moved_to = set()
        for sid, s in store.stripes.items():
            assert all(n in up for n in s.node_of_block), sid
            assert placement_ok("spread", topo, s.node_of_block), sid
            moved_to.update(n for n, o in zip(s.node_of_block, before[sid])
                            if n != o)
        assert moved_to and all(n in up for n in moved_to)
        assert np.asarray(store.get("blob")).tobytes() == payload
        # a relocated block's new home fails: the stripe repairs again
        # (single erasure -> local decode) and the bytes still round-trip
        follow = min(moved_to)
        store.fail_node(follow)
        tele2 = store.repair_all(options=RepairOptions(
            destinations="topology"))
        assert tele2["repairs_local"] > 0
        up2 = {n for n, s in store.nodes.items() if s.name == "UP"}
        for sid, s in store.stripes.items():
            assert all(n in up2 for n in s.node_of_block), sid
        assert np.asarray(store.get("blob")).tobytes() == payload


# ------------------------------------------------------------ rebalancer
def test_expand_validates_and_roundtrips(tmp_path):
    store = _trace_store(tmp_path / "s", stripes=24)
    topo2 = Topology(num_nodes=26, num_domains=13, spread_width=2, seed=7)
    # add-a-rack expansion: every existing node keeps its domain
    assert all(store.topology.domain_of(i) == topo2.domain_of(i)
               for i in range(24))
    added = store.expand(topo2)
    assert added == [24, 25]
    assert store.num_nodes == 26
    assert all(store.nodes[n].name == "UP" for n in added)
    with pytest.raises(ValueError):
        store.expand(Topology(num_nodes=24, num_domains=12))
    store.save_manifest()
    loaded = StripeStore.load(tmp_path / "s")
    assert loaded.num_nodes == 26
    assert loaded.topology == topo2


def test_plan_moves_deterministic_and_legal(tmp_path):
    # round_robin: dispersion (<= 1 block per domain here) is preserved by
    # moves into the added rack's fresh domain. A saturated spread copyset
    # on this fleet (2 blocks in each of 5 two-node racks) legally accepts
    # no expansion move at all — the planner must then emit an empty plan,
    # which test_rebalance_frozen_on_saturated_copysets pins.
    store = _trace_store(tmp_path / "s", stripes=48, policy="round_robin")
    store.expand(Topology(num_nodes=26, num_domains=13, spread_width=2,
                          seed=7))
    plan = plan_moves(store)
    assert plan and plan == plan_moves(store)   # pure + deterministic
    assert len({(m.sid, m.block) for m in plan}) == len(plan)  # move once
    placed = {sid: list(s.node_of_block) for sid, s in store.stripes.items()}
    for m in plan:
        assert m.src != m.dst
        assert store.nodes[m.dst].name == "UP"
        assert placed[m.sid][m.block] == m.src
        assert m.dst not in placed[m.sid]       # stays distinct
        placed[m.sid][m.block] = m.dst
    capped = plan_moves(store, max_moves=5)
    assert capped == plan[:5]


def test_rebalance_frozen_on_saturated_copysets(tmp_path):
    """When every legal move would widen a saturated spread copyset, the
    planner must refuse to trade durability for balance: empty plan."""
    store = _trace_store(tmp_path / "s", stripes=24)  # width-5 copysets
    store.expand(Topology(num_nodes=26, num_domains=13, spread_width=2,
                          seed=7))
    assert plan_moves(store) == []
    rep = rebalance(store)
    assert rep.planned == rep.moved == 0
    assert rep.imbalance_after == rep.imbalance_before


def test_rebalance_after_expansion_smooths_and_is_idempotent(tmp_path):
    store = _trace_store(tmp_path / "s", stripes=48, policy="round_robin")
    payload = np.asarray(store.get("blob")).tobytes()
    store.expand(Topology(num_nodes=26, num_domains=13, spread_width=2,
                          seed=7))
    hooks = []
    rep = rebalance(store, hook=lambda stage, i: hooks.append((stage, i)))
    assert rep.planned == rep.moved > 0
    assert rep.imbalance_after < rep.imbalance_before
    assert rep.windows == -(-rep.planned // store.cfg.pipeline_window)
    assert {s for s, _ in hooks} == {"prefetch", "commit"}
    assert sorted(i for s, i in hooks if s == "commit") \
        == list(range(rep.windows))
    loads = _loads(store)
    assert loads[24] > 0 and loads[25] > 0      # new rack received blocks
    # every replica is on disk where the manifest says, bytes unchanged
    assert all(store._block_path(sid, b).exists()
               for sid in store.stripes for b in range(store.scheme.n))
    assert np.asarray(store.get("blob")).tobytes() == payload
    assert rebalance(store).planned == 0        # idempotent


def test_rebalance_drains_down_nodes_after_in_place_repair(tmp_path):
    """The domain-loss migration case: an in-place repair of a permanent
    loss leaves rebuilt blocks addressed to the dead node; the rebalancer
    treats them as must-move and drains them onto UP nodes through the
    degraded-read path."""
    store = _trace_store(tmp_path / "s", stripes=24, num_nodes=40,
                         domains=8, spread_width=3)
    payload = np.asarray(store.get("blob")).tobytes()
    victim = store.stripes[min(store.stripes)].node_of_block[0]
    store.fail_node(victim)
    store.repair_all(options=RepairOptions(destinations="in_place"))
    held = [(sid, b) for sid, s in store.stripes.items()
            for b, n in enumerate(s.node_of_block) if n == victim]
    assert held                                 # still on the dead address
    rep = rebalance(store)
    assert rep.moved >= len(held)
    assert _loads(store)[victim] == 0
    assert all(n != victim for s in store.stripes.values()
               for n in s.node_of_block)
    assert np.asarray(store.get("blob")).tobytes() == payload
