"""Placement-aware sharded gather: the PlacementMap abstraction, the
per-shard gather geometry, locality accounting, and bit-identity of the
sharded read stack with the single-host path.

The 1-device cases always run; the multi-device cases run in the
forced-8-device CI leg (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import jax
import numpy as np
import pytest

from repro.dist.placement import PlacementMap, assemble_shards, shard_layout
from repro.dist.sharding import with_rules
from repro.dist.stripes import align_stripe_window, stripe_axis_span
from repro.ftx import (RepairOptions, StoreConfig, StripeStore,
                       repair_failed_nodes)

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(shape=(8, 1)):
    return jax.make_mesh(shape, ("data", "model"))


def _build(root, *, stripes=80, block_size=512, batch_stripes=8, **kw):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2,
                      block_size=block_size, batch_stripes=batch_stripes,
                      pipeline_window=batch_stripes, prefetch_threads=2, **kw)
    store = StripeStore(root, cfg)
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * cfg.k * block_size, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


def _all_blocks(store):
    return {(sid, b): store._block_path(sid, b).read_bytes()
            for sid in store.stripes for b in range(store.scheme.n)}


# ------------------------------------------------------------ PlacementMap
def test_placement_map_locate_and_cost(tmp_path):
    store = _build(tmp_path / "s", stripes=10)
    pm = PlacementMap.from_store(store, num_shards=2, remote_multiplier=3.0)
    assert pm.num_shards == 2
    # contiguous node ranges: first half of the 10 nodes -> shard 0
    assert pm.shard_of(0) == 0 and pm.shard_of(store.num_nodes - 1) == 1
    node, shard = pm.locate(0, 0)
    assert node == store.stripes[0].node_of_block[0]
    assert shard == pm.shard_of(node)
    # locality cost model
    assert pm.is_local(node, shard) and pm.read_multiplier(node, shard) == 1.0
    other = 1 - shard
    assert not pm.is_local(node, other)
    assert pm.read_multiplier(node, other) == 3.0
    # unattributed reads are local by definition
    assert pm.is_local(node, None) and pm.read_multiplier(node, None) == 1.0


def test_placement_map_defaults_from_config(tmp_path):
    store = _build(tmp_path / "s", stripes=10, remote_read_multiplier=2.5)
    pm = PlacementMap.from_store(store, num_shards=4)
    assert pm.remote_multiplier == 2.5
    assert pm.num_shards == 4


def test_reader_shard_contiguous_mapping(tmp_path):
    store = _build(tmp_path / "s", stripes=10)
    pm = PlacementMap.from_store(store, num_shards=2)
    # device span 4 folded onto 2 hosts: first two device shards -> host 0
    assert [pm.reader_shard(d, 4) for d in range(4)] == [0, 0, 1, 1]
    # identity when span == hosts
    assert [pm.reader_shard(d, 2) for d in range(2)] == [0, 1]
    one = PlacementMap.from_store(store, num_shards=1)
    assert [one.reader_shard(d, 8) for d in range(8)] == [0] * 8


def test_shard_layout_degrades_without_mesh():
    assert shard_layout((32, 4, 512), None) is None
    with with_rules(_mesh((1, 1))) as mr:
        assert shard_layout((32, 4, 512), mr) is None


# ----------------------------------------------------- layout geometry
@multidevice
def test_shard_layout_partitions_in_stripe_order():
    with with_rules(_mesh()) as mr:
        layout = shard_layout((32, 4, 512), mr)
        assert layout is not None and len(layout) == 8
        # contiguous equal slices covering [0, S) in order — the same
        # stripe->device mapping align_stripe_window preserves
        assert [(sl.lo, sl.hi) for sl in layout] == \
            [(i * 4, (i + 1) * 4) for i in range(8)]
        assert all(sl.index == i for i, sl in enumerate(layout))
        assert all(len(sl.devices) == 1 for sl in layout)
        # an aligned window always yields a full-span layout
        win = align_stripe_window(20, mr)
        assert win == 16
        assert len(shard_layout((win, 4, 512), mr)) == stripe_axis_span(mr)
        # indivisible S degrades
        assert shard_layout((13, 4, 512), mr) is None


@multidevice
def test_shard_layout_replicated_axis_devices():
    """A 4x2 mesh shards stripes 4 ways and replicates over "model": each
    slice is owned by 2 devices and assembly still round-trips exactly."""
    with with_rules(_mesh((4, 2))) as mr:
        shape = (16, 3, 64)
        layout = shard_layout(shape, mr)
        assert len(layout) == 4
        assert all(len(sl.devices) == 2 for sl in layout)
        g = np.arange(np.prod(shape), dtype=np.uint8).reshape(shape)
        bufs = [g[sl.lo:sl.hi] for sl in layout]
        ga = assemble_shards(shape, mr, layout, bufs)
        assert (np.asarray(ga) == g).all()


@multidevice
def test_assemble_shards_zero_copy_launch():
    """An assembled global batch is consumed by the sharded launch with the
    same bytes as the host path (and the sharding it was built with)."""
    from repro.dist.stripes import stripe_sharding
    from repro.kernels.ops import gf_matmul_batch_op

    rng = np.random.default_rng(5)
    coef = rng.integers(0, 256, (3, 5), dtype=np.uint8)
    shape = (16, 5, 256)
    data = rng.integers(0, 256, shape, dtype=np.uint8)
    with with_rules(_mesh()) as mr:
        layout = shard_layout(shape, mr)
        ga = assemble_shards(shape, mr, layout,
                             [data[sl.lo:sl.hi] for sl in layout])
        assert ga.sharding.is_equivalent_to(stripe_sharding(shape, mr), 3)
        want = np.asarray(gf_matmul_batch_op(coef, data, backend="ref"))
        got = np.asarray(gf_matmul_batch_op(coef, ga, backend="ref",
                                            mesh_rules=mr))
        # non-uint8 host input is coerced identically on the sharded path
        wide = np.asarray(gf_matmul_batch_op(
            coef, data.astype(np.int64), backend="ref", mesh_rules=mr))
    assert (want == got).all()
    assert wide.dtype == np.uint8 and (want == wide).all()


# ------------------------------------------------- store integration
def test_unsharded_repair_counts_local(tmp_path):
    """Without a mesh the derived placement has one shard: every repair
    read is local and all gather bytes land on shard 0."""
    store = _build(tmp_path / "s", stripes=20)
    node = store.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(store, [node])
    assert rep.remote_reads == 0
    assert rep.local_reads == rep.blocks_read > 0
    assert rep.local_read_fraction == 1.0
    assert set(rep.gather_bytes_per_shard) == {0}
    assert rep.gather_bytes_per_shard[0] == rep.bytes_read


def test_degraded_reads_not_attributed_to_gather(tmp_path):
    """Client/degraded-read paths stay out of the per-shard gather bytes
    (no shard attribution), and count as local."""
    store = _build(tmp_path / "s", stripes=10)
    before = store.telemetry.copy()
    store.fail_node(store.stripes[0].node_of_block[0])
    store.get("blob")                       # degraded read, no repair_all
    t = store.telemetry
    assert t.blocks_read > before.blocks_read
    assert t.remote_reads == 0
    assert t.gather_bytes_per_shard == before.gather_bytes_per_shard


def test_remote_multiplier_inflates_sim_time(tmp_path):
    """Two shards over the node set: cross-shard reads pay the multiplier
    in simulated time, but rebuilt bytes are identical."""
    sa = _build(tmp_path / "a", stripes=20)
    sb = _build(tmp_path / "b", stripes=20)
    node = sa.stripes[0].node_of_block[0]
    cheap = PlacementMap.from_store(sa, num_shards=1)
    costly = PlacementMap(
        shard_of_node=PlacementMap.from_store(sb, num_shards=2).shard_of_node,
        remote_multiplier=4.0,
        node_of=lambda sid, b: sb.stripes[sid].node_of_block[b])
    rep_a = repair_failed_nodes(sa, [node], options=RepairOptions(placement=cheap))
    # shard 0 gathers everything (span 1) but half the nodes are shard 1:
    # those reads are remote and 4x as expensive in simulated time
    rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(placement=costly))
    assert rep_a.remote_reads == 0 and rep_b.remote_reads > 0
    assert rep_b.sim_seconds > rep_a.sim_seconds * 1.5
    assert rep_a.blocks_read == rep_b.blocks_read
    assert _all_blocks(sa) == _all_blocks(sb)


def test_store_level_placement_attribute(tmp_path):
    """A store-level PlacementMap is the repair default (no per-call arg)."""
    store = _build(tmp_path / "s", stripes=20)
    store.placement = PlacementMap.from_store(store, num_shards=2,
                                              remote_multiplier=2.0)
    node = store.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(store, [node])
    assert rep.remote_reads > 0          # half the nodes live off-shard-0


@multidevice
def test_sharded_gather_repair_bit_identical(tmp_path):
    """The tentpole acceptance: per-shard gather + pre-sharded launch on 8
    devices produces bit-identical blocks to the single-host path, on both
    the synchronous and pipelined routes, with balanced per-shard bytes."""
    sa = _build(tmp_path / "a")                      # sharded, pipelined
    sb = _build(tmp_path / "b")                      # sharded, sync
    sc = _build(tmp_path / "c")                      # unsharded reference
    node = sa.stripes[0].node_of_block[0]
    with with_rules(_mesh()):
        rep_a = repair_failed_nodes(sa, [node], options=RepairOptions(pipeline=True))
        rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False))
    rep_c = repair_failed_nodes(sc, [node], options=RepairOptions(pipeline=False))
    assert rep_a.devices == rep_b.devices == 8
    assert rep_c.devices == 1
    truth = _all_blocks(sc)
    assert _all_blocks(sa) == truth
    assert _all_blocks(sb) == truth
    # same disk traffic; gather bytes split evenly across the 8 shards
    assert rep_a.blocks_read == rep_b.blocks_read == rep_c.blocks_read
    for rep in (rep_a, rep_b):
        assert len(rep.gather_bytes_per_shard) == 8
        lo, hi = (min(rep.gather_bytes_per_shard.values()),
                  max(rep.gather_bytes_per_shard.values()))
        assert lo == hi                 # perfectly balanced pattern groups
        assert sum(rep.gather_bytes_per_shard.values()) == rep.bytes_read
        assert rep.local_reads + rep.remote_reads == rep.blocks_read
    # derived 8-shard placement over round-robin nodes: mostly remote
    assert rep_a.local_read_fraction < 0.5
    assert rep_c.local_read_fraction == 1.0


@multidevice
def test_sharded_gather_sim_time_unchanged_at_unity_multiplier(tmp_path):
    """With the default multiplier (1.0) sharding changes data movement,
    never the simulated link model: sim_seconds matches unsharded."""
    sa = _build(tmp_path / "a")
    sb = _build(tmp_path / "b")
    node = sa.stripes[0].node_of_block[0]
    with with_rules(_mesh()):
        rep = repair_failed_nodes(sa, [node], options=RepairOptions(pipeline=True))
    rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False))
    assert rep.sim_seconds == pytest.approx(rep_b.sim_seconds)


@multidevice
def test_ragged_window_degrades_to_single_shard_gather(tmp_path):
    """Pattern groups the span does not divide fall back to the one-buffer
    gather (shard 0) and stay bit-identical."""
    sa = _build(tmp_path / "a", stripes=50, batch_stripes=5)
    sb = _build(tmp_path / "b", stripes=50, batch_stripes=5)
    node = sa.stripes[0].node_of_block[0]
    with with_rules(_mesh()):
        rep = repair_failed_nodes(sa, [node], options=RepairOptions(pipeline=True))
    assert rep.devices == 1              # every 5-stripe window degraded
    assert set(rep.gather_bytes_per_shard) == {0}
    rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False))
    assert _all_blocks(sa) == _all_blocks(sb)
    assert rep.blocks_read == rep_b.blocks_read
