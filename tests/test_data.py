"""Data pipeline: file mode, host sharding, frontend extras."""
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, make_pipeline


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8, seed=1)
    full = make_pipeline(cfg).batch_at(3)["tokens"]
    parts = [make_pipeline(cfg, process_index=i, process_count=4).batch_at(3)
             for i in range(4)]
    assert all(p["tokens"].shape == (2, 8) for p in parts)


def test_file_mode(tmp_path):
    tokens = np.arange(10_000, dtype=np.uint32)
    path = tmp_path / "toks.bin"
    tokens.tofile(path)
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=0,
                     kind="file", path=str(path))
    batch = make_pipeline(cfg).batch_at(0)
    assert batch["tokens"].shape == (4, 16)
    # labels are next-token shifted views of the same window
    assert (batch["labels"][:, :-1] == batch["tokens"][:, 1:]).all()


def test_frontend_extras():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=1,
                     frontend="patches", frontend_tokens=4, d_model=16)
    b = make_pipeline(cfg).batch_at(0)
    assert b["prefix_embeds"].shape == (2, 4, 16)
    cfg2 = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=1,
                      frontend="frames", d_model=16)
    b2 = make_pipeline(cfg2).batch_at(0)
    assert b2["frames"].shape == (2, 8, 16)
