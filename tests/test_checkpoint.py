"""Asynchronous sharded checkpointing (DESIGN.md §13).

Save: snapshot isolation, async/sync and pipelined/serial bit-identity,
crash-mid-save atomicity (previous checkpoint always restorable, orphaned
staging dirs swept), retention under interleaved async saves. Restore:
parallel gather byte-parity with the serial path after host loss, across
schemes × kernel backends; the sharded-mesh case runs in the forced-
8-device CI leg (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import hashlib
import json

import jax
import numpy as np
import pytest

from repro.ftx import (CheckpointConfig, CheckpointManager, StoreConfig,
                       StripeStore)
from repro.ftx.pipeline import EncodePipeline

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((96, 64)).astype(np.float32),
            "opt": {"m": rng.standard_normal(257).astype(np.float64),
                    "v": rng.integers(0, 255, 1000, np.uint8)},
            "step": np.int64(41)}


def _cfg(scheme="cp-azure", backend=None, **kw):
    over = {} if backend is None else {"backend": backend}
    return CheckpointConfig(
        store=StoreConfig(scheme=scheme, k=6, r=2, p=2, block_size=2048,
                          **over),
        encode_window=2, **kw)


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _disk_blocks(step_dir):
    return {p.relative_to(step_dir).as_posix():
            hashlib.sha256(p.read_bytes()).hexdigest()
            for p in sorted(step_dir.rglob("*.blk"))}


# ------------------------------------------------------------ save identity

def test_async_sync_and_serial_saves_bit_identical(tmp_path):
    state = _state()
    roots = {}
    for name, submit in (
            ("sync", lambda cm: cm.save(5, state)),
            ("async", lambda cm: cm.save_async(5, state).result()),
            ("serial", lambda cm: cm.save_async(5, state,
                                                pipelined=False).result())):
        cm = CheckpointManager(tmp_path / name, _cfg())
        info = submit(cm)
        assert info["step"] == 5 and info["stripes"] > 0
        roots[name] = tmp_path / name / "step5"
    ref = _disk_blocks(roots["sync"])
    assert ref and _disk_blocks(roots["async"]) == ref
    assert _disk_blocks(roots["serial"]) == ref
    ref_manifest = json.loads((roots["sync"] / "manifest.json").read_text())
    for name in ("async", "serial"):
        m = json.loads((roots[name] / "manifest.json").read_text())
        assert m["objects"] == ref_manifest["objects"]
        assert m["stripes"] == ref_manifest["stripes"]


def test_streamed_object_bit_identical_to_put(tmp_path):
    """The streaming put path registers exactly what put+seal would have."""
    payload = np.random.default_rng(5).integers(
        0, 256, 6 * 2048 * 3 + 777, dtype=np.uint8)
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=2048)
    packed = StripeStore(tmp_path / "packed", cfg)
    packed.put("state", payload.tobytes())
    packed.seal()
    streamed = StripeStore(tmp_path / "streamed", cfg)
    stream = streamed.stream_writer("state", len(payload))
    EncodePipeline(streamed, window=2).run(stream, payload)
    stream.close()
    assert _disk_blocks(tmp_path / "streamed") == \
        _disk_blocks(tmp_path / "packed")
    assert streamed.objects.keys() == packed.objects.keys()
    for k in packed.objects:
        assert streamed.objects[k] == packed.objects[k]
    assert np.array_equal(streamed.get("state"), payload)


def test_stream_writer_contract(tmp_path):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=1024)
    store = StripeStore(tmp_path / "s", cfg)
    stream = store.stream_writer("obj", 3 * 6 * 1024)
    assert stream.num_stripes == 3
    with pytest.raises(ValueError):           # not (S, n, B)
        stream.write_window(0, np.zeros((1, store.n, 512), np.uint8))
    with pytest.raises(ValueError):           # out of range
        stream.write_window(3, np.zeros((1, store.n, 1024), np.uint8))
    with pytest.raises(RuntimeError):         # unwritten stripes
        stream.close()
    allocated = set(store.stripes)
    stream.abort()
    assert not (allocated & set(store.stripes))
    # an open (put-buffered) stripe blocks streaming
    store.put("x", b"abc")
    with pytest.raises(RuntimeError):
        store.stream_writer("y", 10)


def test_snapshot_isolation(tmp_path):
    state = _state()
    want = jax.tree.map(lambda x: np.copy(x), state)
    cm = CheckpointManager(tmp_path, _cfg())
    fut = cm.save_async(3, state)
    # Mutating the live state after save_async returns must not leak into
    # the checkpoint: the snapshot was taken before the call returned.
    state["w"][:] = -1.0
    state["opt"]["m"][:] = 0.0
    state["opt"]["v"][:] = 0
    fut.result()
    got, _ = cm.restore(3, state)
    _assert_tree_equal(got, want)


def test_snapshot_for_checkpoint_copies(tmp_path):
    from repro.train.train_step import snapshot_for_checkpoint

    state = _state()
    snap = snapshot_for_checkpoint(state)
    state["w"][:] = 0.0
    assert not np.array_equal(snap["w"], state["w"])
    cm = CheckpointManager(tmp_path, _cfg())
    cm.save(1, snap)
    got, _ = cm.restore(1, snap)
    _assert_tree_equal(got, snap)


# ------------------------------------------------- degraded restore parity

@pytest.mark.parametrize("scheme", ["cp-azure", "cp-uniform"])
@pytest.mark.parametrize("backend", ["gf", "crs"])
def test_restore_after_host_loss_parity(tmp_path, scheme, backend):
    state = _state(seed=3)
    cm = CheckpointManager(tmp_path, _cfg(scheme=scheme, backend=backend))
    cm.save(7, state)
    cm.fail_hosts(7, [1, 2])
    par, tele = cm.restore(7, state)
    ser, _ = cm.restore(7, state, parallel=False)
    _assert_tree_equal(par, ser)
    _assert_tree_equal(par, state)
    assert tele["parallel"] and tele["degraded_blocks"] > 0
    assert tele["restore_decode_launches"] > 0
    # live data sources come from the restore buffer: only the plans'
    # extra (parity) sources hit disk on top of the healthy gather
    assert tele["extra_source_reads"] < tele["blocks_read"]


def test_healthy_parallel_restore_reads_each_needed_block_once(tmp_path):
    state = _state(seed=4)
    cm = CheckpointManager(tmp_path, _cfg())
    info = cm.save(9, state)
    store = cm.store_for(9)
    before = store.telemetry.copy()
    got, tele = cm.restore(9, state)
    _assert_tree_equal(got, state)
    assert tele["degraded_blocks"] == 0
    assert store.telemetry.bytes_read - before.bytes_read == info["bytes"]
    k, B = cm.cfg.store.k, cm.cfg.store.block_size
    assert tele["blocks_read"] == -(-info["bytes"] // B) <= \
        info["stripes"] * k


@multidevice
def test_restore_after_host_loss_parity_sharded(tmp_path):
    from repro.dist.sharding import with_rules

    state = _state(seed=6)
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    cm = CheckpointManager(tmp_path, _cfg())
    with with_rules(mesh):
        info = cm.save(2, state)          # sharded encode launches
        assert info["encode"]["windows"] > 0
        cm.fail_hosts(2, [0, 3])
        par, tele = cm.restore(2, state)
        ser, _ = cm.restore(2, state, parallel=False)
    _assert_tree_equal(par, ser)
    _assert_tree_equal(par, state)
    assert tele["degraded_blocks"] > 0


# ------------------------------------------------------- crash consistency

def test_crash_mid_save_preserves_previous_checkpoint(tmp_path):
    state = _state()
    cm = CheckpointManager(tmp_path, _cfg())
    cm.save(1, state)

    def boom(stage, index):
        if stage == "drain" and index >= 1:
            raise RuntimeError("disk died mid-save")

    fut = cm.save_async(2, _state(seed=9), hook=boom)
    err = fut.exception()
    assert isinstance(err, RuntimeError)
    with pytest.raises(RuntimeError):
        fut.result()
    # the failed save left nothing: no step2, no staging dir
    assert cm.available() == [1]
    assert not (tmp_path / "step2.tmp").exists()
    assert not (tmp_path / "step2").exists()
    got, _ = cm.restore(1, state)
    _assert_tree_equal(got, state)
    # the manager recovers: the next save of the same step succeeds
    cm.save(2, state)
    assert cm.available() == [1, 2]


def test_init_sweeps_orphaned_save_debris(tmp_path):
    state = _state()
    cm = CheckpointManager(tmp_path, _cfg())
    cm.save(4, state)
    # simulate a hard crash: a staging dir and a meta-less step dir
    (tmp_path / "step9.tmp" / "node0").mkdir(parents=True)
    (tmp_path / "step9.tmp" / "node0" / "s0_b0.blk").write_bytes(b"junk")
    (tmp_path / "step7").mkdir()
    (tmp_path / "step7" / "manifest.json").write_text("{}")
    cm2 = CheckpointManager(tmp_path, _cfg())
    assert cm2.available() == [4]
    assert not (tmp_path / "step9.tmp").exists()
    assert not (tmp_path / "step7").exists()
    got, _ = cm2.restore(4, state)
    _assert_tree_equal(got, state)


def test_retention_under_interleaved_async_saves(tmp_path):
    state = _state()
    cm = CheckpointManager(tmp_path, _cfg(keep=2))
    futs = [cm.save_async(step, state) for step in (1, 2, 3, 4, 5)]
    infos = [f.result() for f in futs]
    assert [i["step"] for i in infos] == [1, 2, 3, 4, 5]
    assert cm.available() == [4, 5]
    assert sorted(p.name for p in tmp_path.glob("step*")) == \
        ["step4", "step5"]
    got, _ = cm.restore(5, state)
    _assert_tree_equal(got, state)


def test_available_ignores_junk_entries(tmp_path):
    cm = CheckpointManager(tmp_path, _cfg())
    cm.save(11, _state())
    (tmp_path / "stepXYZ").mkdir()          # junk that is not a checkpoint
    (tmp_path / "step12.tmp").mkdir()
    assert cm.available() == [11]


def test_encode_telemetry_shape(tmp_path):
    cm = CheckpointManager(tmp_path, _cfg())
    info = cm.save(1, _state())
    enc = info["encode"]
    assert enc["windows"] >= 2 and enc["launches"] == enc["windows"]
    assert 0.0 <= enc["overlap_fraction"] <= 1.0
    assert info["snapshot_seconds"] < info["encode_seconds"]
