import os

# Keep the test suite on the host's device topology — the 512-device
# dry-run flag is set only when repro.launch.dryrun runs as __main__, and
# the multi-device CI leg opts in via XLA_FLAGS in the environment.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
