import os

# Keep the test suite on the host's real device topology (1 CPU device) —
# the 512-device dry-run flag is set ONLY inside repro.launch.dryrun.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
