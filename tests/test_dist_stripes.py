"""Stripe-axis device sharding: bit-identity with the single-device path.

The 1-device cases always run (degradation must be a clean no-op); the
multi-device cases run in the forced-8-device CI leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.engine import BatchedCodecEngine
from repro.core.schemes import make_scheme
from repro.dist.sharding import with_rules
from repro.dist.stripes import stripe_span, stripe_spec

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh():
    return jax.make_mesh((8, 1), ("data", "model"))


def _stripes(scheme, S, B, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, (S, scheme.k, B), dtype=np.uint8)
    engine = BatchedCodecEngine(scheme, backend="ref")
    return data, np.asarray(engine.encode(data)), engine


# ------------------------------------------------------------- resolution
def test_stripe_spec_degrades_on_trivial_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with with_rules(mesh) as mr:
        assert stripe_spec((32, 8, 1024), mr) == P("data", None, None)
        assert stripe_span((32, 8, 1024), mr) == 1
    assert stripe_span((32, 8, 1024), None) == 1


def test_engine_without_rules_unchanged():
    scheme = make_scheme("cp-azure", 6, 2, 2)
    data, stripes, engine = _stripes(scheme, 4, 256)
    assert engine.last_span == 1
    out, _ = engine.repair_single(0, {i: stripes[:, i, :]
                                      for i in range(1, scheme.n)})
    assert engine.last_span == 1
    assert (np.asarray(out) == stripes[:, 0, :]).all()


@multidevice
def test_stripe_spec_resolves_to_data_axis():
    with with_rules(_mesh()) as mr:
        assert stripe_spec((32, 8, 1024), mr) == P("data", None, None)
        assert stripe_span((32, 8, 1024), mr) == 8
        # indivisible S degrades to a single-device launch
        assert stripe_spec((13, 8, 1024), mr) == P(None, None, None)
        assert stripe_span((13, 8, 1024), mr) == 1


# ------------------------------------------------------------ bit-identity
@multidevice
@pytest.mark.parametrize("backend", ["ref", "gf", "crs"])
def test_sharded_repair_bit_identical(backend):
    """Sharded encode/repair/decode == single-device, bit for bit."""
    scheme = make_scheme("cp-azure", 8, 2, 2)
    data, stripes, plain = _stripes(scheme, 32, 1024)
    with with_rules(_mesh()) as mr:
        sharded = BatchedCodecEngine(scheme, backend=backend, mesh_rules=mr)
        assert (np.asarray(sharded.encode(data)) == stripes).all()
        assert sharded.last_span == 8

        avail = {i: stripes[:, i, :] for i in range(scheme.n)
                 if i not in (0, scheme.k)}
        want, _ = plain.repair_multi({0, scheme.k}, avail)
        got, _ = sharded.repair_multi({0, scheme.k}, avail)
        assert sharded.last_span == 8
        for b in (0, scheme.k):
            assert (np.asarray(want[b]) == np.asarray(got[b])).all()

        # drop data block 0; its local parity (block k) stands in
        ids = list(range(1, scheme.k)) + [scheme.k]
        dec = sharded.decode({i: stripes[:, i, :] for i in ids})
        assert (np.asarray(dec) == data).all()


@multidevice
def test_sharded_pallas_kernel_lockstep():
    """The batched-grid Pallas kernel itself runs under shard_map — the
    path real TPUs take (no CPU table fallback) — in lockstep with the
    table oracle."""
    from repro.kernels.ops import gf_matmul_batch_op

    rng = np.random.default_rng(1)
    coef = rng.integers(0, 256, (3, 5), dtype=np.uint8)
    data = rng.integers(0, 256, (16, 5, 256), dtype=np.uint8)
    with with_rules(_mesh()) as mr:
        want = np.asarray(gf_matmul_batch_op(coef, data, backend="ref"))
        got = np.asarray(gf_matmul_batch_op(coef, data, backend="gf",
                                            force_pallas=True, mesh_rules=mr))
    assert (want == got).all()


@multidevice
def test_sharded_repair_ragged_batch_degrades_bit_identical():
    """S=13 (indivisible by 8) silently runs single-device, same bits."""
    scheme = make_scheme("cp-azure", 6, 2, 2)
    data, stripes, plain = _stripes(scheme, 13, 512)
    with with_rules(_mesh()) as mr:
        sharded = BatchedCodecEngine(scheme, backend="ref", mesh_rules=mr)
        out, _ = sharded.repair_single(
            0, {i: stripes[:, i, :] for i in range(1, scheme.n)})
        assert sharded.last_span == 1
        assert (np.asarray(out) == stripes[:, 0, :]).all()


def _filled_store(root, *, stripes=80, block_size=1024, batch_stripes=8):
    """A store with exactly ``stripes`` sealed stripes (one spanning object).

    Round-robin placement cycles every ``n`` stripes, so one failed node
    yields ``n`` distinct failure patterns with ``stripes/n`` members each —
    sized here so every pattern group is divisible across 8 devices.
    """
    from repro.ftx import StoreConfig, StripeStore

    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2,
                      block_size=block_size, batch_stripes=batch_stripes)
    store = StripeStore(root, cfg)
    extent = cfg.k * cfg.block_size
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * extent, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


@multidevice
def test_store_sharded_repair_bit_identical_with_telemetry(tmp_path):
    """Fleet repair with mesh context: same disk bytes as unsharded, and
    telemetry reports per-device launch counts."""
    from repro.ftx import RepairOptions, repair_failed_nodes

    sa = _filled_store(tmp_path / "a")
    sb = _filled_store(tmp_path / "b")
    node = sa.stripes[0].node_of_block[0]

    with with_rules(_mesh()) as mr:
        rep = repair_failed_nodes(sa, [node], options=RepairOptions(mesh_rules=mr))
    assert rep.stripes_repaired > 0
    assert rep.devices == 8
    # every pattern group is an 8-stripe chunk -> every launch spans 8 devices
    assert rep.device_launches == 8 * rep.launches

    rep_b = repair_failed_nodes(sb, [node])
    assert rep_b.devices == 1
    assert rep_b.device_launches == rep_b.launches

    for sid in sa.stripes:
        for b in range(sa.scheme.n):
            assert sa._block_path(sid, b).read_bytes() == \
                sb._block_path(sid, b).read_bytes(), (sid, b)


@multidevice
def test_store_ambient_rules_picked_up(tmp_path):
    """repair_all with no explicit mesh_rules uses the ambient context."""
    store = _filled_store(tmp_path / "s", block_size=512)
    store.fail_node(store.stripes[0].node_of_block[0])
    with with_rules(_mesh()):
        tele = store.repair_all()
    assert tele["devices"] == 8
    assert tele["device_launches"] == 8 * tele["launches"]
