"""End-to-end system behaviour: train -> EC checkpoint -> kill hosts ->
repair-restore -> training continues bit-exactly."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_model
from repro.data.pipeline import DataConfig, make_pipeline
from repro.ftx.checkpoint import CheckpointConfig, CheckpointManager
from repro.ftx.stripestore import StoreConfig
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, make_train_step


def test_train_checkpoint_kill_restore_continue(tmp_path):
    api = get_model("qwen2.5-3b", smoke=True)
    cfg = api.cfg
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4, seed=0))
    tc = TrainConfig(opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                     decay_steps=20))
    step = jax.jit(make_train_step(api, tc))
    params = api.init_params(jax.random.key(0))
    opt = adamw_init(params)
    for i in range(5):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, opt, _ = step(params, opt, batch)

    cm = CheckpointManager(tmp_path, CheckpointConfig(store=StoreConfig(
        scheme="cp-azure", k=8, r=2, p=2, block_size=1 << 16)))
    cm.save(5, {"params": params, "opt": opt})

    # continue two more steps (the reference trajectory)
    ref_params, ref_opt = params, opt
    for i in (5, 6):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        ref_params, ref_opt, ref_m = step(ref_params, ref_opt, batch)

    # catastrophic: two hosts die; restore through CP-LRC repair
    cm.fail_hosts(5, [0, 3])
    state, tele = cm.restore(5, {"params": params, "opt": opt})
    assert tele["blocks_read"] > 0
    re_params = jax.tree.map(jnp.asarray, state["params"])
    re_opt = jax.tree.map(jnp.asarray, state["opt"])
    for i in (5, 6):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        re_params, re_opt, re_m = step(re_params, re_opt, batch)

    # recovered trajectory is bit-identical (deterministic pipeline + exact
    # byte-level restore)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(re_params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert float(ref_m["loss"]) == float(re_m["loss"])
