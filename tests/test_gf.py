"""GF(2^8) field properties + the paper's Appendix Theorem 1."""
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import gf
from repro.core.cauchy import (
    cauchy_matrix,
    theorem1_coefficients,
    uniform_combination_coefficients,
    vandermonde_matrix,
    verify_mds,
)

bytes_ = st.integers(0, 255)
nz_bytes = st.integers(1, 255)


@given(bytes_, bytes_, bytes_)
@settings(max_examples=200, deadline=None)
def test_field_axioms(a, b, c):
    mul, add = gf.gf_mul, lambda x, y: int(x) ^ int(y)
    assert mul(a, b) == mul(b, a)
    assert mul(a, mul(b, c)) == mul(mul(a, b), c)
    # distributivity
    assert int(mul(a, add(b, c))) == add(mul(a, b), mul(a, c))
    assert mul(a, 1) == a and mul(a, 0) == 0


@given(nz_bytes)
@settings(max_examples=100, deadline=None)
def test_inverse(a):
    assert gf.gf_mul(a, gf.gf_inv(a)) == 1


@given(st.integers(2, 20), st.integers(1, 8), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_matmul_matches_naive(m, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, k), dtype=np.uint8)
    b = rng.integers(0, 256, (k, 7), dtype=np.uint8)
    got = gf.gf_matmul(a, b)
    want = np.zeros((m, 7), np.uint8)
    for i in range(m):
        for j in range(7):
            acc = 0
            for t in range(k):
                acc ^= int(gf.gf_mul(a[i, t], b[t, j]))
            want[i, j] = acc
    assert (got == want).all()


@given(st.integers(2, 24), st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_matrix_inverse(n, seed):
    rng = np.random.default_rng(seed)
    for _ in range(10):
        m = rng.integers(0, 256, (n, n), dtype=np.uint8)
        if gf.gf_rank(m) == n:
            break
    else:
        pytest.skip("no invertible sample")
    inv = gf.gf_mat_inv(m)
    assert (gf.gf_matmul(m, inv) == np.eye(n, dtype=np.uint8)).all()


@given(st.integers(0, 255))
@settings(max_examples=60, deadline=None)
def test_bitmatrix_representation(c):
    """M_c applied to bits of v == bits of c*v, for all v (vectorized)."""
    m = gf.coeff_bitmatrix(c)
    v = np.arange(256, dtype=np.uint8)
    bits = (v[None, :] >> np.arange(8)[:, None]) & 1      # (8, 256)
    out_bits = (m @ bits) % 2
    got = np.zeros(256, np.uint8)
    for i in range(8):
        got |= (out_bits[i] << i).astype(np.uint8)
    assert (got == gf.gf_mul(c, v)).all()


def test_gf_solve_any_consistency(rng):
    for _ in range(20):
        a = rng.integers(0, 256, (6, 9), dtype=np.uint8)
        x0 = rng.integers(0, 256, 9, dtype=np.uint8)
        y = gf.gf_matvec(a, x0)
        x = gf.gf_solve_any(a, y)
        assert x is not None
        assert (gf.gf_matvec(a, x) == y).all()


@pytest.mark.parametrize("k,r", [(6, 2), (12, 2), (16, 3), (24, 2), (48, 4),
                                 (96, 5), (128, 4)])
def test_cauchy_mds(k, r):
    m = cauchy_matrix(k, r)
    assert (m != 0).all()
    assert verify_mds(m, trials=40)


@pytest.mark.parametrize("k,r", [(6, 2), (16, 3), (24, 2)])
def test_vandermonde_mds(k, r):
    assert verify_mds(vandermonde_matrix(k, r), trials=40)


@pytest.mark.parametrize("k,r", [(6, 2), (12, 2), (16, 3), (20, 3), (48, 4),
                                 (96, 5)])
def test_theorem1_identity(k, r):
    """gamma_bar_i + sum_j eta_bar_j alpha_ij == 0 (Appendix, Theorem 1)."""
    alpha = cauchy_matrix(k, r)
    gamma, eta = theorem1_coefficients(k, r)
    assert (gamma != 0).all() and (eta != 0).all()
    for i in range(k):
        acc = int(gamma[i])
        for j in range(r):
            acc ^= int(gf.gf_mul(eta[j], alpha[j, i]))
        assert acc == 0


@pytest.mark.parametrize("k,r", [(6, 2), (16, 3), (96, 5)])
def test_eq10_identity(k, r):
    """G_r == sum gamma_i D_i + sum eta_j G_j on random data (Eq. 10)."""
    rng = np.random.default_rng(1)
    alpha = cauchy_matrix(k, r)
    gamma, eta = uniform_combination_coefficients(k, r)
    data = rng.integers(0, 256, (k, 33), dtype=np.uint8)
    g = gf.gf_matmul(alpha, data)
    rhs = gf.gf_matmul(gamma.reshape(1, -1), data)[0]
    for j in range(r - 1):
        rhs ^= gf.gf_mul(eta[j], g[j])
    assert (rhs == g[r - 1]).all()
