"""Topology policies + locality-aware stripe scheduling: placement policy
geometry, the never-worse-than-contiguous scheduling property, bit-identity
of scheduled repair on 1- and 8-device meshes (sync and pipelined), the
telemetry that makes the uplift observable, and the docs/baseline CI
tooling that rides along.

The 1-device cases always run; the multi-device cases run in the
forced-8-device CI leg (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from _prop import given, settings, st
from repro.dist.placement import PlacementMap
from repro.dist.schedule import chunk_affinity, schedule_chunk
from repro.dist.sharding import with_rules
from repro.dist.topology import (POLICIES, Topology, place_stripe,
                                 placement_from_topology)
from repro.ftx import (RepairOptions, StoreConfig, StripeStore,
                       repair_failed_nodes)

REPO = Path(__file__).resolve().parent.parent

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(shape=(8, 1)):
    return jax.make_mesh(shape, ("data", "model"))


def _build(root, *, stripes=320, block_size=512, num_nodes=40, domains=8,
           policy="spread", batch_stripes=8, **kw):
    topo = Topology(num_nodes=num_nodes, num_domains=domains,
                    spread_width=2, seed=7)
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2,
                      block_size=block_size, batch_stripes=batch_stripes,
                      pipeline_window=batch_stripes, prefetch_threads=2,
                      placement_policy=policy, **kw)
    store = StripeStore(root, cfg, num_nodes=num_nodes, topology=topo)
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * cfg.k * block_size, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


def _all_blocks(store):
    return {(sid, b): store._block_path(sid, b).read_bytes()
            for sid in store.stripes for b in range(store.scheme.n)}


# --------------------------------------------------------------- topology
def test_topology_domains_are_contiguous_partition():
    topo = Topology(num_nodes=10, num_domains=3)
    doms = [topo.nodes_in(d) for d in range(3)]
    assert sorted(sum(doms, [])) == list(range(10))     # exact partition
    for d, nodes in enumerate(doms):
        assert nodes == sorted(nodes)
        assert all(topo.domain_of(n) == d for n in nodes)
    assert topo.shard_of_node() == tuple(topo.domain_of(i) for i in range(10))


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(num_nodes=0)
    with pytest.raises(ValueError):
        Topology(num_nodes=4, num_domains=5)
    with pytest.raises(ValueError):
        Topology(num_nodes=4, num_domains=2, spread_width=0)
    with pytest.raises(ValueError):
        place_stripe("contiguous", Topology(num_nodes=4), 0, 5)
    with pytest.raises(ValueError):
        place_stripe("bogus", Topology(num_nodes=16), 0, 4)


def test_contiguous_policy_matches_seed_arcs():
    """The default policy is exactly the seed store's stride-7 rotation."""
    topo = Topology(num_nodes=13)
    for sid in range(5):
        base = (sid * 7) % 13
        assert place_stripe("contiguous", topo, sid, 10) == \
            [(base + i) % 13 for i in range(10)]


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(list(POLICIES)), st.integers(0, 99),
       st.integers(1, 8), st.integers(0, 5))
def test_place_stripe_distinct_in_range_deterministic(policy, sid, domains,
                                                      seed):
    topo = Topology(num_nodes=24, num_domains=domains, spread_width=2,
                    seed=seed)
    nodes = place_stripe(policy, topo, sid, 10)
    assert len(nodes) == 10
    assert len(set(nodes)) == 10                       # distinct nodes
    assert all(0 <= n < 24 for n in nodes)
    assert nodes == place_stripe(policy, topo, sid, 10)  # pure function


def test_round_robin_disperses_across_domains():
    topo = Topology(num_nodes=24, num_domains=8)
    for sid in range(4):
        nodes = place_stripe("round_robin", topo, sid, 8)
        # one block per domain when n == D
        assert sorted(topo.domain_of(n) for n in nodes) == list(range(8))


def test_spread_concentrates_in_few_domains():
    topo = Topology(num_nodes=40, num_domains=8, spread_width=2, seed=1)
    for sid in range(8):
        nodes = place_stripe("spread", topo, sid, 10)
        assert len({topo.domain_of(n) for n in nodes}) <= 2
    # widened automatically when the chosen domains can't hold n blocks
    narrow = Topology(num_nodes=40, num_domains=20, spread_width=2, seed=1)
    nodes = place_stripe("spread", narrow, 0, 10)
    assert len(set(nodes)) == 10
    assert len({narrow.domain_of(n) for n in nodes}) >= 5


def test_placement_from_topology_tracks_store(tmp_path):
    store = _build(tmp_path / "s", stripes=10)
    topo = store.topology
    pm = placement_from_topology(store, topo)
    assert pm.num_shards == topo.num_domains
    assert pm.remote_multiplier == store.cfg.remote_read_multiplier
    node, shard = pm.locate(0, 0)
    assert node == store.stripes[0].node_of_block[0]
    assert shard == topo.domain_of(node)
    with pytest.raises(ValueError):
        placement_from_topology(store, Topology(num_nodes=store.num_nodes + 1))


def test_store_rejects_unknown_policy_and_schedule(tmp_path):
    with pytest.raises(ValueError):
        StripeStore(tmp_path / "a", StoreConfig(placement_policy="bogus"))
    with pytest.raises(ValueError):
        StripeStore(tmp_path / "b", StoreConfig(stripe_schedule="bogus"))
    store = StripeStore(tmp_path / "c", StoreConfig(k=6, r=2, p=2))
    with pytest.raises(ValueError):
        store.repair_all(options=RepairOptions(schedule="bogus"))


def test_store_topology_mismatch_raises(tmp_path):
    with pytest.raises(ValueError):
        StripeStore(tmp_path / "s", StoreConfig(k=6, r=2, p=2),
                    num_nodes=20, topology=Topology(num_nodes=30))


def test_manifest_roundtrip_keeps_policy_and_topology(tmp_path):
    store = _build(tmp_path / "s", stripes=10)
    store.save_manifest()
    loaded = StripeStore.load(tmp_path / "s")
    assert loaded.cfg.placement_policy == "spread"
    assert loaded.cfg.stripe_schedule == "global"
    assert loaded.stripes[3].node_of_block == store.stripes[3].node_of_block
    # the explicit topology round-trips: same domains, same num_nodes, and
    # new stripes keep placing under the original copyset policy/seed
    assert loaded.topology == store.topology
    assert loaded.num_nodes == store.num_nodes
    assert loaded.placement is not None
    assert loaded.placement.shard_of_node == store.topology.shard_of_node()
    payload = np.random.default_rng(5).integers(
        0, 256, store.cfg.k * store.cfg.block_size, dtype=np.uint8).tobytes()
    for s in (store, loaded):
        s.put("extra", payload)
        s.seal()
    new_sid = max(loaded.stripes)
    assert loaded.stripes[new_sid].node_of_block == \
        store.stripes[new_sid].node_of_block
    # a store without an explicit topology keeps the seed manifest shape
    plain = StripeStore(tmp_path / "p", StoreConfig(k=6, r=2, p=2))
    plain.save_manifest()
    assert StripeStore.load(tmp_path / "p").topology == plain.topology


# -------------------------------------------------------------- scheduler
def _fake_placement(num_nodes, shards, reads, sids, seed):
    """A synthetic PlacementMap: seeded random node->shard and block->node."""
    rng = np.random.default_rng(seed)
    shard_of = tuple(int(s) for s in rng.integers(0, shards, num_nodes))
    table = {(sid, b): int(rng.integers(num_nodes))
             for sid in sids for b in reads}
    return PlacementMap(shard_of_node=shard_of,
                        node_of=lambda sid, b: table[(sid, b)])


def test_schedule_chunk_identity_without_mesh_or_resolver():
    sids = list(range(8))
    reads = (0, 1, 2)
    pm = _fake_placement(16, 4, reads, sids, 0)
    cs = schedule_chunk(sids, reads, pm, None)          # no mesh: span 1
    assert cs.is_identity and cs.span == 1
    assert cs.sids == tuple(sids)
    assert cs.scheduled_local == cs.contiguous_local
    assert cs.total_reads == len(sids) * len(reads)
    blind = PlacementMap(shard_of_node=pm.shard_of_node)  # no node_of
    cs = schedule_chunk(sids, reads, blind, None)
    assert cs.is_identity and cs.total_reads == 0
    assert cs.scheduled_local_fraction == 1.0           # no prediction


@multidevice
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(2, 9),
       st.integers(0, 999))
def test_scheduler_never_below_contiguous(windows, num_reads, shards, seed):
    """The core property: over random placements, the scheduled order's
    predicted local count never drops below the contiguous order's, and
    the output is a true permutation of the input chunk."""
    with with_rules(_mesh()) as mr:
        sids = [100 + i for i in range(8 * windows)]
        reads = tuple(range(num_reads))
        pm = _fake_placement(32, shards, reads, sids, seed)
        cs = schedule_chunk(sids, reads, pm, mr)
        assert cs.span == 8
        assert sorted(cs.sids) == sorted(sids)          # permutation
        assert tuple(sids[i] for i in cs.order) == cs.sids
        assert cs.scheduled_local >= cs.contiguous_local
        assert cs.scheduled_local_fraction >= cs.contiguous_local_fraction
        # the prediction matches a recount under the affinity matrix
        a = chunk_affinity(cs.sids, reads, pm, cs.span)
        cap = len(sids) // cs.span
        assert cs.scheduled_local == sum(
            int(a[i, i // cap]) for i in range(len(sids)))


@multidevice
def test_schedule_chunk_indivisible_degrades():
    with with_rules(_mesh()) as mr:
        sids = list(range(13))                          # 8 does not divide
        reads = (0, 1)
        pm = _fake_placement(16, 4, reads, sids, 3)
        cs = schedule_chunk(sids, reads, pm, mr)
        assert cs.is_identity and cs.span == 1
        # degraded gathers attribute every read to shard 0
        local = sum(1 for sid in sids for b in reads
                    if pm.shard_of_node[pm.node_of(sid, b)] == 0)
        assert cs.scheduled_local == cs.contiguous_local == local


# ------------------------------------------------- store integration
def test_scheduled_repair_bit_identical_one_device(tmp_path):
    """Without a mesh the scheduler is inert (span 1): scheduled and
    unscheduled repairs are byte- and telemetry-identical."""
    sa = _build(tmp_path / "a", stripes=40)
    sb = _build(tmp_path / "b", stripes=40)
    node = sa.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(sa, [node], options=RepairOptions(schedule="locality"))
    rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(schedule="none"))
    assert rep.schedule == "locality" and rep_b.schedule == "none"
    assert rep.blocks_read == rep_b.blocks_read
    assert rep.scheduled_local_read_fraction == \
        pytest.approx(rep_b.scheduled_local_read_fraction)
    assert rep.schedule_uplift == 1.0
    assert _all_blocks(sa) == _all_blocks(sb)


def test_schedule_defaults_from_config(tmp_path):
    store = _build(tmp_path / "s", stripes=10, stripe_schedule="none")
    node = store.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(store, [node])
    assert rep.schedule == "none"
    rep = repair_failed_nodes(store, [node], options=RepairOptions(schedule="locality"))
    assert rep.schedule == "locality"


@multidevice
def test_scheduled_repair_bit_identical_and_uplifts_8dev(tmp_path):
    """The tentpole acceptance: on the skewed (spread/copyset) placement
    the scheduler's local-read fraction beats the contiguous assignment,
    with repair outputs bit-identical on both the sync and pipelined
    routes, and realized locality matching the scheduler's prediction."""
    sa = _build(tmp_path / "a")                      # scheduled, pipelined
    sb = _build(tmp_path / "b")                      # unscheduled, sync
    sc = _build(tmp_path / "c")                      # scheduled, sync
    node = sa.stripes[0].node_of_block[0]
    with with_rules(_mesh()):
        rep = repair_failed_nodes(
            sa, [node], options=RepairOptions(pipeline=True,
                                              schedule="locality"))
        rep_b = repair_failed_nodes(
            sb, [node], options=RepairOptions(pipeline=False,
                                              schedule="none"))
        rep_c = repair_failed_nodes(
            sc, [node], options=RepairOptions(pipeline=False,
                                              schedule="locality"))
    truth = _all_blocks(sb)
    assert _all_blocks(sa) == truth
    assert _all_blocks(sc) == truth
    assert rep.blocks_read == rep_b.blocks_read == rep_c.blocks_read
    # the scheduler moved reads onto owning shards — strictly better than
    # the contiguous assignment, on both routes, exactly as predicted
    for r in (rep, rep_c):
        assert r.local_read_fraction > rep_b.local_read_fraction
        assert r.schedule_uplift > 1.2
        assert r.local_read_fraction == \
            pytest.approx(r.scheduled_local_read_fraction)
        assert r.scheduled_local_read_fraction > \
            r.contiguous_local_read_fraction
    # the unscheduled run realizes its contiguous prediction
    assert rep_b.local_read_fraction == \
        pytest.approx(rep_b.scheduled_local_read_fraction)
    assert rep_b.schedule_uplift == 1.0


@multidevice
def test_degenerate_placement_keeps_contiguous_order(tmp_path):
    """When every stripe of a group lives on the same nodes (the seed
    store's arcs with num_nodes == n), affinity is flat and the scheduler
    must keep the identity assignment — uplift exactly 1.0."""
    def build(root):
        cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=512,
                          batch_stripes=8, pipeline_window=8,
                          prefetch_threads=2)
        store = StripeStore(root, cfg)
        payload = np.random.default_rng(3).integers(
            0, 256, 80 * cfg.k * 512, dtype=np.uint8)
        store.put("blob", payload.tobytes())
        store.seal()
        return store

    sa, sb = build(tmp_path / "a"), build(tmp_path / "b")
    node = sa.stripes[0].node_of_block[0]
    with with_rules(_mesh()):
        rep = repair_failed_nodes(sa, [node], options=RepairOptions(schedule="locality"))
        rep_b = repair_failed_nodes(sb, [node], options=RepairOptions(schedule="none"))
    assert rep.schedule_uplift == 1.0
    assert rep.local_read_fraction == rep_b.local_read_fraction
    assert _all_blocks(sa) == _all_blocks(sb)


@multidevice
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 9), st.booleans())
def test_property_scheduled_repair_bit_identical(block_idx, pipelined):
    """Any failed node, any policy route: the scheduled permutation never
    changes bytes (write-back is keyed by sid)."""
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        sa = _build(Path(tmp) / "a", stripes=80)
        sb = _build(Path(tmp) / "b", stripes=80)
        node = sa.stripes[0].node_of_block[block_idx]
        with with_rules(_mesh()):
            repair_failed_nodes(
                sa, [node], options=RepairOptions(pipeline=pipelined,
                                                  schedule="locality"))
        repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False, schedule="none"))
        assert _all_blocks(sa) == _all_blocks(sb)


# ------------------------------------------------------- CI plumbing
def test_check_docs_passes_on_current_tree():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-m", "benchmarks.check_docs"],
                          cwd=REPO, env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "docs consistent" in proc.stdout


def test_check_docs_table_parser():
    from benchmarks.check_docs import table_sections

    text = ("| section | paper |\n|---|---|\n"
            "| `alpha_one` | Fig 1 |\n| `beta_two`   | Fig 2 |\n"
            "not a | `row` |\n")
    assert table_sections(text) == ["alpha_one", "beta_two"]


def test_update_baseline_reports_merged_vs_reseeded(tmp_path, capsys):
    """--update-baseline must say which sections it re-seeded vs merged,
    so baseline bumps are auditable in CI logs."""
    from benchmarks.check_regression import main

    results = tmp_path / "results"
    results.mkdir()
    baseline = tmp_path / "baseline.json"
    (results / "stripe_schedule.json").write_text(json.dumps({
        "min_local_uplift": 2.0, "min_scheduled_local_fraction": 0.3}))
    (results / "sharded_gather.json").write_text(json.dumps({
        "gather_speedup_at_max_devices": 3.0, "min_shard_balance": 1.0}))
    common = ["--results", str(results), "--baseline", str(baseline)]
    assert main(["--update-baseline", *common,
                 "--sections", "stripe_schedule,sharded_gather"]) == 0
    out = capsys.readouterr().out
    assert "newly added: sharded_gather, stripe_schedule" in out
    assert "re-seeded from current results: -" in out
    # second pass re-seeds one section and must report the other as kept
    assert main(["--update-baseline", *common,
                 "--sections", "stripe_schedule"]) == 0
    out = capsys.readouterr().out
    assert "re-seeded from current results: stripe_schedule" in out
    assert "kept (merged from old baseline): sharded_gather" in out
    kept = json.loads(baseline.read_text())["sections"]
    assert set(kept) == {"stripe_schedule", "sharded_gather"}


def test_update_baseline_refuses_to_drop_gated_metric(tmp_path, capsys):
    """--update-baseline must exit non-zero when a re-seeded section no
    longer produces a metric its old baseline gated — a benchmark rename
    must not silently delete a CI floor."""
    from benchmarks.check_regression import main

    results = tmp_path / "results"
    results.mkdir()
    baseline = tmp_path / "baseline.json"
    (results / "stripe_schedule.json").write_text(json.dumps({
        "min_local_uplift": 2.0, "min_scheduled_local_fraction": 0.3}))
    baseline.write_text(json.dumps({"tolerance": 0.3, "sections": {
        "stripe_schedule": {"min_local_uplift": 2.0,
                            "min_scheduled_local_fraction": 0.3,
                            "retired_metric": 1.0}}}))
    before = baseline.read_text()
    assert main(["--update-baseline", "--results", str(results),
                 "--baseline", str(baseline),
                 "--sections", "stripe_schedule"]) == 1
    err = capsys.readouterr().err
    assert "stripe_schedule/retired_metric" in err
    assert baseline.read_text() == before       # baseline left untouched
