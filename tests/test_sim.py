"""Event-driven fleet reliability simulator (repro.sim)."""
import dataclasses

import numpy as np
import pytest

from repro.core.reliability import (HOURS_PER_YEAR, ReliabilityParams,
                                    stripe_mttdl_years)
from repro.core.schemes import make_scheme
from repro.dist.topology import Topology
from repro.sim import (BitSource, SimParams, StripeModel, UnitHierarchy,
                       calibrated, measured_bandwidth, simulate,
                       simulate_oracle, weibull_scale)
from repro.sim.rng import exp_hours, weibull_hours

# Accelerated single-failure-mode environment: azure(4,2,1) has every
# pattern up to p+r decodable (q = [0,0,0,0,1]), so the Markov chain is
# exact and paper == strict — the cross-validation configuration.
_REL = ReliabilityParams(node_mttf_years=0.02, bandwidth_gbps=0.002,
                         detect_hours_single=2.0, detect_hours_multi=10.0)


def _params(**over) -> SimParams:
    base = dict(disk_mttf_hours=_REL.node_mttf_years * HOURS_PER_YEAR,
                weibull_shape=1.0, model="paper", cost_model="average",
                reliability=_REL)
    base.update(over)
    return SimParams(**base)


# --------------------------------------------------------------- rng layer

def test_bits_batch_matches_scalar_and_padding():
    src = BitSource(seed=42)
    triples = np.array([[0, 0, 0], [0, 0, 1], [3, 7, 2], [9, 1, 0],
                        [2, 2, 2]], np.uint32)  # 5 rows -> padded to 8
    batch = src.bits(triples)
    for row, got in zip(triples, batch):
        assert src.bit1(*map(int, row)) == got
    # distinct triples give distinct draws (overwhelmingly)
    assert len(set(batch.tolist())) == len(batch)
    assert src.bits(np.zeros((0, 3), np.uint32)).size == 0


def test_weibull_shape_one_is_exponential():
    bits = BitSource(0).bits(np.stack([np.zeros(64, np.uint32),
                                       np.zeros(64, np.uint32),
                                       np.arange(64, dtype=np.uint32)],
                                      axis=1))
    assert np.array_equal(exp_hours(bits, 100.0),
                          weibull_hours(bits, weibull_scale(100.0, 1.0),
                                        1.0))


def test_weibull_scale_mean():
    # shape 2: scale = mean / Gamma(1.5)
    from math import gamma
    assert weibull_scale(100.0, 2.0) == pytest.approx(100.0 / gamma(1.5))


# --------------------------------------------------------------- hierarchy

def test_hierarchy_default_and_streams():
    h = UnitHierarchy.from_topology(10)
    assert h.num_disks == 10 and h.num_nodes == 10 and h.num_racks == 1
    streams = ([h.stream_disk_fail(d) for d in range(10)]
               + [h.stream_node_fail(i) for i in range(h.num_nodes)]
               + [h.stream_rack_fail(j) for j in range(h.num_racks)]
               + [h.stream_lse(d) for d in range(10)] + [h.stream_repair])
    assert len(set(streams)) == len(streams)  # no stream collisions


def test_hierarchy_from_topology_policies():
    topo = Topology(num_nodes=12, num_domains=3)
    for policy in ("contiguous", "spread", "round_robin"):
        h = UnitHierarchy.from_topology(8, topo, policy)
        assert h.num_disks == 8
        for node in range(h.num_nodes):
            for d in h.disks_of_node(node):
                assert h.node_of_disk[d] == node
        covered = sorted(d for j in range(h.num_racks)
                         for d in h.disks_of_rack(j))
        assert covered == list(range(8))


def test_stripe_model_memoizes_and_prices():
    sch = make_scheme("cp-azure", 6, 2, 2)
    m = StripeModel(sch, _params(cost_model="planner"))
    assert m.decodable(frozenset()) and m.decodable(frozenset({0}))
    assert not m.decodable(frozenset(range(sch.p + sch.r + 1)))
    one = m.cost_blocks(frozenset({0}))
    assert one >= 1 and m.cost_blocks(frozenset({0})) == one  # cached
    assert m.tau_hours(frozenset({0})) > 0
    avg = StripeModel(sch, _params())
    assert avg.cost_blocks(frozenset({3})) == avg.cost_blocks(
        frozenset({1}))  # average mode prices by failure count only


def test_sim_params_validation():
    with pytest.raises(ValueError):
        _params(model="bogus")
    with pytest.raises(ValueError):
        _params(cost_model="exact")
    with pytest.raises(ValueError):
        _params(weibull_shape=0.0)


# ------------------------------------------------- engine vs oracle parity

def test_engine_bit_identical_to_oracle_all_processes():
    """The acceptance bar: batched epochs == pure-Python event loop, bit
    for bit, with bursts, latent errors, scrubbing, Weibull lifetimes and
    planner repair costs all switched on."""
    sch = make_scheme("azure", 4, 2, 1)
    topo = Topology(num_nodes=8, num_domains=2)
    hier = UnitHierarchy.from_topology(sch.n, topo, "spread")
    params = _params(disk_mttf_hours=400.0, weibull_shape=1.4,
                     node_burst_hours=900.0, rack_burst_hours=4000.0,
                     lse_hours=700.0, scrub_hours=300.0, model="strict",
                     cost_model="planner")
    kw = dict(trials=6, horizon_hours=5000.0, seed=3, hierarchy=hier,
              record_events=True)
    a = simulate(sch, params, **kw)
    b = simulate_oracle(sch, params, **kw)
    assert a.counts == b.counts
    assert a.observed_hours == b.observed_hours
    assert sorted(a.loss_times) == sorted(b.loss_times)
    for log_a, log_b in zip(a.event_log, b.event_log):
        assert log_a == log_b
    assert a.events == b.events
    assert a.epochs < a.events  # the engine actually batched


def test_engine_bit_identical_paper_model_with_thinning():
    sch = make_scheme("azure", 6, 2, 1)  # q[3] > 0: thinning can trigger
    params = _params(disk_mttf_hours=100.0)
    kw = dict(trials=40, horizon_hours=6000.0, seed=3, record_events=True)
    a = simulate(sch, params, **kw)
    b = simulate_oracle(sch, params, **kw)
    assert a.rejected == b.rejected > 0
    assert a.counts == b.counts
    for log_a, log_b in zip(a.event_log, b.event_log):
        assert log_a == log_b


def test_determinism_and_seed_sensitivity():
    sch = make_scheme("azure", 4, 2, 1)
    kw = dict(trials=20, horizon_hours=3000.0, record_events=True)
    a = simulate(sch, _params(), seed=7, **kw)
    b = simulate(sch, _params(), seed=7, **kw)
    c = simulate(sch, _params(), seed=8, **kw)
    assert a.event_log == b.event_log
    assert a.observed_hours == b.observed_hours
    assert a.event_log != c.event_log


# ------------------------------------------------- closed-form validation

def test_simulated_mttdl_matches_markov_chain():
    """Property the tentpole promises: on the calibrated single-failure-
    mode config the simulator reproduces core/reliability.py's closed-form
    MTTDL (exponential-MLE estimate, seeded, CI-stable tolerance)."""
    sch = make_scheme("azure", 4, 2, 1)
    chain = stripe_mttdl_years(sch, _REL, model="paper")
    res = simulate(sch, _params(), trials=800, horizon_hours=8000.0,
                   seed=11)
    assert res.losses > 300  # enough losses for a tight MLE
    ratio = res.mttdl_years / chain
    assert 0.80 < ratio < 1.25
    # paper == strict on this config (no undecodable pattern below p+r+1)
    strict = simulate(sch, _params(model="strict"), trials=100,
                      horizon_hours=4000.0, seed=11)
    paper = simulate(sch, _params(), trials=100, horizon_hours=4000.0,
                     seed=11)
    assert strict.observed_hours == paper.observed_hours
    assert strict.losses == paper.losses and paper.rejected == 0


def test_paper_model_thinning_slows_descent():
    """azure(6,2,1) has undecodable 3-patterns: the paper chain rejects
    them (slower descent), the strict chain loses — the simulator must
    show the same divergence, in the same direction."""
    sch = make_scheme("azure", 6, 2, 1)
    kw = dict(trials=400, horizon_hours=6000.0, seed=5)
    paper = simulate(sch, _params(disk_mttf_hours=175.0), **kw)
    strict = simulate(sch, _params(disk_mttf_hours=175.0, model="strict"),
                      **kw)
    assert paper.rejected > 0 and strict.rejected == 0
    assert paper.mttdl_years > strict.mttdl_years


def test_lse_and_scrub_semantics():
    """Latent errors alone can lose data; scrubbing heals them."""
    sch = make_scheme("azure", 4, 2, 1)
    quiet = _params(disk_mttf_hours=1e9, lse_hours=200.0)
    kw = dict(trials=30, horizon_hours=4000.0, seed=2)
    unscrubbed = simulate(sch, quiet, **kw)
    assert unscrubbed.counts["sector_error"] > 0
    assert unscrubbed.losses > 0          # 4 latent errors -> undecodable
    scrubbed = simulate(sch, dataclasses.replace(quiet, scrub_hours=20.0),
                        **kw)
    assert scrubbed.counts["scrub"] > 0
    assert scrubbed.losses < unscrubbed.losses


def test_burst_failures_respect_hierarchy():
    """A node burst downs every disk the node holds at once — wide
    placement (more nodes per stripe) survives bursts that kill a
    concentrated placement."""
    sch = make_scheme("azure", 6, 2, 2)
    # default ReliabilityParams: repairs finish in minutes, so the wide
    # placement never overlaps enough bursts to lose data
    quiet = _params(disk_mttf_hours=1e9, node_burst_hours=300.0,
                    reliability=ReliabilityParams())
    kw = dict(trials=25, horizon_hours=3000.0, seed=4)
    # every disk on its own node: a burst is a single-disk failure
    wide = simulate(sch, quiet, **kw)
    # all 10 blocks on 2 nodes: one burst erases 5 blocks -> loss
    packed = UnitHierarchy(node_of_disk=tuple(d % 2 for d in range(sch.n)),
                           rack_of_node=(0, 0))
    narrow = simulate(sch, quiet, hierarchy=packed, **kw)
    assert wide.losses == 0
    assert narrow.losses > 0


def test_mttdl_estimator_censoring():
    sch = make_scheme("azure", 4, 2, 1)
    res = simulate(sch, _params(disk_mttf_hours=1e9), trials=10,
                   horizon_hours=100.0, seed=0)
    assert res.losses == 0
    assert res.mttdl_years == float("inf")
    assert res.observed_hours == pytest.approx(10 * 100.0)


# ------------------------------------------------------------- calibration

def test_measured_bandwidth_and_calibrated_params():
    tele = {"bytes_read": 2_000_000_000, "sim_seconds": 8.0}
    assert measured_bandwidth(tele) == pytest.approx(2.0)
    rel = calibrated(_REL, tele)
    assert rel.bandwidth_gbps == pytest.approx(2.0)
    assert rel.node_mttf_years == _REL.node_mttf_years
    with pytest.raises(ValueError):
        measured_bandwidth({"bytes_read": 1, "sim_seconds": 0.0})


def test_measure_repair_bandwidth_real_pipeline(tmp_path):
    from repro.ftx import StoreConfig
    from repro.sim import measure_repair_bandwidth
    tele = measure_repair_bandwidth(
        tmp_path, StoreConfig(scheme="cp-azure", k=4, r=2, p=1,
                              block_size=1024), objects=2)
    assert tele["gbps"] > 0
    assert tele["bytes_read"] > 0
