"""RepairOptions/ServeOptions API contract.

PR 8 collapsed the loose repair/serve kwargs into options objects and kept
the old spellings for one deprecation cycle; PR 9 deleted them. The
contract now: ``options=`` is the only way in, every legacy kwarg raises
``TypeError`` like any other unknown keyword, and the unified event schema
(``repro.ftx.events``) is the only failure-record vocabulary.
"""
import numpy as np
import pytest

from repro.ftx import (FailureInjector, RepairOptions, ServeOptions,
                       StoreConfig, StripeStore, repair_failed_nodes)
from repro.ftx.events import (DataLossEvent, DiskFailEvent, NodeFailEvent,
                              RackFailEvent, RepairDoneEvent, ScrubEvent,
                              SectorErrorEvent, event_order, from_doc,
                              sort_events, to_doc)
from repro.ftx.pipeline import RepairPipeline


def _twin(tmp_path, name, **cfg_over):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=1024,
                      **cfg_over)
    store = StripeStore(tmp_path / name, cfg)
    rng = np.random.default_rng(7)
    data = {}
    for i in range(4):
        payload = rng.integers(0, 256, 4000, dtype=np.uint8)
        store.put(f"o{i}", payload.tobytes())
        data[f"o{i}"] = payload
    store.seal()
    return store, data


# ------------------------------------------------- legacy kwargs are gone

def test_repair_all_rejects_legacy_kwargs(tmp_path):
    store, _ = _twin(tmp_path, "legacy")
    for kw in ({"pipeline": True}, {"window": 2}, {"batched": False},
               {"schedule": "none"}, {"mesh_rules": None},
               {"pipeline_hook": lambda s, i: None}, {"placement": None},
               {"batch_size": 4}):
        with pytest.raises(TypeError):
            store.repair_all(**kw)


def test_repair_failed_nodes_rejects_legacy_kwargs(tmp_path):
    store, _ = _twin(tmp_path, "fleet")
    victim = store.stripes[0].node_of_block[0]
    for kw in ({"pipeline": True}, {"window": 2}, {"schedule": "none"}):
        with pytest.raises(TypeError):
            repair_failed_nodes(store, [victim], **kw)


def test_repair_pipeline_rejects_legacy_hook_kwarg(tmp_path):
    store, _ = _twin(tmp_path, "hook")
    with pytest.raises(TypeError):
        RepairPipeline(store, hook=lambda stage, i: None)
    with pytest.raises(TypeError):
        RepairPipeline(store, window=2)


def test_resolve_options_helper_deleted():
    import repro.ftx.options as options_mod
    assert not hasattr(options_mod, "resolve_options")


def test_options_path_repairs(tmp_path):
    """The options spelling (the only one left) repairs bit-exactly."""
    store, data = _twin(tmp_path, "opts", pipeline_window=2)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    hook_stages = []
    tele = store.repair_all(options=RepairOptions(
        pipeline=True, window=2,
        pipeline_hook=lambda stage, i: hook_stages.append(stage)))
    store.revive_node(victim)
    assert tele["blocks_read"] > 0 and hook_stages
    for k, v in data.items():
        assert (store.get(k) == v).all()


# ----------------------------------------------------------- ServeOptions

def test_serve_options_resolution_against_config():
    cfg = StoreConfig(k=4, r=2, p=1, coalesce_reads=True,
                      read_cache_blocks=8)
    assert ServeOptions().coalesce_for(cfg) is True
    assert ServeOptions().cache_for(cfg) is True
    assert ServeOptions(coalesce=False).coalesce_for(cfg) is False
    assert ServeOptions(use_cache=False).cache_for(cfg) is False
    off = StoreConfig(k=4, r=2, p=1, coalesce_reads=False,
                      read_cache_blocks=0)
    assert ServeOptions().coalesce_for(off) is False
    assert ServeOptions().cache_for(off) is False
    assert ServeOptions(use_cache=True).cache_for(off) is True


def test_read_with_serve_options_bit_identical(tmp_path):
    store, data = _twin(tmp_path, "serve", coalesce_reads=True,
                        read_cache_blocks=16)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    plain = store.read(0, 0)
    for opts in (ServeOptions(), ServeOptions(coalesce=False),
                 ServeOptions(use_cache=False),
                 ServeOptions(coalesce=False, use_cache=False)):
        assert (store.read(0, 0, options=opts) == plain).all()


def test_serve_options_cache_opt_out_counts(tmp_path):
    store, _ = _twin(tmp_path, "cache", read_cache_blocks=16)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    no_cache = ServeOptions(use_cache=False)
    store.read(0, 0, options=no_cache)
    t = store.telemetry
    before_hits = t.cache_hits
    store.read(0, 0, options=no_cache)     # would hit if caching were on
    assert store.telemetry.cache_hits == before_hits


# ------------------------------------------------- FailureEvent shim gone

def test_failure_event_shim_deleted():
    import repro.ftx.failures as failures_mod
    assert not hasattr(failures_mod, "FailureEvent")


def test_injector_emits_schema_events(tmp_path):
    store, _ = _twin(tmp_path, "inj")
    inj = FailureInjector(store, mttf_hours=8.0, seed=1)
    events = inj.run(hours=20.0)
    assert events
    assert all(isinstance(e, (NodeFailEvent, RepairDoneEvent))
               for e in events)


def test_injector_replay_consumes_foreign_trace(tmp_path):
    src_store, _ = _twin(tmp_path, "src")
    src = FailureInjector(src_store, mttf_hours=8.0, seed=3)
    trace = src.run(hours=25.0)
    dst_store, data = _twin(tmp_path, "dst")
    dst = FailureInjector(dst_store, seed=0)
    replayed = dst.replay(trace)
    assert len(dst.failures()) == len(src.failures())
    assert len(dst.repairs()) == len(dst.failures())
    # repairs re-executed against *this* store: costs are its own
    assert all(r.blocks_read > 0 for r in dst.repairs())
    for k, v in data.items():
        assert (dst_store.get(k) == v).all()
    assert replayed == dst.events


# --------------------------------------------------- event schema round-trip

def test_event_doc_roundtrip():
    events = [
        DiskFailEvent(t=1.5, disk=3, node=1, rack=0),
        NodeFailEvent(t=2.0, node=1, rack=0),
        RackFailEvent(t=2.0, rack=4),
        SectorErrorEvent(t=0.25, disk=2, block=7),
        ScrubEvent(t=9.0),
        RepairDoneEvent(t=3.5, unit=3, kind="disk", started_at=2.0,
                        blocks_read=6, sim_seconds=5400.0, local=True),
        DataLossEvent(t=11.0, blocks=(0, 4, 7)),
    ]
    for ev in events:
        doc = to_doc(ev)
        assert isinstance(doc, dict) and "event" in doc
        assert from_doc(doc) == ev
    # the discriminator never clobbers a field: RepairDoneEvent.kind is
    # the repaired unit's level and survives the round-trip
    rd = to_doc(events[5])
    assert rd["event"] == "repair_done" and rd["kind"] == "disk"


def test_sort_events_canonical_order():
    tie_a = NodeFailEvent(t=2.0, node=1)
    tie_b = RackFailEvent(t=2.0, rack=0)     # same t: rack ranks after node
    out = sort_events([ScrubEvent(t=9.0), tie_b, tie_a,
                       DiskFailEvent(t=0.5, disk=0)])
    assert [type(e).__name__ for e in out] == [
        "DiskFailEvent", "NodeFailEvent", "RackFailEvent", "ScrubEvent"]
    assert event_order(tie_a) < event_order(tie_b)
