"""RepairOptions/ServeOptions API + deprecated-kwarg compatibility.

The PR-8 satellite contract: every pre-PR-8 spelling (loose kwargs on
``repair_all``/``repair_failed_nodes``/``RepairPipeline``, the fused
``FailureEvent`` record) keeps working for one deprecation cycle, warns
once, and is *bit-identical* to the options-object path — same telemetry,
same recovered bytes.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.ftx import (FailureInjector, RepairOptions, ServeOptions,
                       StoreConfig, StripeStore, repair_failed_nodes)
from repro.ftx.events import (DataLossEvent, DiskFailEvent, NodeFailEvent,
                              RackFailEvent, RepairDoneEvent, ScrubEvent,
                              SectorErrorEvent, event_order, from_doc,
                              sort_events, to_doc)
from repro.ftx.failures import FailureEvent
from repro.ftx.options import resolve_options
from repro.ftx.pipeline import RepairPipeline


def _twin(tmp_path, name, **cfg_over):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=1024,
                      **cfg_over)
    store = StripeStore(tmp_path / name, cfg)
    rng = np.random.default_rng(7)
    data = {}
    for i in range(4):
        payload = rng.integers(0, 256, 4000, dtype=np.uint8)
        store.put(f"o{i}", payload.tobytes())
        data[f"o{i}"] = payload
    store.seal()
    return store, data


# --------------------------------------------------------- resolve_options

def test_resolve_options_merges_and_warns():
    with pytest.warns(DeprecationWarning, match="window.*deprecated"):
        o = resolve_options(None, {"window": 3}, RepairOptions, "x")
    assert o.window == 3 and o.batched is True
    # legacy kwargs win over fields of a passed options object
    with pytest.warns(DeprecationWarning):
        o = resolve_options(RepairOptions(window=9, schedule="locality"),
                            {"window": 2}, RepairOptions, "x")
    assert o.window == 2 and o.schedule == "locality"
    # no legacy kwargs: options object passes through untouched, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        same = RepairOptions(pipeline=True)
        assert resolve_options(same, {}, RepairOptions, "x") is same
        assert resolve_options(None, {}, RepairOptions, "x") == \
            RepairOptions()


def test_resolve_options_unknown_kwarg_raises():
    with pytest.raises(TypeError, match="repair_all.*bogus"):
        resolve_options(None, {"bogus": 1}, RepairOptions,
                        "StripeStore.repair_all")


# ------------------------------------------- repair_all legacy == options

def test_repair_all_legacy_bit_identical_to_options(tmp_path):
    results = {}
    for mode in ("options", "legacy"):
        store, data = _twin(tmp_path, mode, pipeline_window=2)
        victim = store.stripes[0].node_of_block[0]
        store.fail_node(victim)
        if mode == "options":
            with warnings.catch_warnings():
                warnings.simplefilter("error", DeprecationWarning)
                tele = store.repair_all(
                    options=RepairOptions(pipeline=True, window=2))
        else:
            with pytest.warns(DeprecationWarning,
                              match="repair_all.*pipeline.*window"):
                tele = store.repair_all(pipeline=True, window=2)
        store.revive_node(victim)
        results[mode] = (tele, {k: store.get(k) for k in data})
        for k, v in data.items():
            assert (store.get(k) == v).all()
    opt_tele, leg_tele = results["options"][0], results["legacy"][0]
    assert set(opt_tele) == set(leg_tele)
    for key in opt_tele:
        if "seconds" in key and key != "sim_seconds":
            continue                      # wall-clock: machine noise
        if key == "sim_seconds":          # modeled time: float-sum order
            assert leg_tele[key] == pytest.approx(opt_tele[key])
        else:                             # counters: exact
            assert leg_tele[key] == opt_tele[key], key
    for k in results["options"][1]:
        assert (results["options"][1][k] == results["legacy"][1][k]).all()


def test_repair_all_unknown_kwarg(tmp_path):
    store, _ = _twin(tmp_path, "u")
    with pytest.raises(TypeError, match="batch_size"):
        store.repair_all(batch_size=4)


def test_repair_failed_nodes_legacy_matches_options(tmp_path):
    teles = {}
    for mode in ("options", "legacy"):
        store, data = _twin(tmp_path, f"f{mode}")
        victim = store.stripes[0].node_of_block[1]
        if mode == "options":
            rep = repair_failed_nodes(store, [victim],
                                      options=RepairOptions(schedule="none"))
        else:
            with pytest.warns(DeprecationWarning):
                rep = repair_failed_nodes(store, [victim], schedule="none")
        teles[mode] = rep
        for k, v in data.items():
            assert (store.get(k) == v).all()
    assert teles["options"].blocks_read == teles["legacy"].blocks_read
    assert teles["options"].stripes_repaired == \
        teles["legacy"].stripes_repaired


def test_repair_pipeline_legacy_hook_kwarg(tmp_path):
    store, data = _twin(tmp_path, "hook", pipeline_window=2)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    stages = []
    with pytest.warns(DeprecationWarning, match="pipeline_hook"):
        pipe = RepairPipeline(store, hook=lambda stage, i:
                              stages.append(stage))
    affected = {}
    for sid in store.stripes:
        down = store._down_blocks(sid)
        if down:
            affected.setdefault(down, []).append(sid)
    work = [(sids, down, store.engine.planner.multi_plan(down))
            for down, sids in affected.items()]
    pipe.run(work)
    store.revive_node(victim)
    assert stages  # the translated hook actually fired
    for k, v in data.items():
        assert (store.get(k) == v).all()


# ----------------------------------------------------------- ServeOptions

def test_serve_options_resolution_against_config():
    cfg = StoreConfig(k=4, r=2, p=1, coalesce_reads=True,
                      read_cache_blocks=8)
    assert ServeOptions().coalesce_for(cfg) is True
    assert ServeOptions().cache_for(cfg) is True
    assert ServeOptions(coalesce=False).coalesce_for(cfg) is False
    assert ServeOptions(use_cache=False).cache_for(cfg) is False
    off = StoreConfig(k=4, r=2, p=1, coalesce_reads=False,
                      read_cache_blocks=0)
    assert ServeOptions().coalesce_for(off) is False
    assert ServeOptions().cache_for(off) is False
    assert ServeOptions(use_cache=True).cache_for(off) is True


def test_read_with_serve_options_bit_identical(tmp_path):
    store, data = _twin(tmp_path, "serve", coalesce_reads=True,
                        read_cache_blocks=16)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    plain = store.read(0, 0)
    for opts in (ServeOptions(), ServeOptions(coalesce=False),
                 ServeOptions(use_cache=False),
                 ServeOptions(coalesce=False, use_cache=False)):
        assert (store.read(0, 0, options=opts) == plain).all()


def test_serve_options_cache_opt_out_counts(tmp_path):
    store, _ = _twin(tmp_path, "cache", read_cache_blocks=16)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    no_cache = ServeOptions(use_cache=False)
    store.read(0, 0, options=no_cache)
    t = store.telemetry
    before_hits = t.cache_hits
    store.read(0, 0, options=no_cache)     # would hit if caching were on
    assert store.telemetry.cache_hits == before_hits


# --------------------------------------------------- FailureEvent shim

def test_failure_event_shim_is_node_fail_event():
    with pytest.warns(DeprecationWarning, match="FailureEvent"):
        ev = FailureEvent(t=3.0, node=2, repaired_at=4.5, blocks_read=12,
                          sim_seconds=5400.0, local=True)
    assert isinstance(ev, NodeFailEvent)
    assert ev.t == 3.0 and ev.node == 2 and ev.repaired_at == 4.5
    assert ev.blocks_read == 12 and ev.local is True


def test_injector_log_has_no_deprecation_warnings(tmp_path):
    store, _ = _twin(tmp_path, "inj")
    inj = FailureInjector(store, mttf_hours=8.0, seed=1)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        events = inj.run(hours=20.0)
    assert events and all(not isinstance(e, FailureEvent) for e in events)


def test_injector_replay_consumes_foreign_trace(tmp_path):
    src_store, _ = _twin(tmp_path, "src")
    src = FailureInjector(src_store, mttf_hours=8.0, seed=3)
    trace = src.run(hours=25.0)
    dst_store, data = _twin(tmp_path, "dst")
    dst = FailureInjector(dst_store, seed=0)
    replayed = dst.replay(trace)
    assert len(dst.failures()) == len(src.failures())
    assert len(dst.repairs()) == len(dst.failures())
    # repairs re-executed against *this* store: costs are its own
    assert all(r.blocks_read > 0 for r in dst.repairs())
    for k, v in data.items():
        assert (dst_store.get(k) == v).all()
    assert replayed == dst.events


# --------------------------------------------------- event schema round-trip

def test_event_doc_roundtrip():
    events = [
        DiskFailEvent(t=1.5, disk=3, node=1, rack=0),
        NodeFailEvent(t=2.0, node=1, rack=0),
        RackFailEvent(t=2.0, rack=4),
        SectorErrorEvent(t=0.25, disk=2, block=7),
        ScrubEvent(t=9.0),
        RepairDoneEvent(t=3.5, unit=3, kind="disk", started_at=2.0,
                        blocks_read=6, sim_seconds=5400.0, local=True),
        DataLossEvent(t=11.0, blocks=(0, 4, 7)),
    ]
    for ev in events:
        doc = to_doc(ev)
        assert isinstance(doc, dict) and "event" in doc
        assert from_doc(doc) == ev
    # the discriminator never clobbers a field: RepairDoneEvent.kind is
    # the repaired unit's level and survives the round-trip
    rd = to_doc(events[5])
    assert rd["event"] == "repair_done" and rd["kind"] == "disk"


def test_sort_events_canonical_order():
    tie_a = NodeFailEvent(t=2.0, node=1)
    tie_b = RackFailEvent(t=2.0, rack=0)     # same t: rack ranks after node
    out = sort_events([ScrubEvent(t=9.0), tie_b, tie_a,
                       DiskFailEvent(t=0.5, disk=0)])
    assert [type(e).__name__ for e in out] == [
        "DiskFailEvent", "NodeFailEvent", "RackFailEvent", "ScrubEvent"]
    assert event_order(tie_a) < event_order(tie_b)
