"""Logical sharding resolution + smoke-mesh lowering of every family."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import _resolve, opt_state_sharding, with_rules


@pytest.fixture
def mesh():
    # 1x1 host mesh with the production axis names
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_divisible(mesh):
    with with_rules(mesh) as mr:
        spec = _resolve((32, 64), ("batch", "ff"), mr)
        assert spec == P("data", "model")


def test_resolve_indivisible_degrades():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with with_rules(mesh) as mr:
        pass
    # simulate a 16-way model axis by faking rule checks on a bigger mesh is
    # not possible on 1 device; the fallback logic is covered via dryrun
    # results (grok experts replicate, arctic heads replicate).


def test_axis_used_once(mesh):
    with with_rules(mesh) as mr:
        spec = _resolve((4, 4), ("heads", "ff"), mr)  # both want "model"
        assert spec[0] == "model" and spec[1] is None


def test_opt_state_extends(mesh):
    with with_rules(mesh) as mr:
        ns = opt_state_sharding(P(None, "model"), (8, 4), mr)
        assert ns.spec[0] == "data"


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "seamless-m4t-medium",
                                  "jamba-v0.1-52b", "mamba2-2.7b",
                                  "arctic-480b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_smoke_lowering_compiles(arch, shape):
    """lower().compile() of reduced configs on the host mesh — the same
    driver the 512-device dry-run uses."""
    from repro.launch.dryrun import lower_cell

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    record, lowered, compiled = lower_cell(arch, shape, mesh, smoke=True)
    assert record["cost"].get("flops", 0) > 0
    assert "error" not in record["memory"]
