"""Logical sharding resolution + smoke-mesh lowering of every family."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import _resolve, opt_state_sharding, with_rules


@pytest.fixture
def mesh():
    # 1x1 host mesh with the production axis names
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_divisible(mesh):
    with with_rules(mesh) as mr:
        spec = _resolve((32, 64), ("batch", "ff"), mr)
        assert spec == P("data", "model")


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_resolve_indivisible_degrades():
    """Real degradation cases on a 2x4 mesh (the multi-device CI leg)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with with_rules(mesh) as mr:
        # divisible everywhere: both axes assigned
        assert _resolve((8, 8), ("batch", "ff"), mr) == P("data", "model")
        # 4-way model axis does not divide 3 heads -> replicate (arctic case)
        assert _resolve((6, 3), ("batch", "heads"), mr) == P("data", None)
        # 2-way data axis does not divide batch 3 -> replicate
        assert _resolve((3, 8), ("batch", "ff"), mr) == P(None, "model")
        # grok case: indivisible experts degrade, freeing "model" for the
        # expert FFN dim (tensor-parallel expert FFNs)
        assert _resolve((3, 16, 32), ("experts", None, "expert_ff"), mr) \
            == P(None, None, "model")
        # divisible experts claim "model" first; expert_ff then degrades
        assert _resolve((4, 16, 32), ("experts", None, "expert_ff"), mr) \
            == P("model", None, None)
        # opt_state_sharding degradation: the largest replicated dim (7) is
        # indivisible by "data"(2), so it is skipped and the next-largest
        # divisible dim (4) takes the axis instead
        ns = opt_state_sharding(P(), (7, 4), mr)
        assert ns.spec == P(None, "data")
        # nothing divisible -> fully replicated
        ns = opt_state_sharding(P(), (7, 5), mr)
        assert all(e is None for e in ns.spec)


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_rule_overrides_and_freed_axes():
    """Overrides reroute logical axes; degradation frees axes for later dims
    (the batch=1 long-context kv_seq context-parallel trick)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with with_rules(mesh, {"kv_seq": ("data",)}) as mr:
        # batch=1 cannot take "data" (1 % 2 != 0); kv_seq picks it up
        spec = _resolve((1, 1024, 4, 64), ("batch", "kv_seq", "kv_heads", None), mr)
        assert spec == P(None, "data", "model", None)
        # with a shardable batch, batch wins "data" and kv_seq degrades
        spec = _resolve((4, 1024, 4, 64), ("batch", "kv_seq", "kv_heads", None), mr)
        assert spec == P("data", None, "model", None)


def test_axis_used_once(mesh):
    with with_rules(mesh) as mr:
        spec = _resolve((4, 4), ("heads", "ff"), mr)  # both want "model"
        assert spec[0] == "model" and spec[1] is None


def test_opt_state_extends(mesh):
    with with_rules(mesh) as mr:
        ns = opt_state_sharding(P(None, "model"), (8, 4), mr)
        assert ns.spec[0] == "data"


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "seamless-m4t-medium",
                                  "jamba-v0.1-52b", "mamba2-2.7b",
                                  "arctic-480b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_smoke_lowering_compiles(arch, shape):
    """lower().compile() of reduced configs on the host mesh — the same
    driver the 512-device dry-run uses."""
    from repro.launch.dryrun import lower_cell

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    record, lowered, compiled = lower_cell(arch, shape, mesh, smoke=True)
    assert record["cost"].get("flops", 0) > 0
    assert "error" not in record["memory"]
