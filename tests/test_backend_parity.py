"""Backend bit-parity: encode/repair/decode through every registered kernel
backend must produce identical bytes — batched engine, sharded launches, and
the store's sync / pipelined / degraded-serving paths.

Since PR 7 the bit-plane backends (crs/mxu) are first-class through the
whole stack: there is no silent ``matmul_backend`` downgrade left, so these
tests drive the *actual* crs/mxu formulations (their jnp references on the
CPU interpret path — same math, fused) and assert bit-identity against the
table oracle. The 1-device cases always run; the 8-device cases run in the
forced-8-device CI leg. ``effective_backend`` telemetry is pinned here too:
gf batches report "ref" on interpreter hosts, everything else reports
itself.
"""
import jax
import numpy as np
import pytest

from repro.core.engine import BatchedCodecEngine
from repro.core.schemes import make_scheme
from repro.dist.sharding import with_rules
from repro.kernels.ops import BACKENDS, effective_backend

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

SCHEMES = ("cp-azure", "cp-uniform")
# Single failure (one data block) and double failure (data block + its
# local parity — the cascading case).
PATTERNS = ("single", "double")


def _mesh():
    return jax.make_mesh((8, 1), ("data", "model"))


def _pattern(scheme, kind):
    return frozenset({0} if kind == "single" else {0, scheme.k})


@pytest.fixture(scope="module", params=SCHEMES)
def reference(request):
    """Per-scheme golden bytes from the table oracle: encoded stripes plus
    repaired blocks for the single and double failure patterns."""
    scheme = make_scheme(request.param, 8, 2, 2)
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, (8, scheme.k, 512), dtype=np.uint8)
    ref = BatchedCodecEngine(scheme, backend="ref")
    stripes = np.asarray(ref.encode(data))
    want = {}
    for kind in PATTERNS:
        pattern = _pattern(scheme, kind)
        avail = {i: stripes[:, i, :] for i in range(scheme.n)
                 if i not in pattern}
        out, _ = ref.repair_multi(pattern, avail)
        want[kind] = {b: np.asarray(v) for b, v in out.items()}
    return scheme, data, stripes, want


def _check_engine(engine, scheme, data, stripes, want, *, span=1):
    enc = np.asarray(engine.encode(data))
    assert (enc == stripes).all(), f"{engine.backend}: encode bytes differ"
    assert engine.last_span == span
    assert engine.effective_backend == effective_backend(engine.backend)
    for kind in PATTERNS:
        pattern = _pattern(scheme, kind)
        avail = {i: stripes[:, i, :] for i in range(scheme.n)
                 if i not in pattern}
        got, _ = engine.repair_multi(pattern, avail)
        for b in sorted(pattern):
            assert (np.asarray(got[b]) == want[kind][b]).all(), \
                f"{engine.backend}/{kind}: repaired block {b} differs"
    # decode the data blocks with block 0 replaced by its local parity
    ids = list(range(1, scheme.k)) + [scheme.k]
    dec = np.asarray(engine.decode({i: stripes[:, i, :] for i in ids}))
    assert (dec == data).all(), f"{engine.backend}: decode bytes differ"


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_parity_single_device(backend, reference):
    scheme, data, stripes, want = reference
    eng = BatchedCodecEngine(scheme, backend=backend)
    _check_engine(eng, scheme, data, stripes, want)


@multidevice
@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_parity_sharded_8dev(backend, reference):
    """Same golden bytes through the jit(shard_map) launch on 8 devices."""
    scheme, data, stripes, want = reference
    with with_rules(_mesh()) as mr:
        eng = BatchedCodecEngine(scheme, backend=backend, mesh_rules=mr)
        _check_engine(eng, scheme, data, stripes, want, span=8)


def test_effective_backend_reporting():
    """gf substitutes the fused table path on interpreter hosts (and says
    so); the bit-plane backends and ref always report themselves."""
    on_cpu = jax.default_backend() == "cpu"
    assert effective_backend("gf") == ("ref" if on_cpu else "gf")
    assert effective_backend("gf", force_pallas=True) == "gf"
    assert effective_backend("gf", interpret=False) == "gf"
    for b in ("crs", "mxu", "ref"):
        assert effective_backend(b) == b
    with pytest.raises(ValueError, match="unknown kernel backend"):
        effective_backend("nope")


def test_unknown_backend_rejected():
    scheme = make_scheme("cp-azure", 6, 2, 2)
    with pytest.raises(ValueError, match="unknown kernel backend"):
        BatchedCodecEngine(scheme, backend="nope")


# ----------------------------------------------------------- store parity
def _build_store(root, backend, *, stripes=12, pipeline_window=0):
    from repro.ftx import StoreConfig, StripeStore

    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=512,
                      backend=backend, batch_stripes=4,
                      pipeline_window=pipeline_window, prefetch_threads=2)
    store = StripeStore(root, cfg, num_nodes=cfg.k + cfg.r + cfg.p)
    rng = np.random.default_rng(3)
    payload = rng.integers(0, 256, stripes * cfg.k * cfg.block_size,
                           dtype=np.uint8).tobytes()
    store.put("obj", payload)
    store.seal()
    return store, payload


@pytest.mark.parametrize("backend", ("crs", "mxu"))
@pytest.mark.parametrize("pipelined", (False, True))
def test_store_repair_parity_bit_plane_backends(tmp_path, backend, pipelined):
    """Fleet repair (sync and pipelined) through crs/mxu rebuilds the same
    bytes as the ref store, and the report names the backend that ran."""
    from repro.ftx import repair_failed_nodes

    window = 4 if pipelined else 0
    ref_store, payload = _build_store(tmp_path / "ref", "ref",
                                      pipeline_window=window)
    bit_store, _ = _build_store(tmp_path / backend, backend,
                                pipeline_window=window)
    repair_failed_nodes(ref_store, [0, 6])
    report = repair_failed_nodes(bit_store, [0, 6])
    assert report.effective_backend == backend
    assert report.pipelined == pipelined
    assert bit_store.get("obj").tobytes() == payload
    for sid, stripe in ref_store.stripes.items():
        for b in range(ref_store.scheme.n):
            assert (bit_store._read_block(sid, b)
                    == ref_store._read_block(sid, b)).all(), (sid, b)


@pytest.mark.parametrize("backend", ("crs", "mxu"))
def test_store_degraded_serving_parity(tmp_path, backend):
    """Degraded reads (the serving path) through crs/mxu return the same
    bytes as healthy reads, and the engine records the formulation."""
    store, _ = _build_store(tmp_path / backend, backend)
    sid = min(store.stripes)
    healthy = {b: store.read(sid, b).tobytes()
               for b in range(store.scheme.n)}
    down = store.stripes[sid].node_of_block[0]
    store.fail_node(down)
    served = {b: store.read(sid, b).tobytes()
              for b in range(store.scheme.n)}
    assert served == healthy
    assert store.telemetry.degraded_reads > 0
    assert store.engine.effective_backend == backend
