"""Backend bit-parity: one batched encode/repair/decode through every
registered kernel backend must produce identical bytes.

Guards the ROADMAP "route batched decode through crs/mxu on TPU" follow-on:
whatever backend the dispatch layer picks, GF(2^8) bytes may never change.
Backends whose kernels are genuinely unavailable on the host skip rather
than fail (on CPU containers all of them run via the Pallas interpreter or
the fused table path).
"""
import numpy as np
import pytest

from repro.core.engine import BatchedCodecEngine
from repro.core.schemes import make_scheme
from repro.kernels.ops import BACKENDS


@pytest.fixture(scope="module")
def reference():
    scheme = make_scheme("cp-azure", 8, 2, 2)
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, (8, scheme.k, 512), dtype=np.uint8)
    ref = BatchedCodecEngine(scheme, backend="ref")
    stripes = np.asarray(ref.encode(data))
    pattern = frozenset({0, scheme.k})    # data block + local parity cascade
    avail = {i: stripes[:, i, :] for i in range(scheme.n)
             if i not in pattern}
    want, _ = ref.repair_multi(pattern, avail)
    want = {b: np.asarray(v) for b, v in want.items()}
    return scheme, data, stripes, pattern, avail, want


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_repair_bit_parity_across_backends(backend, reference):
    scheme, data, stripes, pattern, avail, want = reference
    try:
        eng = BatchedCodecEngine(scheme, backend=backend)
        enc = np.asarray(eng.encode(data))
        got, _ = eng.repair_multi(pattern, avail)
        got = {b: np.asarray(v) for b, v in got.items()}
        # decode the data blocks with block 0 replaced by its local parity
        ids = list(range(1, scheme.k)) + [scheme.k]
        dec = np.asarray(eng.decode({i: stripes[:, i, :] for i in ids}))
    except NotImplementedError as e:      # kernel unavailable on this host
        pytest.skip(f"backend {backend!r} unavailable here: {e}")
    assert (enc == stripes).all(), f"{backend}: encode bytes differ"
    for b in sorted(pattern):
        assert (got[b] == want[b]).all(), \
            f"{backend}: repaired block {b} differs"
    assert (dec == data).all(), f"{backend}: decode bytes differ"


def test_unknown_backend_rejected():
    scheme = make_scheme("cp-azure", 6, 2, 2)
    with pytest.raises(ValueError, match="unknown kernel backend"):
        BatchedCodecEngine(scheme, backend="nope")
