"""MTTDL model sanity + paper-model ordering."""
import pytest

from repro.core.reliability import ReliabilityParams, stripe_mttdl_years
from repro.core.schemes import make_scheme

PARAMS = ReliabilityParams()


def test_positive_and_finite():
    for name in ("azure", "cp-azure", "cp-uniform"):
        v = stripe_mttdl_years(make_scheme(name, 6, 2, 2), PARAMS,
                               samples=300)
        assert v > 0


def test_paper_model_cp_wins_at_p1():
    az = stripe_mttdl_years(make_scheme("azure", 6, 2, 2), PARAMS,
                            samples=500, model="paper")
    cpa = stripe_mttdl_years(make_scheme("cp-azure", 6, 2, 2), PARAMS,
                             samples=500, model="paper")
    cpu = stripe_mttdl_years(make_scheme("cp-uniform", 6, 2, 2), PARAMS,
                             samples=500, model="paper")
    assert cpa > az and cpu > az


def test_strict_model_penalizes_lower_distance():
    """Under the rank-faithful model, CP's d=r+1 costs reliability vs
    Azure's d=r+2 — the honest trade-off DESIGN.md documents."""
    az = stripe_mttdl_years(make_scheme("azure", 6, 2, 2), PARAMS,
                            samples=500, model="strict")
    cpa = stripe_mttdl_years(make_scheme("cp-azure", 6, 2, 2), PARAMS,
                             samples=500, model="strict")
    assert az > cpa


def test_faster_repair_higher_mttdl():
    import dataclasses

    s = make_scheme("cp-azure", 6, 2, 2)
    slow = dataclasses.replace(PARAMS, bandwidth_gbps=0.1)
    fast = dataclasses.replace(PARAMS, bandwidth_gbps=10.0)
    assert (stripe_mttdl_years(s, fast, samples=300)
            > stripe_mttdl_years(s, slow, samples=300))
