"""Property-test shim: real hypothesis when installed, deterministic fallback
when not.

The tier-1 container does not ship ``hypothesis`` (see requirements-dev.txt
to install it); property tests must still *run*, not error at collection.
With hypothesis absent, ``given`` replays a fixed number of seeded,
deterministic samples per strategy — far weaker than real shrinking/search,
but it keeps every property exercised on the same assertion bodies.

Usage in test modules::

    from _prop import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np
    import pytest

    _FALLBACK_EXAMPLES = 25
    _SEED = 0xC0DEC

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> value

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples=_FALLBACK_EXAMPLES, **_ignored):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(**fixtures):
                n = getattr(wrapper, "_prop_max_examples", _FALLBACK_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                skips = []
                for _ in range(n):
                    args = [s.sample(rng) for s in strategies]
                    try:
                        fn(*args, **fixtures)
                    except pytest.skip.Exception as e:
                        # Per-example skip (hypothesis `assume` idiom); only
                        # skip the test if every example bailed.
                        skips.append(e)
                if len(skips) == n:
                    raise skips[0]
            # pytest must not mistake the strategy params for fixtures.
            sig = inspect.signature(fn)
            keep = list(sig.parameters.values())[len(strategies):]
            wrapper.__signature__ = sig.replace(parameters=keep)
            del wrapper.__wrapped__
            return wrapper
        return deco
