"""Serve engine: continuous batching completes requests deterministically."""
import jax
import numpy as np
import pytest

from repro.configs import get_model
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def engine():
    api = get_model("qwen2.5-3b", smoke=True)
    eng = ServeEngine(api, max_batch=3, max_len=96)
    eng.load(api.init_params(jax.random.key(0)))
    return eng


def test_requests_complete(engine):
    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, 500, 12), max_new=6)
            for _ in range(5)]
    engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)


def test_greedy_decode_deterministic(engine):
    prompt = np.arange(10) % 500
    r1 = engine.submit(prompt.copy(), max_new=5)
    engine.run()
    r2 = engine.submit(prompt.copy(), max_new=5)
    engine.run()
    assert r1.out_tokens == r2.out_tokens
