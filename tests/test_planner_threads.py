"""RepairPlanner LRU cache under concurrency.

The planner is shared by the codec, the batched engine and every pipeline
reader/writer thread of a store — and fleet repair may run from multiple
coordinator threads at once. The cache contract under that load: counters
stay consistent (hits + misses == lookups, evictions never exceed
insertions), the LRU bound holds, and no plan is ever lost or corrupted
(every returned CompiledPlan matches a fresh single-threaded solve).
"""
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.planner import RepairPlanner
from repro.core.schemes import make_scheme
from repro.ftx import (RepairOptions, StoreConfig, StripeStore,
                       repair_failed_nodes)


def _build(root, stripes=20):
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=256,
                      batch_stripes=8, pipeline_window=4, prefetch_threads=2)
    store = StripeStore(root, cfg)
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * cfg.k * cfg.block_size, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    return store


def test_concurrent_repair_all_shares_planner_consistently(tmp_path):
    """Four threads drive repair_all on the same store at once (idempotent:
    every thread rebuilds the same blocks to the same bytes). The shared
    planner's stats stay consistent and every pattern stays cached."""
    store = _build(tmp_path / "s")
    truth = {(sid, b): store._block_path(sid, b).read_bytes()
             for sid in store.stripes for b in range(store.scheme.n)}
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    patterns = {store._down_blocks(sid) for sid in store.stripes
                if store._down_blocks(sid)}
    assert patterns
    store.codec.planner.cache_clear()

    barrier = threading.Barrier(4)

    def worker():
        barrier.wait()
        return store.repair_all(options=RepairOptions(pipeline=False))

    with ThreadPoolExecutor(4) as pool:
        futures = [pool.submit(worker) for _ in range(4)]
        results = [f.result() for f in futures]    # raises on any failure
    store.revive_node(node)

    assert all(r["stripes_repaired"] > 0 for r in results)
    assert {(sid, b): store._block_path(sid, b).read_bytes()
            for sid in store.stripes
            for b in range(store.scheme.n)} == truth

    stats = store.codec.planner.stats
    assert stats.lookups == stats.hits + stats.misses
    # duplicate concurrent builds are allowed (solve runs outside the
    # lock), but nothing may be lost: every pattern is now a pure hit
    assert stats.misses >= len(patterns)
    before = stats.snapshot()
    for down in patterns:
        store.engine.planner.multi_plan(down)
    after = stats.snapshot()
    assert after["misses"] == before["misses"]
    assert after["hits"] == before["hits"] + len(patterns)


def test_repair_all_concurrent_with_degraded_reads(tmp_path):
    """repair_all races 8 serving threads on the same store: every byte
    served during the race is bit-identical (write-back invalidation never
    exposes a stale cache entry), and the serving + planner counters stay
    consistent — every read is accounted exactly once."""
    store = _build(tmp_path / "s")
    truth = {(sid, b): store._block_path(sid, b).read_bytes()
             for sid in store.stripes for b in range(store.scheme.n)}
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    keys = sorted(truth)
    reads_per_thread = 150
    barrier = threading.Barrier(9)
    errors = []

    def reader(seed):
        rng = np.random.default_rng(seed)
        barrier.wait()
        for _ in range(reads_per_thread):
            sid, b = keys[int(rng.integers(len(keys)))]
            got = store.read(sid, b).tobytes()
            if got != truth[(sid, b)]:
                errors.append((sid, b))

    def repairer():
        barrier.wait()
        return store.repair_all(options=RepairOptions(pipeline=False))

    with ThreadPoolExecutor(9) as pool:
        futures = [pool.submit(reader, seed) for seed in range(8)]
        repair = pool.submit(repairer)
        for f in futures:
            f.result()                       # raises on any reader failure
        rep = repair.result()
    assert rep["stripes_repaired"] > 0
    assert not errors, f"stale/corrupt serves: {errors[:3]}"
    t = store.telemetry
    assert t.direct_reads + t.degraded_reads == 8 * reads_per_thread
    # every degraded read either hit the hot cache or was counted a miss
    # (coalesced waiters are misses too — they paid for the shared decode)
    assert t.cache_hits + t.cache_misses == t.degraded_reads
    stats = store.codec.planner.stats
    assert stats.lookups == stats.hits + stats.misses
    # post-repair, post-revive: the written-back blocks serve direct and
    # bit-identical — no reconstruction artifacts survived the race
    store.revive_node(node)
    assert {k: store.read(*k).tobytes() for k in keys} == truth


def test_lru_eviction_consistent_under_thread_hammer():
    """16 threads hammer a maxsize-8 planner with 3x as many distinct
    patterns: the LRU bound holds, counters add up, and every plan handed
    out equals the single-threaded solve (no lost/corrupt plans)."""
    scheme = make_scheme("cp-azure", 24, 2, 2)
    planner = RepairPlanner(scheme, maxsize=8)
    oracle = RepairPlanner(scheme, maxsize=512)
    patterns = [frozenset({b}) for b in range(24)]
    rounds = 4
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        for _ in range(rounds):
            for i in rng.permutation(len(patterns)):
                plan = planner.multi_plan(patterns[i])
                ref = oracle.multi_plan(patterns[i])
                if (plan.targets != ref.targets or plan.reads != ref.reads
                        or not (plan.coeffs == ref.coeffs).all()):
                    errors.append(patterns[i])

    with ThreadPoolExecutor(16) as pool:
        list(pool.map(worker, range(16)))

    assert not errors, f"lost/corrupt plans for {errors[:3]}"
    stats = planner.stats
    assert stats.lookups == stats.hits + stats.misses == \
        16 * rounds * len(patterns)
    assert stats.misses >= len(patterns)
    assert stats.evictions <= stats.misses
    assert len(planner) <= 8


def test_pipelined_repair_threads_share_planner(tmp_path):
    """The pipeline's reader/writer threads re-plan through the same
    planner mid-repair; a serial second repair of the same pattern set is
    then all hits (plans survived the concurrent phase)."""
    store = _build(tmp_path / "s")
    node = store.stripes[0].node_of_block[0]
    rep = repair_failed_nodes(store, [node], options=RepairOptions(pipeline=True))
    assert rep.pipelined and rep.stripes_repaired > 0
    assert rep.plan_cache["hits"] + rep.plan_cache["misses"] > 0
    rep2 = repair_failed_nodes(store, [node], options=RepairOptions(pipeline=True))
    assert rep2.plan_cache["misses"] == 0
    assert rep2.plan_cache["hits"] > 0


def test_concurrent_byte_and_bit_plans_share_one_expansion():
    """Byte plans and their bit-matrix expansions requested concurrently
    for the same down-sets: LRU stats count only plan lookups (bit
    expansions ride on the cached plan, never the planner cache), every
    thread sees one identical expansion per plan, and the process-wide
    expansion counter grows by exactly the number of distinct plans —
    the once-per-pattern-chunk amortization contract (DESIGN.md §11)."""
    from repro.core.gf import matrix_to_bitmatrix
    from repro.core.planner import bitmatrix_expansions

    scheme = make_scheme("cp-azure", 12, 2, 2)
    planner = RepairPlanner(scheme)
    patterns = [frozenset({b}) for b in range(8)]
    # Warm the byte plans serially so the race below is over *one* cached
    # plan object per pattern (racing solves legitimately build duplicate
    # plan objects; only the published one matters for expansion counting).
    for down in patterns:
        planner.multi_plan(down)
    base = planner.stats.snapshot()
    assert base["misses"] == len(patterns)
    before = bitmatrix_expansions()
    barrier = threading.Barrier(16)
    seen: list[dict] = []
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        got = {}
        barrier.wait()
        for i in rng.permutation(len(patterns)):
            down = patterns[i]
            plan = planner.multi_plan(down)       # byte-plan lookup (hit)
            bits = plan.bit_coeffs()              # bit-plan request
            if bits.shape != (plan.coeffs.shape[0] * 8,
                              plan.coeffs.shape[1] * 8):
                errors.append(down)
            got[down] = (id(plan.bit_coeffs()), bits)
        seen.append(got)

    with ThreadPoolExecutor(16) as pool:
        list(pool.map(worker, range(16)))

    assert not errors
    # Bit requests never touch the planner cache: lookups grew only by the
    # byte-plan hits, and hits+misses still add up.
    stats = planner.stats
    assert stats.lookups == stats.hits + stats.misses
    assert stats.misses == base["misses"]
    assert stats.hits == base["hits"] + 16 * len(patterns)
    # Every thread got the same cached expansion object, with the right bits.
    for down in patterns:
        plan = planner.multi_plan(down)
        ids = {got[down][0] for got in seen}
        assert ids == {id(plan.bit_coeffs())}, down
        want = matrix_to_bitmatrix(plan.coeffs)
        for got in seen:
            assert (got[down][1] == want).all(), down
    # Counter: one expansion per plan — never per call or per thread.
    assert bitmatrix_expansions() - before == len(patterns)


def test_bit_expansion_cached_once_per_pattern_chunk(tmp_path):
    """End-to-end counter test: a fleet repair through a bit-plane backend
    expands each pattern's coefficient matrix exactly once, no matter how
    many chunked launches the pattern's stripe group takes."""
    from repro.core.planner import bitmatrix_expansions

    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=256,
                      backend="crs", batch_stripes=4, pipeline_window=0)
    store = StripeStore(tmp_path / "s", cfg)
    payload = np.random.default_rng(5).integers(
        0, 256, 80 * cfg.k * cfg.block_size, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    patterns = {store._down_blocks(sid) for sid in store.stripes
                if store._down_blocks(sid)}
    before = bitmatrix_expansions()
    tele = store.repair_all()
    assert tele["effective_backend"] == "crs"
    # chunking (batch_stripes=4 over 80 stripes) guarantees each pattern
    # group takes multiple launches — yet each pattern expands once
    assert tele["launches"] > len(patterns)
    assert bitmatrix_expansions() - before == len(patterns)


def test_eviction_counter_matches_cache_size_single_thread():
    """Deterministic counterpart: distinct patterns streamed through a
    small cache evict exactly (misses - maxsize) times."""
    scheme = make_scheme("cp-azure", 24, 2, 2)
    planner = RepairPlanner(scheme, maxsize=4)
    for b in range(12):
        planner.multi_plan(frozenset({b}))
    stats = planner.stats
    assert stats.misses == 12 and stats.hits == 0
    assert len(planner) == 4
    assert stats.evictions == 8
    with pytest.raises(RuntimeError):
        planner.multi_plan(frozenset(range(10)))   # > r+p: not decodable
