"""Degraded-read serving path: bit-identity, planning economy, caching,
coalescing telemetry, mid-read failure injection, and the front end.

The 1-device cases always run; the mesh-context cases run in the
forced-8-device CI leg (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import threading
import time

import jax
import numpy as np
import pytest

from _prop import given, settings, st
from repro.core.repair import single_repair_plan
from repro.ftx import (DegradedReadReport, StoreConfig, StripeStore,
                       read_report, repair_failed_nodes)
from repro.serve.blocks import BlockServer, zipf_requests
from repro.serve.telemetry import LatencyRecorder

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

SCHEMES = ("cp-azure", "cp-uniform")


def _build(root, *, scheme="cp-azure", stripes=12, block_size=256, **kw):
    cfg = StoreConfig(scheme=scheme, k=6, r=2, p=2, block_size=block_size,
                      pipeline_window=0, **kw)
    store = StripeStore(root, cfg)
    payload = np.random.default_rng(7).integers(
        0, 256, stripes * cfg.k * block_size, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


def _healthy(store):
    return {(sid, b): store.read(sid, b).tobytes()
            for sid in store.stripes for b in range(store.scheme.n)}


# ----------------------------------------------------------- bit-identity
@pytest.mark.parametrize("scheme", SCHEMES)
def test_single_failure_reads_bit_identical(tmp_path, scheme):
    store = _build(tmp_path / "s", scheme=scheme)
    truth = _healthy(store)
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    assert {k: store.read(*k).tobytes() for k in truth} == truth
    rep = read_report(store)
    assert rep.degraded_reads > 0 and rep.direct_reads > 0
    # Single failures repair at local-group bandwidth for every data and
    # local-parity block; only a lost cascade parity may need the global
    # tier (its cheapest recompute reads all k data blocks).
    assert rep.global_decodes <= 1
    assert rep.local_decode_fraction >= 0.9


@pytest.mark.parametrize("scheme", SCHEMES)
def test_double_failure_reads_bit_identical(tmp_path, scheme):
    store = _build(tmp_path / "s", scheme=scheme)
    truth = _healthy(store)
    read_report(store, reset=True)
    # Two data-block nodes: same-group stripes force the multi/global
    # fallback, cross-group stripes stay local — both must serve.
    store.fail_node(store.stripes[0].node_of_block[0])
    store.fail_node(store.stripes[0].node_of_block[1])
    assert {k: store.read(*k).tobytes() for k in truth} == truth
    rep = read_report(store)
    assert rep.degraded_reads > 0
    assert rep.decode_launches > 0


def test_unrecoverable_pattern_raises_ioerror(tmp_path):
    store = _build(tmp_path / "s", stripes=4)
    sid = next(iter(store.stripes))
    nodes = {store.stripes[sid].node_of_block[b]
             for b in range(store.scheme.r + store.cfg.p + 1)}
    for n in nodes:
        store.fail_node(n)
    down = store._down_blocks(sid)
    if len(down) <= store.scheme.r + store.cfg.p:
        pytest.skip("placement folded the failed nodes onto fewer blocks")
    with pytest.raises(IOError):
        store.read(sid, sorted(down)[0])


# ------------------------------------------------------- mesh context (CI)
@multidevice
@pytest.mark.parametrize("scheme", SCHEMES)
def test_degraded_reads_bit_identical_under_mesh(tmp_path, scheme):
    """Serving decodes issued inside an active 8-device mesh context return
    the same bytes (S=1 launches degrade to a single device — the
    divisibility rule — but must stay correct)."""
    from repro.dist.sharding import with_rules

    store = _build(tmp_path / "s", scheme=scheme)
    truth = _healthy(store)
    store.fail_node(store.stripes[0].node_of_block[0])
    store.fail_node(store.stripes[0].node_of_block[1])
    with with_rules(jax.make_mesh((8, 1), ("data", "model"))):
        got = {k: store.read(*k).tobytes() for k in truth}
    assert got == truth


# ------------------------------------------------ planning economy (prop)
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 5), st.sampled_from(SCHEMES))
def test_degraded_read_never_exceeds_planned_cost(block, scheme):
    """A cold degraded read touches exactly the chosen plan's source blocks,
    and for a single failure that plan never costs more than the paper's
    single-repair plan (local-group bandwidth, not k reads)."""
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        store = _build(f"{tmp}/s", scheme=scheme, stripes=4)
        sid = next(iter(store.stripes))
        store.fail_node(store.stripes[sid].node_of_block[block])
        down = store._down_blocks(sid)
        if block not in down:
            pytest.skip("another stripe's block landed on that node")
        plan = store.engine.planner.serving_plan(block, down)
        before = store.telemetry.blocks_read
        data = store.read(sid, block)
        touched = store.telemetry.blocks_read - before
        assert touched == plan.cost == len(plan.reads)
        assert plan.cost <= single_repair_plan(store.scheme, block).cost
        assert data.nbytes == store.cfg.block_size


def test_serving_plan_tiers_and_validation(tmp_path):
    store = _build(tmp_path / "s", stripes=2)
    planner = store.engine.planner
    # lone failure: a local-tier plan (group members, never a global decode)
    plan = planner.serving_plan(0, frozenset({0}))
    assert plan.meta.method in ("group", "recompute")
    assert plan.cost < store.scheme.k
    # block not in the down-set: ValueError
    with pytest.raises(ValueError):
        planner.serving_plan(1, frozenset({0}))
    # two data blocks of one local group down: no single-block candidate
    # survives the down-set, so the plan falls back to the flattened
    # multi-node decode — its targets cover the whole pattern and its reads
    # avoid every down block
    down = frozenset({0, 1})
    plan = planner.serving_plan(0, down)
    assert 0 in plan.targets
    assert not (set(plan.reads) & down)
    # repeated queries are pure cache hits
    before = planner.stats.snapshot()
    planner.serving_plan(0, down)
    after = planner.stats.snapshot()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]


# ------------------------------------------------------------- hot cache
def test_cache_hit_miss_and_eviction_bound(tmp_path):
    store = _build(tmp_path / "s", read_cache_blocks=2)
    store.fail_node(store.stripes[0].node_of_block[0])
    lost = [(sid, b) for sid in store.stripes
            for b in store._down_blocks(sid)]
    assert len(lost) > 2
    first = lost[0]
    store.read(*first)                      # miss -> decode
    store.read(*first)                      # hit
    t = store.telemetry
    assert t.cache_hits == 1 and t.cache_misses == 1
    assert t.serve_decode_launches == 1
    for key in lost:                         # stream past the capacity
        store.read(*key)
    assert len(store._hot_cache) <= 2        # LRU bound holds
    # evicted entries decode again rather than serving stale/absent data
    assert store.telemetry.serve_decode_launches >= len(lost) - 2


def test_cache_disabled_decodes_every_time(tmp_path):
    store = _build(tmp_path / "s", read_cache_blocks=0)
    store.fail_node(store.stripes[0].node_of_block[0])
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))
    for _ in range(3):
        store.read(sid, block)
    t = store.telemetry
    assert t.serve_decode_launches == 3
    assert t.cache_hits == 0


def test_repair_invalidates_cached_reconstructions(tmp_path):
    store = _build(tmp_path / "s")
    truth = _healthy(store)
    read_report(store, reset=True)
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    lost = [(sid, b) for sid in store.stripes
            for b in store._down_blocks(sid)]
    for key in lost:
        store.read(*key)                     # populate the hot cache
    assert len(store._hot_cache) == len(lost)  # default cap (64) holds all
    rep = repair_failed_nodes(store, [node])  # write-back invalidates
    assert rep.stripes_repaired > 0
    assert store.telemetry.cache_invalidations == len(lost)
    assert not any(k in store._hot_cache for k in lost)
    # post-repair reads are direct (node revived) and still bit-identical
    before = store.telemetry.direct_reads
    assert {k: store.read(*k).tobytes() for k in lost} == \
        {k: truth[k] for k in lost}
    assert store.telemetry.direct_reads == before + len(lost)


def test_multi_plan_fallback_caches_sibling_blocks(tmp_path):
    """When both failures share a local group, the multi-plan decode
    rebuilds the whole pattern in one launch; the sibling's first read must
    be a cache hit, not a second launch."""
    store = _build(tmp_path / "s")
    store.fail_node(store.stripes[0].node_of_block[0])
    store.fail_node(store.stripes[0].node_of_block[1])
    sid = 0
    down = sorted(store._down_blocks(sid))
    assert down == [0, 1]                   # same local group at (6,2,2)
    store.read(sid, down[0])
    launches = store.telemetry.serve_decode_launches
    store.read(sid, down[1])
    assert store.telemetry.serve_decode_launches == launches
    assert store.telemetry.cache_hits >= 1


# --------------------------------------------------------- read_range API
def test_read_range_slices_live_and_degraded(tmp_path):
    store = _build(tmp_path / "s")
    sid = next(iter(store.stripes))
    whole = store.read(sid, 0).tobytes()
    assert store.read_range(sid, 0, 10, 50).tobytes() == whole[10:50]
    store.fail_node(store.stripes[sid].node_of_block[0])
    assert store.read_range(sid, 0, 10, 50).tobytes() == whole[10:50]
    assert store.read_range(sid, 0).tobytes() == whole  # hi=None -> full


def test_read_api_validation(tmp_path):
    store = _build(tmp_path / "s", stripes=2)
    with pytest.raises(KeyError):
        store.read(999, 0)
    with pytest.raises(IndexError):
        store.read(0, store.scheme.n)
    with pytest.raises(ValueError):
        store.read_range(0, 0, 50, 10)
    with pytest.raises(ValueError):
        store.read_range(0, 0, 0, store.cfg.block_size + 1)


def test_served_bytes_counts_range_not_block(tmp_path):
    store = _build(tmp_path / "s", stripes=2)
    read_report(store, reset=True)
    store.read_range(0, 0, 0, 10)
    assert store.telemetry.served_bytes == 10
    store.fail_node(store.stripes[0].node_of_block[0])
    store.read_range(0, 0, 0, 10)
    assert store.telemetry.served_bytes == 20


# ------------------------------------------------- mid-read node failure
def test_node_death_between_plan_and_gather_replans(tmp_path):
    """A source node dying after plan selection surfaces as an IOError on
    the gather; the read re-plans against the fresh down-set and still
    returns correct bytes (mirrors the pipeline's mid-repair re-plan)."""
    store = _build(tmp_path / "s")
    truth = _healthy(store)
    read_report(store, reset=True)
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))
    plan = store.engine.planner.serving_plan(block, store._down_blocks(sid))
    victim_block = sorted(plan.reads)[0]
    victim_node = store.stripes[sid].node_of_block[victim_block]
    fired = []

    def hook(stage, s, b):
        if stage == "gather" and not fired:
            fired.append((s, b))
            store.fail_node(victim_node)    # dies between plan and gather

    store.read_hook = hook
    try:
        data = store.read(sid, block)
    finally:
        store.read_hook = None
    assert data.tobytes() == truth[(sid, block)]
    assert store.telemetry.serve_replans >= 1
    rep = read_report(store)
    assert rep.replans >= 1


def test_replan_gives_up_when_pattern_unrecoverable(tmp_path):
    store = _build(tmp_path / "s", stripes=4)
    node = store.stripes[0].node_of_block[0]
    store.fail_node(node)
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))

    def hook(stage, s, b):
        if stage == "gather":
            for n in range(store.num_nodes):   # kill everything mid-read
                store.fail_node(n)

    store.read_hook = hook
    try:
        with pytest.raises(IOError):
            store.read(sid, block)
    finally:
        store.read_hook = None


# ----------------------------------------------------- coalescing (serve)
def test_concurrent_reads_coalesce_to_one_launch(tmp_path):
    """8 threads race onto one lost block with the cache off: exactly one
    decode launch, 7 coalesced waiters, all bytes identical."""
    store = _build(tmp_path / "s", read_cache_blocks=0)
    truth = _healthy(store)
    read_report(store, reset=True)
    store.fail_node(store.stripes[0].node_of_block[0])
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))
    n_threads = 8
    gate = threading.Event()

    def hook(stage, s, b):
        if stage == "gather":
            gate.wait(timeout=30)           # hold the leader's decode ...

    store.read_hook = hook
    results = [None] * n_threads
    errors = []

    def reader(i):
        try:
            results[i] = store.read(sid, block).tobytes()
        except BaseException as e:          # pragma: no cover - diagnostics
            errors.append(e)
            gate.set()

    threads = [threading.Thread(target=reader, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    # ... until every follower has attached to the in-flight decode: the
    # leader (whichever thread won the registration race) is parked in the
    # hook, so once waiters == 7 all eight requests are accounted for.
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        entry = store._inflight.get((sid, block))
        if entry is not None and entry.waiters == n_threads - 1:
            break
        time.sleep(0.002)
    else:                                    # pragma: no cover - diagnostics
        gate.set()
        pytest.fail("followers never coalesced onto the in-flight decode")
    gate.set()
    for t in threads:
        t.join(timeout=60)
    store.read_hook = None
    assert not errors, errors
    assert all(r == truth[(sid, block)] for r in results)
    t = store.telemetry
    assert t.serve_decode_launches == 1
    assert t.coalesced_reads == n_threads - 1
    assert t.degraded_reads == n_threads
    assert not store._inflight                # future retired


def test_coalescing_disabled_launches_per_request(tmp_path):
    store = _build(tmp_path / "s", read_cache_blocks=0, coalesce_reads=False)
    store.fail_node(store.stripes[0].node_of_block[0])
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))
    results = BlockServer(store, clients=4).run([(sid, block)] * 8)
    assert len({r.tobytes() for r in results}) == 1
    assert store.telemetry.serve_decode_launches == 8
    assert store.telemetry.coalesced_reads == 0


def test_decode_error_propagates_to_waiters_and_retires_future(tmp_path):
    """A failing decode must release every coalesced waiter with the error
    and retire the in-flight entry so later reads start fresh."""
    store = _build(tmp_path / "s", read_cache_blocks=0)
    store.fail_node(store.stripes[0].node_of_block[0])
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))

    def hook(stage, s, b):
        if stage == "gather":
            for n in range(store.num_nodes):
                store.fail_node(n)

    store.read_hook = hook
    with pytest.raises(IOError):
        store.read(sid, block)
    store.read_hook = None
    assert not store._inflight
    for n in range(store.num_nodes):
        store.revive_node(n)
    store.fail_node(store.stripes[sid].node_of_block[block])
    assert store.read(sid, block).nbytes == store.cfg.block_size


# --------------------------------------------------- report + front end
def test_read_report_fields_and_reset(tmp_path):
    store = _build(tmp_path / "s")
    read_report(store, reset=True)
    store.fail_node(store.stripes[0].node_of_block[0])
    sid = next(s for s in store.stripes if store._down_blocks(s))
    block = next(iter(store._down_blocks(sid)))
    store.read(sid, block)
    store.read(sid, block)
    live = next(b for b in range(store.scheme.n)
                if b not in store._down_blocks(sid))
    store.read(sid, live)
    rep = read_report(store)
    assert isinstance(rep, DegradedReadReport)
    assert rep.direct_reads == 1 and rep.degraded_reads == 2
    assert rep.decode_launches == 1 and rep.cache_hits == 1
    assert rep.coalescing_ratio == 2.0
    assert rep.cache_hit_rate == 0.5
    assert rep.local_decode_fraction == 1.0
    assert rep.latency["count"] == 3
    assert rep.p99_ms >= rep.p50_ms >= 0.0
    assert rep.served_bytes == 3 * store.cfg.block_size
    # reset zeroes serving counters but not repair telemetry
    blocks_read = store.telemetry.blocks_read
    read_report(store, reset=True)
    assert store.telemetry.degraded_reads == 0
    assert store.telemetry.blocks_read == blocks_read
    assert store.read_latency.snapshot()["count"] == 0


def test_zipf_requests_deterministic_and_skewed(tmp_path):
    store = _build(tmp_path / "s")
    a = zipf_requests(store, 500, alpha=1.2, seed=9)
    b = zipf_requests(store, 500, alpha=1.2, seed=9)
    assert a == b                            # same seed, same stream
    assert a != zipf_requests(store, 500, alpha=1.2, seed=10)
    assert all(0 <= blk < store.cfg.k for _, blk in a)   # data pool only
    counts = {}
    for key in a:
        counts[key] = counts.get(key, 0) + 1
    top = max(counts.values())
    assert top >= 5 * (500 / (len(store.stripes) * store.cfg.k))  # skew
    full = zipf_requests(store, 100, block_pool="all")
    assert any(blk >= store.cfg.k for _, blk in full)
    with pytest.raises(ValueError):
        zipf_requests(store, 10, block_pool="bogus")


def test_block_server_preserves_order_and_latency(tmp_path):
    store = _build(tmp_path / "s")
    truth = _healthy(store)
    store.fail_node(store.stripes[0].node_of_block[0])
    requests = zipf_requests(store, 64, seed=3)
    server = BlockServer(store, clients=4)
    out = server.run(requests)
    assert [d.tobytes() for d in out] == [truth[k] for k in requests]
    assert server.latency.snapshot()["count"] == len(requests)
    timed = server.run(requests[:8], timed=True)
    assert all(dt >= 0.0 for _, dt in timed)
    assert server.report().degraded_reads >= 0
    with pytest.raises(ValueError):
        BlockServer(store, clients=0)


# ------------------------------------------------------ latency recorder
def test_latency_recorder_quantiles_and_ring():
    rec = LatencyRecorder(max_samples=64)
    assert rec.snapshot() == {"count": 0, "bytes": 0, "p50_ms": 0.0,
                              "p99_ms": 0.0, "mean_ms": 0.0, "max_ms": 0.0}
    for ms in range(1, 101):                 # 100 samples through a 64-ring
        rec.record(ms / 1e3, nbytes=10)
    snap = rec.snapshot()
    assert snap["count"] == 100 and snap["bytes"] == 1000
    # ring keeps the most recent 64 samples: 37..100 ms
    assert snap["max_ms"] == pytest.approx(100.0)
    assert snap["p50_ms"] == pytest.approx(68.5, abs=1.0)
    assert snap["p99_ms"] <= 100.0
    prev = rec.reset()
    assert prev["count"] == 100
    assert rec.snapshot()["count"] == 0


def test_latency_recorder_thread_safe_counts():
    rec = LatencyRecorder(max_samples=128)

    def worker(_):
        for _ in range(200):
            rec.record(0.001, nbytes=1)

    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(8) as pool:
        list(pool.map(worker, range(8)))
    snap = rec.snapshot()
    assert snap["count"] == 8 * 200 and snap["bytes"] == 8 * 200
