"""Serving substrate: the continuous-batching LLM engine and the degraded
block-read front end over erasure-coded stripe stores.

Attribute access is lazy (PEP 562): ``repro.serve.telemetry`` is imported
by the stripe store's hot read path, and must not drag the model stack
(``repro.serve.engine`` -> ``repro.models``) in with it.
"""
_LAZY = {
    "Request": "engine", "ServeEngine": "engine",
    "BlockServer": "blocks", "zipf_requests": "blocks",
    "LatencyRecorder": "telemetry",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
