"""Serving-path telemetry: thread-safe latency reservoirs with tail quantiles.

Import-light on purpose: the stripe store's read path records into a
:class:`LatencyRecorder` on every request, so this module must not drag the
model/serving stack (``repro.serve.engine``) in with it. Both serving front
ends — the LLM continuous-batching engine and the degraded block server —
share this recorder, so "p99" means the same thing on both paths.
"""
from __future__ import annotations

import threading

import numpy as np


class LatencyRecorder:
    """Bounded per-request latency reservoir with percentile queries.

    Keeps the most recent ``max_samples`` latencies in a ring buffer (old
    samples are overwritten — a serving tail metric should reflect recent
    traffic, not startup transients) plus exact lifetime counters for
    requests and bytes. All methods are thread-safe; ``record`` is O(1).
    """

    def __init__(self, max_samples: int = 8192):
        if max_samples <= 0:
            raise ValueError("max_samples must be positive")
        self.max_samples = max_samples
        self._buf = np.zeros(max_samples, np.float64)
        self._pos = 0
        self._filled = 0
        self.count = 0
        self.bytes = 0
        self._lock = threading.Lock()

    def record(self, seconds: float, nbytes: int = 0) -> None:
        """Record one request's wall latency (and bytes served, if any)."""
        with self._lock:
            self._buf[self._pos] = seconds
            self._pos = (self._pos + 1) % self.max_samples
            self._filled = min(self._filled + 1, self.max_samples)
            self.count += 1
            self.bytes += nbytes

    def _samples(self) -> np.ndarray:
        return self._buf[:self._filled].copy()

    def percentile(self, p: float) -> float:
        """The p-th latency percentile (seconds) over the retained window."""
        with self._lock:
            samples = self._samples()
        if samples.size == 0:
            return 0.0
        return float(np.percentile(samples, p))

    def snapshot(self) -> dict:
        """Counters plus p50/p99/mean/max over the retained window."""
        with self._lock:
            samples = self._samples()
            count, nbytes = self.count, self.bytes
        if samples.size == 0:
            return {"count": count, "bytes": nbytes, "p50_ms": 0.0,
                    "p99_ms": 0.0, "mean_ms": 0.0, "max_ms": 0.0}
        return {
            "count": count,
            "bytes": nbytes,
            "p50_ms": float(np.percentile(samples, 50)) * 1e3,
            "p99_ms": float(np.percentile(samples, 99)) * 1e3,
            "mean_ms": float(samples.mean()) * 1e3,
            "max_ms": float(samples.max()) * 1e3,
        }

    def reset(self) -> dict:
        """Snapshot, then clear the window and counters."""
        snap = self.snapshot()
        with self._lock:
            self._pos = self._filled = 0
            self.count = self.bytes = 0
        return snap
