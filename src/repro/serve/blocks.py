"""Degraded-read front end: multi-client block serving over a stripe store.

A thin serving layer over ``StripeStore.read``/``read_range`` (which owns
the reconstruction, coalescing and caching — DESIGN.md §10): this module
adds the *client* side — a thread pool standing in for concurrent readers,
per-request wall-latency recording into a shared
:class:`~repro.serve.telemetry.LatencyRecorder`, and the Zipfian request
generator the tail-latency experiments drive it with. The point of the
split: N front-end clients hammering one lost block must collapse onto one
decode launch *inside* the store, so any number of front ends stay correct
by construction.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np

from .telemetry import LatencyRecorder


class BlockServer:
    """Concurrent block-read front end over one stripe store.

    ``read`` serves a single request synchronously; ``run`` replays a
    request stream through ``clients`` worker threads — the multi-client
    load shape of a production object store, where many readers race onto
    the same hot lost block. Front-end latency (queueing + store time)
    lands in ``latency``; the store's own counters stay the source of truth
    for coalescing/cache behavior (``repro.ftx.read_report``).
    """

    def __init__(self, store, clients: int = 8,
                 latency: Optional[LatencyRecorder] = None):
        if clients < 1:
            raise ValueError("need at least one client thread")
        self.store = store
        self.clients = clients
        self.latency = latency if latency is not None else LatencyRecorder()

    def read(self, sid: int, block: int, lo: int = 0,
             hi: Optional[int] = None) -> np.ndarray:
        t0 = time.perf_counter()
        data = self.store.read_range(sid, block, lo, hi)
        self.latency.record(time.perf_counter() - t0, int(data.size))
        return data

    def run(self, requests: Sequence[tuple],
            timed: bool = False) -> list:
        """Serve ``(sid, block)`` (or ``(sid, block, lo, hi)``) requests
        across the client pool; responses come back in request order.
        ``timed=True`` returns ``(data, seconds)`` pairs so load generators
        can split tail latency by request class (e.g. degraded vs live)."""

        def one(rq):
            t0 = time.perf_counter()
            data = self.read(*rq)
            return (data, time.perf_counter() - t0) if timed else data

        with ThreadPoolExecutor(self.clients) as pool:
            return list(pool.map(one, requests))

    def report(self):
        """The store-side :class:`~repro.ftx.DegradedReadReport`."""
        from repro.ftx.fleet import read_report

        return read_report(self.store)


def zipf_requests(store, num_requests: int, *, alpha: float = 1.1,
                  seed: int = 0,
                  block_pool: str = "data") -> list[tuple[int, int]]:
    """A Zipfian ``(sid, block)`` request stream over a store's stripes.

    Block popularity follows ``rank^-alpha`` over the pool of addressable
    blocks (``"data"`` restricts to the k data blocks per stripe — the
    object-serving shape — ``"all"`` includes parities); ranks are assigned
    by a seeded shuffle so the hot set spreads across stripes and nodes
    instead of clustering on stripe 0. Deterministic for a given
    ``(store contents, num_requests, alpha, seed)``, which is what lets the
    benchmark gate *counts* (coalescing ratio, local fraction) rather than
    timings.
    """
    if block_pool not in ("data", "all"):
        raise ValueError(f"unknown block_pool {block_pool!r}")
    width = store.cfg.k if block_pool == "data" else store.scheme.n
    pairs = [(sid, b) for sid in sorted(store.stripes) for b in range(width)]
    if not pairs:
        raise ValueError("store has no sealed stripes to read")
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(len(pairs))
    weights = 1.0 / (1.0 + ranks.astype(np.float64)) ** alpha
    weights /= weights.sum()
    picks = rng.choice(len(pairs), size=num_requests, p=weights)
    return [pairs[i] for i in picks]
