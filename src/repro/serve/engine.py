"""Continuous-batching serve engine.

A fixed pool of ``max_batch`` slots over one shared, preallocated KV cache:

* ``submit`` queues requests;
* each ``step()`` admits queued requests into free slots (prefill computes
  the prompt's cache row-block and writes it into the slot) and then runs
  ONE decode step for all live slots (per-slot position indices);
* finished requests (EOS or max_new) free their slots immediately — the
  classic continuous-batching schedule.

Single-host demo engine: it drives the same jitted prefill/decode_step the
dry run lowers for the 512-chip mesh, at smoke scale on CPU.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks
from repro.models.registry import ModelApi

from .telemetry import LatencyRecorder


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int = 16
    eos_id: Optional[int] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0   # perf_counter at submit; feeds latency p50/p99


class ServeEngine:
    def __init__(self, api: ModelApi, max_batch: int = 4, max_len: int = 512):
        if api.cfg.family == "encdec":
            raise NotImplementedError("engine demo targets decoder-only archs")
        self.api = api
        self.cfg = api.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = None
        self.caches = None
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.lengths = np.zeros(max_batch, np.int32)
        self._rid = itertools.count()
        self._decode = jax.jit(api.decode_step)
        self._prefill = jax.jit(api.prefill)
        # Submit-to-completion wall latency per request — the same recorder
        # (and so the same p50/p99 meaning) as the degraded block-read
        # serving path (repro.serve.telemetry).
        self.latency = LatencyRecorder()

    def load(self, params) -> None:
        self.params = params
        self.caches = self.api.init_caches(self.cfg, self.max_batch,
                                           self.max_len)

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               eos_id: Optional[int] = None) -> Request:
        req = Request(rid=next(self._rid), prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, eos_id=eos_id,
                      submitted_at=time.perf_counter())
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if self.cfg.frontend != "none":
                batch["prefix_embeds"] = jnp.zeros(
                    (1, self.cfg.frontend_tokens, self.cfg.d_model),
                    jnp.bfloat16)
            logits, row_caches = self._prefill(self.params, batch)
            row_caches = blocks.pad_caches(row_caches, self.cfg, self.max_len)
            self.caches = _write_slot(self.caches, row_caches, slot)
            self.slots[slot] = req
            off = (self.cfg.frontend_tokens
                   if self.cfg.frontend != "none" else 0)
            self.lengths[slot] = len(req.prompt) + off
            first = int(jnp.argmax(logits[0, -1]))
            req.out_tokens.append(first)

    def step(self) -> int:
        """Admit + one decode step for all live slots; returns #live."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i in live:
            tokens[i, 0] = self.slots[i].out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.lengths))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for i in live:
            req = self.slots[i]
            self.lengths[i] += 1
            req.out_tokens.append(int(nxt[i]))
            if (len(req.out_tokens) >= req.max_new
                    or (req.eos_id is not None and nxt[i] == req.eos_id)
                    or self.lengths[i] >= self.max_len - 1):
                req.done = True
                self.slots[i] = None
                self.latency.record(time.perf_counter() - req.submitted_at,
                                    len(req.out_tokens))
        return len(live)

    def latency_stats(self) -> dict:
        """p50/p99/mean submit-to-completion latency over finished requests."""
        return self.latency.snapshot()

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()


def _write_slot(caches, row_caches, slot: int):
    """Copy a prefilled single-row cache into batch slot ``slot``."""

    def write(dst, src):
        if dst.ndim >= 3 and src.shape[0] == dst.shape[0]:
            length = min(src.shape[2], dst.shape[2]) if dst.ndim >= 3 else 0
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))
        return dst

    return jax.tree.map(write, caches, row_caches)
