"""Stripe-axis sharding: scale the batched codec engine across devices.

The batched codec engine executes ``coeffs (m, t) @ batch (S, t, B)`` with a
stripe grid axis. Stripes are independent — no cross-stripe terms exist in
any codec operation — so the stripe axis ``S`` is embarrassingly parallel:
this module resolves it onto the mesh's data-parallel axes (the "stripes"
logical axis, ``("data", "pod")`` by default) and wraps the kernel in a
``shard_map`` so each device runs one launch over its local ``S/D`` shard.

Degradation mirrors ``repro.dist.sharding._resolve``: an ``S`` that the data
axis does not divide falls back to a single-device launch (bit-identical
either way — GF(2^8) arithmetic is exact, so partitioning never changes
results, only wall-clock).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 promotes shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from .sharding import MeshRules, _resolve


def _shard_map(body, mesh, in_specs, out_specs):
    # check_rep=False: pallas_call has no replication rule, and the stripe
    # launch needs none (coeffs replicate, everything else shards on S).
    # Newer jax renamed/removed the kwarg; fall back to defaults there.
    try:
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)


def stripe_spec(shape, mr: MeshRules) -> P:
    """PartitionSpec sharding axis 0 (stripes) of an ``(S, ...)`` batch.

    Args:
        shape: the batch shape; only ``shape[0]`` (the stripe count S)
            participates in resolution, trailing dims always replicate.
        mr: active mesh + rules; the "stripes" logical axis resolves onto
            its data-parallel axes with divisibility degradation.

    Returns:
        A spec like ``P(("data",), None, ...)``, or ``P(None, ...)`` when
        the stripe axis degrades (indivisible S / no candidate axes).
    """
    names = ("stripes",) + (None,) * (len(shape) - 1)
    return _resolve(shape, names, mr)


def stripe_sharding(shape, mr: MeshRules) -> NamedSharding:
    """:func:`stripe_spec` bound to ``mr``'s mesh as a ``NamedSharding`` —
    the layout both the sharded launch and the per-shard gather geometry
    (``repro.dist.placement.shard_layout``) derive from."""
    return NamedSharding(mr.mesh, stripe_spec(shape, mr))


def stripe_axis_span(mr: Optional[MeshRules]) -> int:
    """Device count the "stripes" logical axis *can* claim on ``mr``'s mesh
    (the product of its candidate axes present in the mesh), independent of
    any particular batch size. 1 with no rules or no candidate axes."""
    if mr is None:
        return 1
    sizes = dict(mr.mesh.shape)
    span = 1
    for ax in dict.fromkeys(mr.axes_for("stripes")):
        span *= sizes.get(ax, 1)
    return span


def align_stripe_window(window: int, mr: Optional[MeshRules]) -> int:
    """Largest window' <= ``window`` divisible by the stripe-axis device
    span, so windowed launches keep their full device parallelism instead of
    degrading to one device on an indivisible S. Windows smaller than the
    span are returned unchanged (they degrade, matching ragged-tail
    semantics elsewhere)."""
    span = stripe_axis_span(mr)
    if span <= 1 or window < span:
        return window
    return (window // span) * span


def stripe_span(shape, mr: Optional[MeshRules]) -> int:
    """How many devices an ``(S, ...)`` batch spreads over (1 = degraded).

    Unlike :func:`stripe_axis_span` this accounts for the *batch*: an S the
    stripe axis does not divide resolves to ``None`` and returns 1. The
    scheduler (``repro.dist.schedule``) and the gather layout both key off
    this value, so "will this launch shard?" has one answer everywhere.
    """
    if mr is None:
        return 1
    entry = stripe_spec(shape, mr)[0] if len(shape) else None
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    sizes = dict(mr.mesh.shape)
    span = 1
    for ax in axes:
        span *= sizes[ax]
    return span


@functools.lru_cache(maxsize=128)
def _mapped(fn: Callable, mesh, spec: P, coef_ndim: int,
            kwargs_items: tuple) -> Callable:
    """jit(shard_map(fn)) cache keyed on (fn, mesh, spec, static kwargs).

    ``fn`` must be a module-level function (stable identity) taking
    ``(coeffs, batch, **kwargs)``; coeffs replicate, the batch shards on
    axis 0, and the output inherits the batch's spec.
    """
    kwargs = dict(kwargs_items)

    def body(coeffs, batch):
        return fn(coeffs, batch, **kwargs)

    return jax.jit(_shard_map(
        body, mesh,
        in_specs=(P(*([None] * coef_ndim)), spec),
        out_specs=spec))


def _matches(batch, sharding: NamedSharding) -> bool:
    """Is ``batch`` already laid out shard-for-shard as ``sharding``?"""
    if not isinstance(batch, jax.Array):
        return False
    try:
        return batch.sharding.is_equivalent_to(sharding, batch.ndim)
    except TypeError:                     # older signature
        return batch.sharding == sharding


def sharded_launch(fn: Callable, coeffs, batch, mr: Optional[MeshRules],
                   **kwargs):
    """Run ``fn(coeffs, batch, **kwargs)`` as one device-parallel launch.

    With no rules, or when the stripe axis degrades (indivisible ``S`` or a
    trivial mesh), falls through to a plain single-device call. ``kwargs``
    must be hashable (they key the jit cache).

    ``batch`` may arrive three ways, cheapest first:

    * a global ``jax.Array`` already sharded as the stripe spec resolves
      (e.g. assembled per shard by ``repro.dist.placement.assemble_shards``)
      — consumed with **zero re-transfer**;
    * a host ``numpy`` array — scattered shard-by-shard with one
      ``device_put`` onto the target sharding (no device-0 bounce);
    * anything else (including a single-device ``jax.Array``) — resharded
      by ``device_put`` onto the stripe sharding.
    """
    import jax.numpy as jnp

    if stripe_span(batch.shape, mr) <= 1:
        return fn(coeffs, jnp.asarray(batch, jnp.uint8), **kwargs)
    spec = stripe_spec(batch.shape, mr)
    sharding = NamedSharding(mr.mesh, spec)
    if not _matches(batch, sharding):
        batch = jax.device_put(batch, sharding)
    mapped = _mapped(fn, mr.mesh, spec, coeffs.ndim,
                     tuple(sorted(kwargs.items())))
    return mapped(coeffs, batch)
