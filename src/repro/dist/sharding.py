"""Logical-axis sharding: rules, resolution and degradation.

Models annotate parameters and activations with *logical* axis names
("batch", "heads", "ff", ...). This module owns the mapping from logical
names to mesh axes and resolves it per-tensor with two safety rules:

* **Divisibility degradation** — a mesh axis is only assigned to a dimension
  it divides evenly; otherwise the dimension silently replicates (``None``).
  This is what lets one set of rules serve every config: grok's 8 experts on
  a 16-way "model" axis degrade to replicated experts (the expert FFNs then
  tensor-parallel-shard over the freed axis), arctic's odd head counts
  replicate, smoke configs on a 1x1 host mesh resolve to trivial specs.
* **Each mesh axis at most once per spec** — GSPMD rejects duplicate mesh
  axes within one ``PartitionSpec``; the first (leftmost) logical dimension
  that can legally claim an axis wins, later claimants degrade.

``with_rules(mesh, overrides)`` installs a :class:`MeshRules` as the ambient
context so deep model code can call :func:`shard_activation` without
threading a handle through every layer; outside any context it is a no-op,
which is how the single-process smoke tests run the exact production model
code without a mesh.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Iterator, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical-axis -> candidate mesh axes, tried left to right. Absent, claimed
# or indivisible axes are skipped (degradation); an empty tuple is an inert
# axis that only shards when a rule override maps it somewhere (e.g. the
# dry-run maps "kv_seq" -> ("data",) for batch=1 long-context cells, and the
# perf harness maps "seq" -> ("model",) for sequence-parallel activations).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("data", "pod"),
    "stripes": ("data", "pod"),
    "seq": (),
    "kv_seq": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "expert_ff": ("model",),
    "inner": ("model",),
    "vocab": ("model",),
}

# Data-parallel axes used by the ZeRO/FSDP extension (opt_state_sharding).
DATA_AXES = ("data", "pod")


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """A mesh plus the active logical-axis -> mesh-axes rules."""
    mesh: Mesh
    rules: Mapping[str, tuple[str, ...]]

    def axes_for(self, name: Optional[str]) -> tuple[str, ...]:
        if name is None:
            return ()
        return self.rules.get(name, ())


_ACTIVE: contextvars.ContextVar[Optional[MeshRules]] = contextvars.ContextVar(
    "repro_dist_mesh_rules", default=None)


def _normalize(overrides: Optional[Mapping]) -> dict[str, tuple[str, ...]]:
    out: dict[str, tuple[str, ...]] = {}
    for name, axes in (overrides or {}).items():
        if axes is None:
            axes = ()
        elif isinstance(axes, str):
            axes = (axes,)
        out[name] = tuple(axes)
    return out


@contextlib.contextmanager
def with_rules(mesh: Mesh, overrides: Optional[Mapping] = None
               ) -> Iterator[MeshRules]:
    """Install ``mesh`` + (DEFAULT_RULES | overrides) as the ambient context.

    Yields the :class:`MeshRules`, which every resolution helper takes
    explicitly; :func:`shard_activation` picks it up implicitly.
    """
    mr = MeshRules(mesh=mesh, rules={**DEFAULT_RULES, **_normalize(overrides)})
    token = _ACTIVE.set(mr)
    try:
        yield mr
    finally:
        _ACTIVE.reset(token)


def current_rules() -> Optional[MeshRules]:
    """The ambient MeshRules, or None outside any ``with_rules`` block."""
    return _ACTIVE.get()


def _resolve(shape: Sequence[int], names: Sequence[Optional[str]],
             mr: MeshRules) -> P:
    """Logical names -> PartitionSpec under ``mr`` with degradation.

    Per dimension, candidate mesh axes are tried in rule order; an axis is
    assigned only if it exists in the mesh, is not already claimed by an
    earlier dimension of this spec, and evenly divides what remains of the
    dimension after earlier assignments. Multiple surviving axes for one
    dimension become a tuple entry; zero become ``None`` (replicate).
    """
    axis_sizes = dict(mr.mesh.shape)
    used: set[str] = set()
    entries: list = []
    for dim, name in zip(shape, names):
        picked: list[str] = []
        remaining = int(dim)
        for ax in mr.axes_for(name):
            size = axis_sizes.get(ax)
            if size is None or ax in used or remaining % size != 0:
                continue
            picked.append(ax)
            used.add(ax)
            remaining //= size
        entries.append(picked[0] if len(picked) == 1
                       else tuple(picked) if picked else None)
    return P(*entries)


def shard_activation(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain an activation to the resolved spec of ``names``.

    Reads the ambient :class:`MeshRules`; with none active (unit tests, the
    serve engine without a mesh) it returns ``x`` untouched, so model code is
    unconditional.
    """
    mr = _ACTIVE.get()
    if mr is None:
        return x
    spec = _resolve(x.shape, names, mr)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mr.mesh, spec))


def opt_state_sharding(spec: P, shape: Sequence[int], mr: MeshRules
                       ) -> NamedSharding:
    """ZeRO/FSDP extension: spread free data-parallel axes over ``spec``.

    Optimizer moments (and FSDP'd parameters) replicate along whatever the
    parameter spec leaves unsharded; this assigns the mesh's unclaimed
    data axes (:data:`DATA_AXES`) to the largest still-replicated divisible
    dimension, largest dimension first, so the f32 moments of giant configs
    spread over the full device count instead of living whole on every chip.
    """
    axis_sizes = dict(mr.mesh.shape)
    entries: list = list(spec) + [None] * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    used = {ax for e in entries if e is not None
            for ax in ((e,) if isinstance(e, str) else tuple(e))}
    free = [ax for ax in DATA_AXES if ax in axis_sizes and ax not in used]
    for i in sorted((i for i, e in enumerate(entries) if e is None),
                    key=lambda i: -int(shape[i])):
        if not free:
            break
        picked, remaining = [], int(shape[i])
        for ax in list(free):
            if remaining % axis_sizes[ax] != 0:
                continue
            picked.append(ax)
            free.remove(ax)
            remaining //= axis_sizes[ax]
        if picked:
            entries[i] = picked[0] if len(picked) == 1 else tuple(picked)
    return NamedSharding(mr.mesh, P(*entries))
