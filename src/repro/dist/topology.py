"""Topology layer: nodes, failure domains, and block-placement policies.

The paper's repair-time wins assume repair reads come from *nearby*
survivors; whether they do is decided by **placement policy**, not code
structure — the lesson of the copyset/failure-domain analysis in *XORing
Elephants* (Sathiamoorthy et al.) and the locality framing of *Locally
Repairable Codes* (Papailiopoulos & Dimakis). This module makes that policy
pluggable:

* :class:`Topology` describes the physical fleet: ``num_nodes`` storage
  nodes grouped into ``num_domains`` failure domains (racks / hosts).
  Domains are contiguous equal node ranges — the same node->shard geometry
  :meth:`~repro.dist.placement.PlacementMap.from_store` derives — so a
  domain doubles as the *gather shard* that serves a device slice during
  sharded repair.
* :func:`place_stripe` maps a stripe's ``n`` blocks onto nodes under one of
  three policies (:data:`POLICIES`):

  - ``"contiguous"`` — a rotated arc of ``n`` consecutive nodes (the stripe
    store's seed behavior, stride 7). Every stripe of a pattern group lands
    on the *same* arc, so repair locality is whatever the arc's overlap
    with the reading domain happens to be.
  - ``"round_robin"`` — blocks round-robin across failure domains (classic
    "one replica per rack"): maximal failure-domain dispersion, which also
    means every repair read set spreads over ~all domains and no scheduler
    can make it local.
  - ``"spread"`` — copyset-style: each stripe picks a small seeded-random
    set of domains (``spread_width``) and scatters its blocks over their
    nodes. Bounds the number of distinct copysets (the XORing-Elephants
    correlated-failure argument) *and* concentrates each stripe's repair
    reads in few domains — the skewed scenario where locality-aware stripe
    scheduling (``repro.dist.schedule``) pays.

* :func:`placement_from_topology` turns a topology + a live stripe store
  into the :class:`~repro.dist.placement.PlacementMap` the repair read
  stack consumes (node->shard from the domains, block->node from the
  store's stripe index, remote cost from the store config).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .placement import PlacementMap

# Recognized block-placement policies, in increasing dispersion order of a
# single stripe's blocks across failure domains: arc < copyset < per-block.
POLICIES = ("contiguous", "spread", "round_robin")

# The seed stripe store's arc stride: coprime to typical node counts, so
# consecutive stripes rotate their arcs and parities spread across nodes.
_ARC_STRIDE = 7


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fleet of storage nodes grouped into failure domains.

    Args:
        num_nodes: total storage nodes (the stripe store's virtual nodes).
        num_domains: failure domains (racks / hosts). Nodes are assigned to
            domains in contiguous equal ranges: node ``i`` lives in domain
            ``i * num_domains // num_nodes`` — the same contiguous geometry
            the placement layer's default node->shard map uses, so a domain
            is also the gather shard serving a device slice.
        spread_width: how many domains the ``"spread"`` policy lets one
            stripe touch (widened automatically when the chosen domains
            hold fewer than ``n`` nodes).
        seed: seeds the ``"spread"`` policy's per-stripe domain choice.
    """
    num_nodes: int
    num_domains: int = 1
    spread_width: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.num_nodes < 1:
            raise ValueError("topology needs at least one node")
        if not 1 <= self.num_domains <= self.num_nodes:
            raise ValueError(
                f"num_domains must be in [1, {self.num_nodes}], "
                f"got {self.num_domains}")
        if self.spread_width < 1:
            raise ValueError("spread_width must be >= 1")

    def domain_of(self, node: int) -> int:
        """Failure domain of ``node`` (contiguous equal ranges)."""
        return node * self.num_domains // self.num_nodes

    # Rack vocabulary: the reliability simulator (``repro.sim``) models a
    # disk/node/rack unit hierarchy; its racks ARE this topology's failure
    # domains (one correlated-failure blast radius per domain), so the same
    # Topology object drives placement, gather sharding, and fleet
    # simulation without a parallel geometry.
    @property
    def num_racks(self) -> int:
        """Racks for the unit hierarchy — identical to ``num_domains``."""
        return self.num_domains

    def rack_of(self, node: int) -> int:
        """Rack of ``node`` — identical to :meth:`domain_of`."""
        return self.domain_of(node)

    def nodes_by_rack(self) -> list[list[int]]:
        """Node ids grouped by rack (= failure domain), ascending."""
        return [self.nodes_in(d) for d in range(self.num_domains)]

    def nodes_in(self, domain: int) -> list[int]:
        """All node ids in ``domain``, ascending."""
        n, d = self.num_nodes, self.num_domains
        lo = -(-domain * n // d)            # ceil(domain * n / d)
        hi = -(-(domain + 1) * n // d)
        return list(range(lo, hi))

    def shard_of_node(self) -> tuple[int, ...]:
        """node id -> domain id, as the tuple ``PlacementMap`` consumes."""
        return tuple(self.domain_of(i) for i in range(self.num_nodes))


def place_stripe(policy: str, topo: Topology, sid: int, n: int) -> list[int]:
    """Place stripe ``sid``'s ``n`` blocks onto nodes under ``policy``.

    Args:
        policy: one of :data:`POLICIES`.
        topo: the fleet topology.
        sid: stripe id (drives rotation / the seeded domain choice).
        n: blocks per stripe (``k + p + r``).

    Returns:
        ``n`` distinct node ids, indexed by block. Deterministic in
        ``(policy, topo, sid, n)`` — re-running a placement is a pure
        function, so manifests and twin stores reproduce exactly.

    Raises:
        ValueError: unknown policy, or ``n`` exceeds the available nodes.
    """
    if n > topo.num_nodes:
        raise ValueError(f"cannot place {n} blocks on {topo.num_nodes} nodes")
    if policy == "contiguous":
        base = (sid * _ARC_STRIDE) % topo.num_nodes
        return [(base + i) % topo.num_nodes for i in range(n)]
    if policy == "round_robin":
        return _place_round_robin(topo, sid, n)
    if policy == "spread":
        return _place_spread(topo, sid, n)
    raise ValueError(f"unknown placement policy {policy!r} "
                     f"(choose from {', '.join(POLICIES)})")


def _place_round_robin(topo: Topology, sid: int, n: int) -> list[int]:
    """One block per domain, cycling: block ``b`` -> domain
    ``(sid + b) % D`` (rotated per stripe), node rotated within the domain.
    Skips full domains so uneven domain sizes still place ``n`` distinct
    nodes."""
    d_count = topo.num_domains
    pools = [topo.nodes_in(d) for d in range(d_count)]
    used = [0] * d_count
    out: list[int] = []
    for b in range(n):
        d = (sid + b) % d_count
        for off in range(d_count):          # first domain with spare nodes
            dd = (d + off) % d_count
            if used[dd] < len(pools[dd]):
                d = dd
                break
        nodes = pools[d]
        out.append(nodes[(sid + used[d]) % len(nodes)])
        used[d] += 1
    # used[d] consecutive ring offsets per domain => distinct within the
    # domain; domains partition nodes => distinct overall.
    return out


def _place_spread(topo: Topology, sid: int, n: int) -> list[int]:
    """Copyset-style: a seeded per-stripe choice of ``spread_width`` domains
    (widened until they hold ``n`` nodes), blocks sampled without
    replacement from their pooled nodes."""
    rng = np.random.default_rng([topo.seed, sid])
    order = rng.permutation(topo.num_domains)
    pool: list[int] = []
    taken = 0
    for d in order:
        pool.extend(topo.nodes_in(int(d)))
        taken += 1
        if taken >= topo.spread_width and len(pool) >= n:
            break
    sel = rng.choice(len(pool), size=n, replace=False)
    return [pool[int(i)] for i in sel]


def placement_ok(policy: str, topo: Topology, nodes: list[int], *,
                 width: Optional[int] = None) -> bool:
    """Does a stripe's block->node list satisfy ``policy``'s invariants?

    The shared legality check behind rebuild-destination selection
    (:func:`pick_destinations`), the rebalancer's move filter
    (``repro.ftx.rebalance``), and the post-repair property tests:

    * every policy: the ``n`` nodes are distinct;
    * ``"round_robin"``: no failure domain holds more than
      ``ceil(n / num_domains)`` of the stripe's blocks (the one-block-
      per-domain dispersion bound, generalized to ``n > num_domains``);
    * ``"spread"``: the stripe touches at most ``width`` distinct domains
      (the copyset width bound; defaults to the larger of
      ``topo.spread_width`` and the fewest domains whose pooled nodes can
      hold ``n`` blocks — the same widening ``_place_spread`` applies);
    * ``"contiguous"``: no constraint beyond distinctness (arcs are a
      write-time layout, not a durability invariant).
    """
    if len(set(nodes)) != len(nodes):
        return False
    if policy == "round_robin":
        per: dict[int, int] = {}
        for n in nodes:
            d = topo.domain_of(n)
            per[d] = per.get(d, 0) + 1
        return max(per.values()) <= -(-len(nodes) // topo.num_domains)
    if policy == "spread":
        if width is None:
            sizes = sorted((len(topo.nodes_in(d))
                            for d in range(topo.num_domains)), reverse=True)
            need, pooled = 0, 0
            while pooled < len(nodes) and need < len(sizes):
                pooled += sizes[need]
                need += 1
            width = max(topo.spread_width, need)
        return len({topo.domain_of(n) for n in nodes}) <= width
    return True


def pick_destinations(topo: Topology, policy: str,
                      placements: dict[int, list[int]],
                      lost, alive,
                      loads: Optional[dict[int, int]] = None
                      ) -> dict[tuple[int, int], int]:
    """Choose a surviving home for every lost block, least-loaded first.

    The rebuild-destination policy (DESIGN.md §14): instead of writing a
    rebuilt block back to its dead node's address, place it on an *alive*
    node of the least-loaded surviving failure domain — ranked so the
    placement policy's invariants are preserved, not just node
    distinctness:

    * ``"spread"`` prefers domains the stripe already occupies (by
      surviving blocks or already-chosen destinations), so the copyset
      width does not grow while any occupied domain still has capacity;
    * ``"round_robin"`` prefers the domains holding the *fewest* of the
      stripe's blocks, so the per-domain dispersion bound is maintained;
    * ``"contiguous"`` ranks purely by domain load.

    Within the chosen domain the least-loaded alive node not already used
    by the stripe wins; ties break on the lower id, so the result is
    deterministic in ``(topo, policy, placements, lost, alive, loads)``.
    Domain load is the mean resident-block count per *alive* member node.
    A block with no legal destination (every alive node already used by
    its stripe) is omitted — the caller writes it back in place.

    Args:
        topo: the fleet topology.
        policy: the store's placement policy (one of :data:`POLICIES`).
        placements: ``sid -> node_of_block`` pre-repair snapshot for every
            affected stripe.
        lost: ``(sid, block)`` pairs needing a new home.
        alive: ids of UP nodes (destination candidates).
        loads: resident-block count per node
            (``repro.dist.placement.block_loads``); defaults to loads over
            ``placements`` only. Not mutated; assignment updates are
            tracked on a copy so later picks see earlier ones.

    Returns:
        ``{(sid, block): node}`` for every block that found a legal
        surviving destination.
    """
    from .placement import block_loads

    alive = set(alive)
    lost = sorted(set(lost))
    if loads is None:
        loads = block_loads(placements.values(), topo.num_nodes)
    loads = dict(loads)
    lost_by_sid: dict[int, set[int]] = {}
    for sid, b in lost:
        lost_by_sid.setdefault(sid, set()).add(b)
    members = {d: [n for n in topo.nodes_in(d) if n in alive]
               for d in range(topo.num_domains)}

    out: dict[tuple[int, int], int] = {}
    for sid, block in lost:
        nodes = placements[sid]
        # Nodes this stripe occupies: survivors of non-lost blocks plus
        # destinations already chosen for sibling lost blocks.
        used = {n for i, n in enumerate(nodes)
                if i not in lost_by_sid[sid]}
        used |= {out[(sid, b)] for b in lost_by_sid[sid]
                 if (sid, b) in out}
        occupancy: dict[int, int] = {}
        for n in used:
            d = topo.domain_of(n)
            occupancy[d] = occupancy.get(d, 0) + 1

        def usable(d: int) -> list[int]:
            return [n for n in members[d] if n not in used]

        def load_of(d: int) -> float:
            pool = members[d]
            return (sum(loads.get(n, 0) for n in pool) / len(pool)
                    if pool else float("inf"))

        cands = [d for d in range(topo.num_domains) if usable(d)]
        if not cands:
            continue                        # no legal home: stay in place
        def key(d: int) -> tuple:
            if policy == "round_robin":
                return (occupancy.get(d, 0), load_of(d), d)
            if policy == "spread":
                return (occupancy.get(d, 0) == 0, load_of(d), d)
            return (load_of(d), d)

        dom = min(cands, key=key)
        node = min(usable(dom), key=lambda n: (loads.get(n, 0), n))
        out[(sid, block)] = node
        loads[node] = loads.get(node, 0) + 1
        old = nodes[block]
        loads[old] = max(0, loads.get(old, 0) - 1)
    return out


def placement_from_topology(store, topo: Topology,
                            remote_multiplier: Optional[float] = None
                            ) -> PlacementMap:
    """The :class:`~repro.dist.placement.PlacementMap` of ``store`` under
    ``topo``: node->shard from the topology's failure domains, block->node
    from the store's live stripe index.

    Args:
        store: a ``repro.ftx.StripeStore`` whose ``num_nodes`` matches the
            topology.
        topo: the fleet topology (domains become gather shards).
        remote_multiplier: simulated link-time cost of a cross-domain read;
            defaults to ``store.cfg.remote_read_multiplier``.

    Returns:
        A ``PlacementMap`` resolving ``(sid, block)`` through the store —
        it tracks placement changes (e.g. spare remapping) live.
    """
    if topo.num_nodes != store.num_nodes:
        raise ValueError(f"topology has {topo.num_nodes} nodes, "
                         f"store has {store.num_nodes}")
    if remote_multiplier is None:
        remote_multiplier = getattr(store.cfg, "remote_read_multiplier", 1.0)
    return PlacementMap(
        shard_of_node=topo.shard_of_node(),
        remote_multiplier=float(remote_multiplier),
        node_of=lambda sid, b: store.stripes[sid].node_of_block[b])
