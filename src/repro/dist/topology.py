"""Topology layer: nodes, failure domains, and block-placement policies.

The paper's repair-time wins assume repair reads come from *nearby*
survivors; whether they do is decided by **placement policy**, not code
structure — the lesson of the copyset/failure-domain analysis in *XORing
Elephants* (Sathiamoorthy et al.) and the locality framing of *Locally
Repairable Codes* (Papailiopoulos & Dimakis). This module makes that policy
pluggable:

* :class:`Topology` describes the physical fleet: ``num_nodes`` storage
  nodes grouped into ``num_domains`` failure domains (racks / hosts).
  Domains are contiguous equal node ranges — the same node->shard geometry
  :meth:`~repro.dist.placement.PlacementMap.from_store` derives — so a
  domain doubles as the *gather shard* that serves a device slice during
  sharded repair.
* :func:`place_stripe` maps a stripe's ``n`` blocks onto nodes under one of
  three policies (:data:`POLICIES`):

  - ``"contiguous"`` — a rotated arc of ``n`` consecutive nodes (the stripe
    store's seed behavior, stride 7). Every stripe of a pattern group lands
    on the *same* arc, so repair locality is whatever the arc's overlap
    with the reading domain happens to be.
  - ``"round_robin"`` — blocks round-robin across failure domains (classic
    "one replica per rack"): maximal failure-domain dispersion, which also
    means every repair read set spreads over ~all domains and no scheduler
    can make it local.
  - ``"spread"`` — copyset-style: each stripe picks a small seeded-random
    set of domains (``spread_width``) and scatters its blocks over their
    nodes. Bounds the number of distinct copysets (the XORing-Elephants
    correlated-failure argument) *and* concentrates each stripe's repair
    reads in few domains — the skewed scenario where locality-aware stripe
    scheduling (``repro.dist.schedule``) pays.

* :func:`placement_from_topology` turns a topology + a live stripe store
  into the :class:`~repro.dist.placement.PlacementMap` the repair read
  stack consumes (node->shard from the domains, block->node from the
  store's stripe index, remote cost from the store config).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .placement import PlacementMap

# Recognized block-placement policies, in increasing dispersion order of a
# single stripe's blocks across failure domains: arc < copyset < per-block.
POLICIES = ("contiguous", "spread", "round_robin")

# The seed stripe store's arc stride: coprime to typical node counts, so
# consecutive stripes rotate their arcs and parities spread across nodes.
_ARC_STRIDE = 7


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fleet of storage nodes grouped into failure domains.

    Args:
        num_nodes: total storage nodes (the stripe store's virtual nodes).
        num_domains: failure domains (racks / hosts). Nodes are assigned to
            domains in contiguous equal ranges: node ``i`` lives in domain
            ``i * num_domains // num_nodes`` — the same contiguous geometry
            the placement layer's default node->shard map uses, so a domain
            is also the gather shard serving a device slice.
        spread_width: how many domains the ``"spread"`` policy lets one
            stripe touch (widened automatically when the chosen domains
            hold fewer than ``n`` nodes).
        seed: seeds the ``"spread"`` policy's per-stripe domain choice.
    """
    num_nodes: int
    num_domains: int = 1
    spread_width: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.num_nodes < 1:
            raise ValueError("topology needs at least one node")
        if not 1 <= self.num_domains <= self.num_nodes:
            raise ValueError(
                f"num_domains must be in [1, {self.num_nodes}], "
                f"got {self.num_domains}")
        if self.spread_width < 1:
            raise ValueError("spread_width must be >= 1")

    def domain_of(self, node: int) -> int:
        """Failure domain of ``node`` (contiguous equal ranges)."""
        return node * self.num_domains // self.num_nodes

    # Rack vocabulary: the reliability simulator (``repro.sim``) models a
    # disk/node/rack unit hierarchy; its racks ARE this topology's failure
    # domains (one correlated-failure blast radius per domain), so the same
    # Topology object drives placement, gather sharding, and fleet
    # simulation without a parallel geometry.
    @property
    def num_racks(self) -> int:
        """Racks for the unit hierarchy — identical to ``num_domains``."""
        return self.num_domains

    def rack_of(self, node: int) -> int:
        """Rack of ``node`` — identical to :meth:`domain_of`."""
        return self.domain_of(node)

    def nodes_by_rack(self) -> list[list[int]]:
        """Node ids grouped by rack (= failure domain), ascending."""
        return [self.nodes_in(d) for d in range(self.num_domains)]

    def nodes_in(self, domain: int) -> list[int]:
        """All node ids in ``domain``, ascending."""
        n, d = self.num_nodes, self.num_domains
        lo = -(-domain * n // d)            # ceil(domain * n / d)
        hi = -(-(domain + 1) * n // d)
        return list(range(lo, hi))

    def shard_of_node(self) -> tuple[int, ...]:
        """node id -> domain id, as the tuple ``PlacementMap`` consumes."""
        return tuple(self.domain_of(i) for i in range(self.num_nodes))


def place_stripe(policy: str, topo: Topology, sid: int, n: int) -> list[int]:
    """Place stripe ``sid``'s ``n`` blocks onto nodes under ``policy``.

    Args:
        policy: one of :data:`POLICIES`.
        topo: the fleet topology.
        sid: stripe id (drives rotation / the seeded domain choice).
        n: blocks per stripe (``k + p + r``).

    Returns:
        ``n`` distinct node ids, indexed by block. Deterministic in
        ``(policy, topo, sid, n)`` — re-running a placement is a pure
        function, so manifests and twin stores reproduce exactly.

    Raises:
        ValueError: unknown policy, or ``n`` exceeds the available nodes.
    """
    if n > topo.num_nodes:
        raise ValueError(f"cannot place {n} blocks on {topo.num_nodes} nodes")
    if policy == "contiguous":
        base = (sid * _ARC_STRIDE) % topo.num_nodes
        return [(base + i) % topo.num_nodes for i in range(n)]
    if policy == "round_robin":
        return _place_round_robin(topo, sid, n)
    if policy == "spread":
        return _place_spread(topo, sid, n)
    raise ValueError(f"unknown placement policy {policy!r} "
                     f"(choose from {', '.join(POLICIES)})")


def _place_round_robin(topo: Topology, sid: int, n: int) -> list[int]:
    """One block per domain, cycling: block ``b`` -> domain
    ``(sid + b) % D`` (rotated per stripe), node rotated within the domain.
    Skips full domains so uneven domain sizes still place ``n`` distinct
    nodes."""
    d_count = topo.num_domains
    pools = [topo.nodes_in(d) for d in range(d_count)]
    used = [0] * d_count
    out: list[int] = []
    for b in range(n):
        d = (sid + b) % d_count
        for off in range(d_count):          # first domain with spare nodes
            dd = (d + off) % d_count
            if used[dd] < len(pools[dd]):
                d = dd
                break
        nodes = pools[d]
        out.append(nodes[(sid + used[d]) % len(nodes)])
        used[d] += 1
    # used[d] consecutive ring offsets per domain => distinct within the
    # domain; domains partition nodes => distinct overall.
    return out


def _place_spread(topo: Topology, sid: int, n: int) -> list[int]:
    """Copyset-style: a seeded per-stripe choice of ``spread_width`` domains
    (widened until they hold ``n`` nodes), blocks sampled without
    replacement from their pooled nodes."""
    rng = np.random.default_rng([topo.seed, sid])
    order = rng.permutation(topo.num_domains)
    pool: list[int] = []
    taken = 0
    for d in order:
        pool.extend(topo.nodes_in(int(d)))
        taken += 1
        if taken >= topo.spread_width and len(pool) >= n:
            break
    sel = rng.choice(len(pool), size=n, replace=False)
    return [pool[int(i)] for i in sel]


def placement_from_topology(store, topo: Topology,
                            remote_multiplier: Optional[float] = None
                            ) -> PlacementMap:
    """The :class:`~repro.dist.placement.PlacementMap` of ``store`` under
    ``topo``: node->shard from the topology's failure domains, block->node
    from the store's live stripe index.

    Args:
        store: a ``repro.ftx.StripeStore`` whose ``num_nodes`` matches the
            topology.
        topo: the fleet topology (domains become gather shards).
        remote_multiplier: simulated link-time cost of a cross-domain read;
            defaults to ``store.cfg.remote_read_multiplier``.

    Returns:
        A ``PlacementMap`` resolving ``(sid, block)`` through the store —
        it tracks placement changes (e.g. spare remapping) live.
    """
    if topo.num_nodes != store.num_nodes:
        raise ValueError(f"topology has {topo.num_nodes} nodes, "
                         f"store has {store.num_nodes}")
    if remote_multiplier is None:
        remote_multiplier = getattr(store.cfg, "remote_read_multiplier", 1.0)
    return PlacementMap(
        shard_of_node=topo.shard_of_node(),
        remote_multiplier=float(remote_multiplier),
        node_of=lambda sid, b: store.stripes[sid].node_of_block[b])
