"""Placement layer: (stripe, block) -> (node, shard) + locality cost model.

The paper's repair gains are *bandwidth* gains — CP-LRC repair reads fewer
blocks — and this module makes the fleet layer move those blocks along the
shortest path. A :class:`PlacementMap` names, for every block, the node that
holds it and the *shard* (host / failure domain) that node belongs to, plus
a locality cost model: reads a shard serves from its own nodes are local,
reads that cross shards pay a configurable ``remote_multiplier`` on the
simulated link time (the same accounting XORing Elephants does for
cross-rack repair traffic).

The second half of the module is the sharded-gather geometry shared by the
stripe store and the repair pipeline: :func:`shard_layout` turns an
``(S, ...)`` batch shape plus :class:`~repro.dist.sharding.MeshRules` into
the per-device contiguous stripe slices the mesh's stripe axis implies, and
:func:`assemble_shards` builds the global device array straight from one
host buffer per shard via ``jax.make_array_from_single_device_arrays`` — no
single-host ``(S, |reads|, B)`` stack and no device-0 bounce ever exist on
the path. Window alignment (``dist.stripes.align_stripe_window``) and this
layout agree by construction: both derive from the same ``NamedSharding``,
so an aligned window always yields ``span`` equal slices of ``S / span``
stripes in global stripe order.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from .sharding import MeshRules
from .stripes import stripe_sharding, stripe_span


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """(stripe, block) -> (node, shard), with a local/remote cost model.

    ``shard_of_node[i]`` is the shard (host) node ``i`` lives in. ``node_of``
    resolves a ``(sid, block)`` pair to its node id (the stripe store's
    block placement); it may be ``None`` for maps that only answer
    node-level questions. ``remote_multiplier`` scales the simulated link
    time of a read whose source node lives outside the reading shard
    (1.0 = locality-blind, matching the pre-placement model).
    """
    shard_of_node: tuple[int, ...]
    remote_multiplier: float = 1.0
    node_of: Optional[Callable[[int, int], int]] = None

    @property
    def num_shards(self) -> int:
        return max(self.shard_of_node) + 1 if self.shard_of_node else 1

    def locate(self, sid: int, block: int) -> tuple[int, int]:
        """The (node, shard) holding ``(sid, block)``."""
        if self.node_of is None:
            raise ValueError("this PlacementMap has no (sid, block) resolver")
        node = self.node_of(sid, block)
        return node, self.shard_of_node[node]

    def shard_of(self, node: int) -> int:
        return self.shard_of_node[node]

    def is_local(self, node: int, reader_shard: Optional[int]) -> bool:
        """Is a read of ``node`` by ``reader_shard`` shard-local?

        ``reader_shard=None`` means the read is not attributed to any shard
        (client/degraded reads) and is charged as local.
        """
        if reader_shard is None:
            return True
        return self.shard_of_node[node] == reader_shard

    def read_multiplier(self, node: int, reader_shard: Optional[int]) -> float:
        """Link-time multiplier for one read (1.0 local, else remote cost)."""
        return 1.0 if self.is_local(node, reader_shard) \
            else self.remote_multiplier

    def reader_shard(self, device_shard: int, span: int) -> int:
        """Host shard serving device shard ``device_shard`` of ``span``.

        Contiguous, order-preserving — the same stripe->device mapping
        ``shard_layout`` / ``align_stripe_window`` use — so device shard d
        of a span-wide launch reads through host ``d * num_shards // span``
        (identity when the mesh span equals the host count).
        """
        if span <= 0:
            return 0
        return min(self.num_shards - 1, device_shard * self.num_shards // span)

    @classmethod
    def from_store(cls, store, num_shards: int = 1,
                   remote_multiplier: Optional[float] = None
                   ) -> "PlacementMap":
        """Default node->shard map for a stripe store: ``num_shards``
        contiguous node ranges (node ``i`` -> shard ``i*num_shards//N``),
        resolving blocks through the store's stripe placement. The
        multiplier defaults to ``store.cfg.remote_read_multiplier``."""
        n = store.num_nodes
        num_shards = max(1, min(int(num_shards), n))
        shard = tuple(i * num_shards // n for i in range(n))
        if remote_multiplier is None:
            remote_multiplier = getattr(store.cfg, "remote_read_multiplier",
                                        1.0)
        return cls(shard_of_node=shard,
                   remote_multiplier=float(remote_multiplier),
                   node_of=lambda sid, b: store.stripes[sid].node_of_block[b])


def block_loads(placements, num_nodes: int) -> dict[int, int]:
    """Resident-block count per node over per-stripe block->node lists.

    Args:
        placements: iterable of ``node_of_block`` lists (one per stripe) —
            e.g. ``(s.node_of_block for s in store.stripes.values())``.
        num_nodes: fleet size; every node gets an entry (0 when empty), so
            least-loaded selection sees idle nodes too.

    Returns:
        ``{node: blocks resident}`` — the load model behind
        rebuild-destination selection
        (``repro.dist.topology.pick_destinations``) and the rebalancer
        (``repro.ftx.rebalance``).
    """
    loads = {n: 0 for n in range(num_nodes)}
    for nodes in placements:
        for n in nodes:
            loads[n] = loads.get(n, 0) + 1
    return loads


@dataclasses.dataclass(frozen=True)
class ShardSlice:
    """One device shard's contiguous stripe range of an ``(S, ...)`` batch.

    ``devices`` has more than one entry when other mesh axes replicate the
    batch (e.g. a 4x2 mesh shards stripes over "data" and replicates over
    "model"): every listed device holds a copy of the slice.
    """
    index: int
    lo: int
    hi: int
    devices: tuple

    @property
    def size(self) -> int:
        return self.hi - self.lo


def shard_layout(shape: Sequence[int], mr: Optional[MeshRules]
                 ) -> Optional[list[ShardSlice]]:
    """Per-device stripe slices for an ``(S, ...)`` batch, global order.

    Args:
        shape: the batched ``(S, |reads|, B)`` gather shape.
        mr: active mesh + rules, or ``None`` (single-process callers).

    Returns:
        ``None`` when the batch degrades to a single device (no rules,
        trivial mesh, or an ``S`` the stripe axis does not divide) —
        callers keep the one-buffer fast path there. Otherwise a list of
        :class:`ShardSlice` partitioning ``[0, S)`` into ``span`` equal
        contiguous ranges in stripe order (``slices[d]`` covers positions
        ``[d*S/span, (d+1)*S/span)``), matching the mesh's
        ``NamedSharding`` exactly — the launch consumes the assembled
        array with zero re-transfer, and the stripe scheduler
        (``repro.dist.schedule``) relies on this list-position -> slice
        mapping to assign stripes to shards by permutation.
    """
    shape = tuple(shape)
    if mr is None or stripe_span(shape, mr) <= 1:
        return None
    sharding = stripe_sharding(shape, mr)
    groups: dict[tuple[int, int], list] = {}
    for dev, idx in sharding.addressable_devices_indices_map(shape).items():
        sl = idx[0]
        lo = 0 if sl.start is None else int(sl.start)
        hi = shape[0] if sl.stop is None else int(sl.stop)
        groups.setdefault((lo, hi), []).append(dev)
    return [ShardSlice(i, lo, hi, tuple(devs))
            for i, ((lo, hi), devs) in enumerate(sorted(groups.items()))]


@dataclasses.dataclass
class GatherShard:
    """One shard's gather work item: fill ``buf`` with stripes
    ``[lo, hi)`` of the group, attributing every read to ``shard``."""
    lo: int
    hi: int
    shard: int                             # reader (host) shard for accounting
    buf: np.ndarray                        # (hi - lo, ...) preallocated
    slice_: Optional[ShardSlice] = None    # None on the degraded path


def plan_gather(shape: Sequence[int], mr: Optional[MeshRules], placement
                ) -> tuple[Optional[list[ShardSlice]], list[GatherShard]]:
    """Shared gather geometry for the stripe store and the repair pipeline.

    Args:
        shape: the batched ``(S, |reads|, B)`` gather shape.
        mr: active mesh + rules, or ``None``.
        placement: the active :class:`PlacementMap` (attributes each
            shard's reads), or ``None`` to attribute device shard *i* to
            host shard *i* directly.

    Returns:
        ``(layout, parts)``: the :func:`shard_layout` result plus one
        :class:`GatherShard` per buffer — preallocated ``uint8`` buffers
        with their stripe ranges and reader-shard attribution. A degraded
        batch (``layout is None``) gets one full-shape buffer attributed
        to shard 0 — the single-host gather, charged consistently on both
        the synchronous and pipelined paths. Sharded batches map device
        shard *i* onto the placement's host shards contiguously
        (``PlacementMap.reader_shard``), the same stripe->device order the
        layout itself uses.
    """
    shape = tuple(shape)
    layout = shard_layout(shape, mr)
    if layout is None:
        return None, [GatherShard(0, shape[0], 0,
                                  np.empty(shape, np.uint8))]
    span = len(layout)
    parts = [GatherShard(
        sl.lo, sl.hi,
        placement.reader_shard(sl.index, span) if placement is not None
        else sl.index,
        np.empty((sl.size,) + shape[1:], np.uint8), sl) for sl in layout]
    return layout, parts


def assemble_shards(shape: Sequence[int], mr: MeshRules,
                    layout: Sequence[ShardSlice],
                    bufs: Sequence[np.ndarray]) -> jax.Array:
    """Per-shard host buffers -> one global device array, no host stack.

    Args:
        shape: the global ``(S, ...)`` shape being assembled.
        mr: active mesh + rules (must be the ones ``layout`` derives from).
        layout: the :func:`shard_layout` slices, in slice order.
        bufs: one host ``(slice.size, ...)`` buffer per slice, same order.

    Returns:
        The global ``jax.Array``, sharded exactly as ``stripe_sharding``
        resolves — ``sharded_launch`` consumes it with zero re-transfer.
        Each buffer lands on its slice's device(s) with an independent
        ``device_put`` (replicated slices are put once per replica
        device), and the global array is stitched from the on-device
        shards — the single-host gather + device-0 bounce the old read
        path paid is gone.
    """
    shape = tuple(shape)
    sharding = stripe_sharding(shape, mr)
    arrays = [jax.device_put(buf, dev)
              for sl, buf in zip(layout, bufs) for dev in sl.devices]
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)
