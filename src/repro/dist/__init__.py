"""Distribution layer: logical-axis sharding rules + stripe-batch sharding.

``repro.dist.sharding`` maps *logical* axis names ("batch", "heads", "ff",
...) onto mesh axes with divisibility degradation — the contract the model,
train, serve and launch layers program against. ``repro.dist.stripes`` is
the codec-side counterpart: it shards the stripe axis ``S`` of ``(S, k, B)``
batches over the mesh's data-parallel axes so fleet repair scales past one
device. ``repro.dist.placement`` names where blocks physically live — a
``PlacementMap`` maps (stripe, block) -> (node, shard) with a local/remote
read cost model — and owns the per-shard gather geometry
(``shard_layout``/``assemble_shards``) that lands disk reads directly on
each device's shard.
"""
from .placement import (  # noqa: F401
    GatherShard,
    PlacementMap,
    ShardSlice,
    assemble_shards,
    plan_gather,
    shard_layout,
)
from .sharding import (  # noqa: F401
    MeshRules,
    _resolve,
    current_rules,
    opt_state_sharding,
    shard_activation,
    with_rules,
)
from .stripes import (  # noqa: F401
    sharded_launch,
    stripe_sharding,
    stripe_span,
    stripe_spec,
)
