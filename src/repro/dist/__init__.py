"""Distribution layer: logical-axis sharding rules + stripe-batch sharding.

``repro.dist.sharding`` maps *logical* axis names ("batch", "heads", "ff",
...) onto mesh axes with divisibility degradation — the contract the model,
train, serve and launch layers program against. ``repro.dist.stripes`` is
the codec-side counterpart: it shards the stripe axis ``S`` of ``(S, k, B)``
batches over the mesh's data-parallel axes so fleet repair scales past one
device. ``repro.dist.placement`` names where blocks physically live — a
``PlacementMap`` maps (stripe, block) -> (node, shard) with a local/remote
read cost model — and owns the per-shard gather geometry
(``shard_layout``/``assemble_shards``) that lands disk reads directly on
each device's shard. ``repro.dist.topology`` makes the placement itself a
policy: a ``Topology`` (nodes grouped into failure domains) plus pluggable
block-placement policies (contiguous arcs, per-block round-robin,
copyset-style spread) generate the maps. ``repro.dist.schedule`` closes the
loop: it permutes each repair chunk so every stripe lands on the device
shard whose host owns most of its surviving blocks, never predicting worse
locality than the contiguous default.
"""
from .placement import (  # noqa: F401
    GatherShard,
    PlacementMap,
    ShardSlice,
    assemble_shards,
    plan_gather,
    shard_layout,
)
from .schedule import (  # noqa: F401
    ChunkSchedule,
    chunk_affinity,
    schedule_chunk,
)
from .sharding import (  # noqa: F401
    MeshRules,
    _resolve,
    current_rules,
    opt_state_sharding,
    shard_activation,
    with_rules,
)
from .stripes import (  # noqa: F401
    sharded_launch,
    stripe_sharding,
    stripe_span,
    stripe_spec,
)
from .topology import (  # noqa: F401
    POLICIES,
    Topology,
    place_stripe,
    placement_from_topology,
)
