"""Locality-aware stripe scheduling: which device shard repairs which stripe.

The placement layer (``repro.dist.placement``) gave every repair read a
local/remote cost, but the stripe store still assigned a pattern group's
stripes to device shards *contiguously* — stripe ``i`` of a chunk lands on
device slice ``i * span // S`` regardless of where its surviving blocks
live, so the realized local-read fraction is whatever the default layout
happens to give. This module closes that gap: given a chunk of stripes
sharing one failure pattern, a :class:`~repro.dist.placement.PlacementMap`,
and the mesh's stripe-axis span, :func:`schedule_chunk` **permutes the
chunk** so each stripe lands on the device slice whose serving host shard
owns the most of its surviving blocks.

Why a permutation is all it takes
---------------------------------

``shard_layout`` partitions an ``(S, ...)`` batch into ``span`` equal
contiguous stripe slices in *list order*: positions ``[d*S/span,
(d+1)*S/span)`` of the chunk's sid list go to device slice ``d``, which is
gathered by host shard ``reader_shard(d, span)``. Reordering the sid list
is therefore exactly an assignment of stripes to reading shards — applied
*before* ``shard_layout`` so the gather, the launch sharding, and the
window alignment all see the scheduled order. The inverse permutation on
write-back is carried by the sid list itself: every downstream consumer
(``_gather_group``, ``_finish_repair``, telemetry) indexes rows through the
same permuted list, and rebuilt row ``i`` is persisted to ``sids[i]``'s own
block paths — so outputs are bit-identical under *any* permutation (GF(2^8)
decoding is exact and stripes share no terms; only which shard reads which
bytes changes).

Two assignment modes build on that, forming a dominance chain
(``tests/test_orchestration.py`` property-tests it):

* ``"locality"`` (PR 5) — per-chunk greedy cost-model argmax: stripes claim
  their highest-affinity slice (affinity = surviving blocks the slice's
  host shard owns) best-pair-first under per-slice capacity ``S/span``; if
  the greedy total does not beat the contiguous assignment's total, the
  identity order is kept — the scheduler **never yields a lower predicted
  local-read fraction than the contiguous baseline** (property-tested in
  ``tests/test_schedule.py``).
* ``"global"`` (PR 10, the default) — an exact min-cost assignment across
  **all windows of a pattern group at once** (:func:`schedule_group`). The
  key structural fact: ``reader_shard(d, span)`` does not depend on the
  window index, so the per-window slice slots are interchangeable per slice
  index and the cross-window problem is a *transportation problem* — S
  stripes onto ``span`` columns whose aggregate capacity is the sum of the
  per-window caps. It is solved exactly by starting from the greedy
  per-window assignment (feasible by construction) and canceling
  positive-gain cycles in the column residual graph
  (:func:`optimize_assignment`) until none remain — the classic optimality
  condition for min-cost circulations, equivalent to Hungarian on the
  slot-expanded matrix but warm-started so **global >= greedy >= contiguous
  holds structurally**, not just empirically. Stripes may migrate between
  windows; per-window slice capacities are restored when the optimal
  column assignment is dealt back into windows in input order.

Degradation mirrors the gather geometry: a chunk the span does not divide
would fall back to the single-buffer gather (shard 0), so it is left in
identity order and its reads are predicted against shard 0 — predicted and
realized locality agree on every path. Such ragged chunks keep their
per-chunk schedule under ``"global"`` too (they launch degraded, so there
is no cross-window slot to trade).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .placement import PlacementMap
from .sharding import MeshRules
from .stripes import stripe_span


@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """One chunk's stripe -> device-slice assignment, as a permutation.

    Attributes:
        sids: the chunk's stripe ids in scheduled (launch) order — feed
            this, not the input order, to the gather/launch path.
        order: ``order[i]`` is the input-list index of the stripe now at
            position ``i`` (``sids[i] == input[order[i]]``); the identity
            tuple when scheduling found no improvement or was inapplicable.
        span: device slices the launch will spread over (1 = degraded).
        scheduled_local: predicted shard-local reads under ``sids`` order.
        contiguous_local: predicted shard-local reads under input order.
        total_reads: reads the chunk's gather will issue
            (``len(sids) * |plan reads|``); 0 when the placement cannot
            resolve block locations (no prediction possible).
    """
    sids: tuple[int, ...]
    order: tuple[int, ...]
    span: int
    scheduled_local: int
    contiguous_local: int
    total_reads: int

    @property
    def is_identity(self) -> bool:
        return all(i == o for i, o in enumerate(self.order))

    @property
    def scheduled_local_fraction(self) -> float:
        """Predicted local-read fraction in scheduled order (1.0 when no
        prediction exists — matching ``local_read_fraction``'s convention)."""
        return self.scheduled_local / self.total_reads if self.total_reads \
            else 1.0

    @property
    def contiguous_local_fraction(self) -> float:
        """Predicted local-read fraction in input (contiguous) order."""
        return self.contiguous_local / self.total_reads if self.total_reads \
            else 1.0


def chunk_affinity(sids: Sequence[int], reads: Sequence[int],
                   placement: PlacementMap, span: int) -> np.ndarray:
    """Affinity matrix ``A[i, d]``: how many of stripe ``sids[i]``'s read
    blocks live on nodes of the host shard serving device slice ``d``.

    Args:
        sids: the chunk's stripe ids.
        reads: the compiled plan's read blocks (shared by every stripe of a
            pattern group).
        placement: resolves ``(sid, block) -> node -> shard``; must have a
            ``node_of`` resolver.
        span: device slices of the launch; slice ``d`` is served by host
            shard ``placement.reader_shard(d, span)``.

    Returns:
        ``(len(sids), span)`` int array. Row sums are ``<= len(reads)``
        (equal when the placement's shards cover every read's node, which
        contiguous-domain topologies always do).
    """
    shard_of = placement.shard_of_node
    hosts = [placement.reader_shard(d, span) for d in range(span)]
    a = np.zeros((len(sids), span), dtype=np.int64)
    for i, sid in enumerate(sids):
        per_shard: dict[int, int] = {}
        for b in reads:
            s = shard_of[placement.node_of(sid, b)]
            per_shard[s] = per_shard.get(s, 0) + 1
        for d, h in enumerate(hosts):
            a[i, d] = per_shard.get(h, 0)
    return a


def _identity(sids: Sequence[int], span: int, local: int, total: int
              ) -> ChunkSchedule:
    return ChunkSchedule(sids=tuple(sids), order=tuple(range(len(sids))),
                         span=span, scheduled_local=local,
                         contiguous_local=local, total_reads=total)


def greedy_assign(a: np.ndarray, cap: int) -> list[int]:
    """PR-5 greedy argmax: assign each stripe (row of ``a``) to a device
    slice (column), best ``(stripe, slice)`` pairs first, at most ``cap``
    stripes per slice. Ties break on (stripe, slice) index for determinism.

    Returns ``assigned[i] = column of stripe i``; every stripe is placed
    (``a`` must have ``rows <= cap * columns``).
    """
    n, span = a.shape
    pairs = sorted(((int(-a[i, d]), i, d) for i in range(n)
                    for d in range(span)))
    assigned = [-1] * n
    counts = [0] * span
    placed = 0
    for neg, i, d in pairs:
        if assigned[i] >= 0 or counts[d] >= cap:
            continue
        assigned[i] = d
        counts[d] += 1
        placed += 1
        if placed == n:
            break
    return assigned


def _positive_cycle(gain: np.ndarray) -> Optional[list[int]]:
    """A simple column cycle with strictly positive total gain, or None.

    Bellman–Ford negative-cycle detection on cost ``-gain`` over the
    ``m``-node column graph: relax ``m`` rounds; a node still relaxing in
    the last round reaches a negative cycle, and walking predecessors ``m``
    steps lands on it. Entries equal to the int64 minimum mark absent edges
    (empty source columns).
    """
    m = gain.shape[0]
    absent = np.iinfo(np.int64).min
    edges = [(d, d2, -int(gain[d, d2])) for d in range(m) for d2 in range(m)
             if d != d2 and gain[d, d2] != absent]
    dist = [0] * m
    pred = [-1] * m
    last = -1
    for _ in range(m):
        last = -1
        for u, v, w in edges:
            if dist[u] + w < dist[v]:
                dist[v] = dist[u] + w
                pred[v] = u
                last = v
        if last == -1:
            return None
    for _ in range(m):                      # walk onto the cycle itself
        last = pred[last]
    cycle = [last]
    v = pred[last]
    while v != last:
        cycle.append(v)
        v = pred[v]
    cycle.reverse()                         # consecutive pairs are edges
    return cycle


def optimize_assignment(a: np.ndarray, assign: Sequence[int]) -> np.ndarray:
    """Cancel positive-gain cycles until ``assign`` is an optimal
    transportation solution for affinity ``a`` under the (equality) column
    capacities the starting assignment implies.

    Each round builds the column residual graph — edge ``d -> d2`` carries
    the best single-stripe reassignment gain ``max_{i in d} a[i, d2] -
    a[i, d]`` — and applies one positive cycle (distinct source columns, so
    the simultaneous moves keep every column's count exact). The total is a
    bounded integer that strictly increases, so termination is guaranteed;
    absence of a positive cycle is the standard optimality condition for
    min-cost circulations. The result is therefore never worse than the
    starting assignment — feed it the greedy solution and the dominance
    chain ``global >= greedy`` holds by construction.
    """
    a = np.asarray(a, dtype=np.int64)
    out = np.asarray(list(assign), dtype=np.int64)
    n, m = a.shape
    if n == 0 or m <= 1:
        return out
    absent = np.iinfo(np.int64).min
    cols = np.arange(m)
    # Strictly-improving integer objective bounded by n * max affinity.
    for _ in range(int(n) * int(max(1, a.max())) + 1):
        gain = np.full((m, m), absent, dtype=np.int64)
        arg = np.full((m, m), -1, dtype=np.int64)
        for d in range(m):
            idx = np.nonzero(out == d)[0]
            if idx.size == 0:
                continue
            diffs = a[idx] - a[idx, d][:, None]
            j = np.argmax(diffs, axis=0)
            gain[d] = diffs[j, cols]
            arg[d] = idx[j]
        cycle = _positive_cycle(gain)
        if cycle is None:
            return out
        moves = [(int(arg[d, d2]), d2)
                 for d, d2 in zip(cycle, cycle[1:] + cycle[:1])]
        if sum(int(gain[d, d2]) for d, d2
               in zip(cycle, cycle[1:] + cycle[:1])) <= 0:
            return out                      # defensive: never regress
        for i, d2 in moves:
            out[i] = d2
    return out


def schedule_chunk(sids: Sequence[int], reads: Sequence[int],
                   placement: Optional[PlacementMap],
                   mr: Optional[MeshRules],
                   mode: str = "locality") -> ChunkSchedule:
    """Schedule one launch chunk's stripes onto the mesh's device slices.

    Args:
        sids: stripe ids sharing one failure pattern (one launch chunk /
            pipeline window).
        reads: the pattern's compiled read blocks.
        placement: the active ``PlacementMap``; ``None`` (or one without a
            ``node_of`` resolver) disables prediction and scheduling.
        mr: the active ``MeshRules``; ``None`` or a trivial/indivisible
            stripe span leaves the chunk in identity order, predicted
            against gather shard 0 (the degraded single-buffer path).
        mode: ``"locality"`` runs the greedy assignment; ``"none"`` skips
            it and returns the identity order with the contiguous
            prediction (so both modes account through one code path, and
            the disabled scheduler pays only the single counting pass).

    Returns:
        A :class:`ChunkSchedule` whose ``sids`` is the order to launch and
        whose ``scheduled_local`` is the prediction *for that order* —
        **never** below ``contiguous_local``: the greedy assignment is
        kept only when it strictly beats the contiguous one, else the
        identity order (and its score) is returned.
    """
    n_stripes = len(sids)
    if placement is None or placement.node_of is None or not n_stripes \
            or not len(reads):
        return _identity(sids, 1, 0, 0)
    total = n_stripes * len(reads)
    span = stripe_span((n_stripes, max(1, len(reads)), 1), mr)
    if span <= 1 or n_stripes % span:
        # Degraded launch: plan_gather attributes every read to shard 0.
        shard_of = placement.shard_of_node
        local = sum(1 for sid in sids for b in reads
                    if shard_of[placement.node_of(sid, b)] == 0)
        return _identity(sids, 1, local, total)
    a = chunk_affinity(sids, reads, placement, span)
    cap = n_stripes // span
    contiguous = int(sum(a[i, i // cap] for i in range(n_stripes)))
    if mode == "none":
        return _identity(sids, span, contiguous, total)
    # Greedy argmax: best (stripe, slice) pairs first, per-slice capacity
    # cap. Ties break on (stripe, slice) index for determinism.
    assigned = greedy_assign(a, cap)
    buckets: list[list[int]] = [[] for _ in range(span)]
    for i in range(n_stripes):
        buckets[assigned[i]].append(i)
    greedy = int(sum(a[i, assigned[i]] for i in range(n_stripes)))
    if greedy <= contiguous:
        return _identity(sids, span, contiguous, total)
    for b in buckets:                       # stable within a slice
        b.sort()
    order = tuple(i for b in buckets for i in b)
    return ChunkSchedule(sids=tuple(sids[i] for i in order), order=order,
                         span=span, scheduled_local=greedy,
                         contiguous_local=contiguous, total_reads=total)


def schedule_group(sids: Sequence[int], reads: Sequence[int],
                   placement: Optional[PlacementMap],
                   mr: Optional[MeshRules], *, step: int,
                   mode: str = "global") -> list[ChunkSchedule]:
    """Schedule a whole pattern group's stripes across all its windows.

    Splits ``sids`` into launch chunks of ``step`` stripes (exactly as the
    synchronous repair loop and the pipeline's window builder chunk) and
    returns one :class:`ChunkSchedule` per chunk, in chunk order:

    * ``mode="none"`` / ``"locality"`` — the PR-5 behavior: each chunk is
      scheduled independently by :func:`schedule_chunk`.
    * ``mode="global"`` — one exact min-cost assignment over **every
      shardable chunk of the group at once**. Because the host shard
      serving device slice ``d`` (``placement.reader_shard(d, span)``) does
      not depend on the window index, slice-``d`` slots of different
      windows are interchangeable: the cross-window problem is a
      transportation problem onto ``span`` columns with aggregate capacity
      ``sum_w cap_w``. It is solved exactly by warm-starting from the
      greedy per-chunk assignment and canceling positive-gain column
      cycles (:func:`optimize_assignment`); the optimal column assignment
      is then dealt back into windows — column ``d``'s stripes, in input
      order, fill slice ``d`` of each window in turn — restoring every
      per-window per-slice capacity. Stripes therefore **migrate between
      windows** when that buys locality; write-back is keyed by sid, so
      the result stays bit-identical (only which shard reads which bytes
      changes).

    The dominance chain is structural: the greedy start is feasible, cycle
    canceling only ever improves it, and when the optimum does not
    strictly beat the greedy total the per-chunk greedy schedules are
    returned unchanged — so ``global >= greedy >= contiguous`` on
    predicted shard-local reads, always. Chunks the span does not divide
    (and whole groups with no usable placement/mesh) launch degraded and
    keep their per-chunk schedule under every mode.

    For windows produced by the global mode, ``ChunkSchedule.order``
    indexes into the *group's* input ``sids`` (stripes may have crossed
    windows); ``contiguous_local`` remains the original chunk's
    contiguous-order prediction, so aggregating either field over the
    returned list compares like for like.
    """
    step = max(1, int(step))
    chunks = [list(sids[lo:lo + step]) for lo in range(0, len(sids), step)]
    if mode != "global":
        return [schedule_chunk(c, reads, placement, mr, mode)
                for c in chunks]
    greedy = [schedule_chunk(c, reads, placement, mr, "locality")
              for c in chunks]
    # Pool every chunk that actually shards at the full-window span; the
    # rest (degraded tails, unpredictable placements) keep their per-chunk
    # result.
    span = stripe_span((step, max(1, len(reads)), 1), mr) if chunks else 1
    pooled = [w for w, cs in enumerate(greedy)
              if cs.span == span > 1 and cs.total_reads]
    if not pooled:
        return greedy
    base = {w: sum(len(chunks[v]) for v in pooled[:j])
            for j, w in enumerate(pooled)}
    rows: list[tuple[int, int]] = [(w, i) for w in pooled
                                   for i in range(len(chunks[w]))]
    pooled_sids = [chunks[w][i] for w, i in rows]
    a = chunk_affinity(pooled_sids, reads, placement, span)
    caps = {w: len(chunks[w]) // span for w in pooled}
    start = np.empty(len(rows), dtype=np.int64)
    for w in pooled:
        # greedy[w].order[i] = chunk-input index of the stripe launched at
        # position i; position i of a chunk belongs to slice i // cap.
        for i, oi in enumerate(greedy[w].order):
            start[base[w] + oi] = i // caps[w]
    before = int(a[np.arange(len(rows)), start].sum())
    assign = optimize_assignment(a, start)
    after = int(a[np.arange(len(rows)), assign].sum())
    if after <= before:                     # hard floor: keep greedy
        return greedy
    # Deal columns back into windows: slice d of window w takes the next
    # cap_w stripes of column d, in pooled input order (deterministic).
    queues = [np.nonzero(assign == d)[0].tolist() for d in range(span)]
    heads = [0] * span
    group_ix = {}                           # pooled row -> group input index
    pos = 0
    for w, chunk in enumerate(chunks):
        for i in range(len(chunk)):
            if w in caps:
                group_ix[(w, i)] = pos + i
        pos += len(chunk)
    out = list(greedy)
    for w in pooled:
        cap = caps[w]
        taken: list[int] = []
        for d in range(span):
            taken.extend(queues[d][heads[d]:heads[d] + cap])
            heads[d] += cap
        order = tuple(group_ix[rows[j]] for j in taken)
        sched = int(sum(a[j, int(assign[j])] for j in taken))
        out[w] = ChunkSchedule(
            sids=tuple(pooled_sids[j] for j in taken), order=order,
            span=span, scheduled_local=sched,
            contiguous_local=greedy[w].contiguous_local,
            total_reads=greedy[w].total_reads)
    return out
