"""Locality-aware stripe scheduling: which device shard repairs which stripe.

The placement layer (``repro.dist.placement``) gave every repair read a
local/remote cost, but the stripe store still assigned a pattern group's
stripes to device shards *contiguously* — stripe ``i`` of a chunk lands on
device slice ``i * span // S`` regardless of where its surviving blocks
live, so the realized local-read fraction is whatever the default layout
happens to give. This module closes that gap: given a chunk of stripes
sharing one failure pattern, a :class:`~repro.dist.placement.PlacementMap`,
and the mesh's stripe-axis span, :func:`schedule_chunk` **permutes the
chunk** so each stripe lands on the device slice whose serving host shard
owns the most of its surviving blocks.

Why a permutation is all it takes
---------------------------------

``shard_layout`` partitions an ``(S, ...)`` batch into ``span`` equal
contiguous stripe slices in *list order*: positions ``[d*S/span,
(d+1)*S/span)`` of the chunk's sid list go to device slice ``d``, which is
gathered by host shard ``reader_shard(d, span)``. Reordering the sid list
is therefore exactly an assignment of stripes to reading shards — applied
*before* ``shard_layout`` so the gather, the launch sharding, and the
window alignment all see the scheduled order. The inverse permutation on
write-back is carried by the sid list itself: every downstream consumer
(``_gather_group``, ``_finish_repair``, telemetry) indexes rows through the
same permuted list, and rebuilt row ``i`` is persisted to ``sids[i]``'s own
block paths — so outputs are bit-identical under *any* permutation (GF(2^8)
decoding is exact and stripes share no terms; only which shard reads which
bytes changes).

The assignment itself is a greedy cost-model argmax with a safety net:
stripes claim their highest-affinity slice (affinity = surviving blocks the
slice's host shard owns) best-pair-first under per-slice capacity
``S/span``; if the greedy total does not beat the contiguous assignment's
total, the identity order is kept — the scheduler **never yields a lower
predicted local-read fraction than the contiguous baseline** (property-
tested in ``tests/test_schedule.py``).

Degradation mirrors the gather geometry: a chunk the span does not divide
would fall back to the single-buffer gather (shard 0), so it is left in
identity order and its reads are predicted against shard 0 — predicted and
realized locality agree on every path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .placement import PlacementMap
from .sharding import MeshRules
from .stripes import stripe_span


@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """One chunk's stripe -> device-slice assignment, as a permutation.

    Attributes:
        sids: the chunk's stripe ids in scheduled (launch) order — feed
            this, not the input order, to the gather/launch path.
        order: ``order[i]`` is the input-list index of the stripe now at
            position ``i`` (``sids[i] == input[order[i]]``); the identity
            tuple when scheduling found no improvement or was inapplicable.
        span: device slices the launch will spread over (1 = degraded).
        scheduled_local: predicted shard-local reads under ``sids`` order.
        contiguous_local: predicted shard-local reads under input order.
        total_reads: reads the chunk's gather will issue
            (``len(sids) * |plan reads|``); 0 when the placement cannot
            resolve block locations (no prediction possible).
    """
    sids: tuple[int, ...]
    order: tuple[int, ...]
    span: int
    scheduled_local: int
    contiguous_local: int
    total_reads: int

    @property
    def is_identity(self) -> bool:
        return all(i == o for i, o in enumerate(self.order))

    @property
    def scheduled_local_fraction(self) -> float:
        """Predicted local-read fraction in scheduled order (1.0 when no
        prediction exists — matching ``local_read_fraction``'s convention)."""
        return self.scheduled_local / self.total_reads if self.total_reads \
            else 1.0

    @property
    def contiguous_local_fraction(self) -> float:
        """Predicted local-read fraction in input (contiguous) order."""
        return self.contiguous_local / self.total_reads if self.total_reads \
            else 1.0


def chunk_affinity(sids: Sequence[int], reads: Sequence[int],
                   placement: PlacementMap, span: int) -> np.ndarray:
    """Affinity matrix ``A[i, d]``: how many of stripe ``sids[i]``'s read
    blocks live on nodes of the host shard serving device slice ``d``.

    Args:
        sids: the chunk's stripe ids.
        reads: the compiled plan's read blocks (shared by every stripe of a
            pattern group).
        placement: resolves ``(sid, block) -> node -> shard``; must have a
            ``node_of`` resolver.
        span: device slices of the launch; slice ``d`` is served by host
            shard ``placement.reader_shard(d, span)``.

    Returns:
        ``(len(sids), span)`` int array. Row sums are ``<= len(reads)``
        (equal when the placement's shards cover every read's node, which
        contiguous-domain topologies always do).
    """
    shard_of = placement.shard_of_node
    hosts = [placement.reader_shard(d, span) for d in range(span)]
    a = np.zeros((len(sids), span), dtype=np.int64)
    for i, sid in enumerate(sids):
        per_shard: dict[int, int] = {}
        for b in reads:
            s = shard_of[placement.node_of(sid, b)]
            per_shard[s] = per_shard.get(s, 0) + 1
        for d, h in enumerate(hosts):
            a[i, d] = per_shard.get(h, 0)
    return a


def _identity(sids: Sequence[int], span: int, local: int, total: int
              ) -> ChunkSchedule:
    return ChunkSchedule(sids=tuple(sids), order=tuple(range(len(sids))),
                         span=span, scheduled_local=local,
                         contiguous_local=local, total_reads=total)


def schedule_chunk(sids: Sequence[int], reads: Sequence[int],
                   placement: Optional[PlacementMap],
                   mr: Optional[MeshRules],
                   mode: str = "locality") -> ChunkSchedule:
    """Schedule one launch chunk's stripes onto the mesh's device slices.

    Args:
        sids: stripe ids sharing one failure pattern (one launch chunk /
            pipeline window).
        reads: the pattern's compiled read blocks.
        placement: the active ``PlacementMap``; ``None`` (or one without a
            ``node_of`` resolver) disables prediction and scheduling.
        mr: the active ``MeshRules``; ``None`` or a trivial/indivisible
            stripe span leaves the chunk in identity order, predicted
            against gather shard 0 (the degraded single-buffer path).
        mode: ``"locality"`` runs the greedy assignment; ``"none"`` skips
            it and returns the identity order with the contiguous
            prediction (so both modes account through one code path, and
            the disabled scheduler pays only the single counting pass).

    Returns:
        A :class:`ChunkSchedule` whose ``sids`` is the order to launch and
        whose ``scheduled_local`` is the prediction *for that order* —
        **never** below ``contiguous_local``: the greedy assignment is
        kept only when it strictly beats the contiguous one, else the
        identity order (and its score) is returned.
    """
    n_stripes = len(sids)
    if placement is None or placement.node_of is None or not n_stripes \
            or not len(reads):
        return _identity(sids, 1, 0, 0)
    total = n_stripes * len(reads)
    span = stripe_span((n_stripes, max(1, len(reads)), 1), mr)
    if span <= 1 or n_stripes % span:
        # Degraded launch: plan_gather attributes every read to shard 0.
        shard_of = placement.shard_of_node
        local = sum(1 for sid in sids for b in reads
                    if shard_of[placement.node_of(sid, b)] == 0)
        return _identity(sids, 1, local, total)
    a = chunk_affinity(sids, reads, placement, span)
    cap = n_stripes // span
    contiguous = int(sum(a[i, i // cap] for i in range(n_stripes)))
    if mode == "none":
        return _identity(sids, span, contiguous, total)
    # Greedy argmax: best (stripe, slice) pairs first, per-slice capacity
    # cap. Ties break on (stripe, slice) index for determinism.
    pairs = sorted(((int(-a[i, d]), i, d) for i in range(n_stripes)
                    for d in range(span)))
    assigned = [-1] * n_stripes
    buckets: list[list[int]] = [[] for _ in range(span)]
    placed = 0
    for neg, i, d in pairs:
        if assigned[i] >= 0 or len(buckets[d]) >= cap:
            continue
        assigned[i] = d
        buckets[d].append(i)
        placed += 1
        if placed == n_stripes:
            break
    greedy = int(sum(a[i, assigned[i]] for i in range(n_stripes)))
    if greedy <= contiguous:
        return _identity(sids, span, contiguous, total)
    for b in buckets:                       # stable within a slice
        b.sort()
    order = tuple(i for b in buckets for i in b)
    return ChunkSchedule(sids=tuple(sids[i] for i in order), order=order,
                         span=span, scheduled_local=greedy,
                         contiguous_local=contiguous, total_reads=total)
