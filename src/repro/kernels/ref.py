"""Pure-jnp oracles for the Pallas erasure-coding kernels.

Every kernel in this package has a reference implementation here; the test
suite sweeps shapes/dtypes and asserts bit-exact equality (erasure coding is
integer math — there is no tolerance, results must match exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gf import GF_MUL_TABLE, PRIM_POLY

_BITS = 8


# --------------------------------------------------------------------------
# GF(2^8) matmul (table path — ground truth)
# --------------------------------------------------------------------------
def gf256_matmul_ref(coef: jax.Array, data: jax.Array) -> jax.Array:
    """(m, k) x (k, B) over GF(2^8) via the 64 KB multiplication table."""
    coef = coef.astype(jnp.int32)
    data = data.astype(jnp.int32)
    table = jnp.asarray(GF_MUL_TABLE.reshape(-1))
    idx = coef[:, :, None] * 256 + data[None, :, :]
    prods = jnp.take(table, idx, axis=0).astype(jnp.uint8)  # (m, k, B)
    return jax.lax.reduce(prods, np.uint8(0),
                          lambda a, b: jax.lax.bitwise_xor(a, b), (1,))


def gf256_matmul_batched_ref(coef: jax.Array, data: jax.Array) -> jax.Array:
    """Batched oracle: ``coef (m,k) @ data (S,k,B) -> (S,m,B)``, table path.

    vmap of :func:`gf256_matmul_ref` over the stripe axis — bit-exact lockstep
    for the batched Pallas kernel.
    """
    return jax.vmap(gf256_matmul_ref, in_axes=(None, 0))(coef, data)


def gf256_matmul_shift_ref(coef: jax.Array, data: jax.Array) -> jax.Array:
    """Same product via the table-free shift-and-XOR algorithm the TPU kernel
    uses (oracle for the algorithm itself, not just the result)."""
    coef = coef.astype(jnp.int32)[:, :, None]  # (m, k, 1)
    cur = data.astype(jnp.int32)[None, :, :]   # (1, k, B)
    m, k, _ = coef.shape
    acc = jnp.zeros((m, k, data.shape[1]), jnp.int32)
    cur = jnp.broadcast_to(cur, acc.shape)
    cf = jnp.broadcast_to(coef, acc.shape)
    for _ in range(_BITS):
        acc = acc ^ jnp.where((cf & 1) != 0, cur, 0)
        cur = ((cur << 1) & 0xFF) ^ jnp.where((cur & 0x80) != 0, PRIM_POLY & 0xFF, 0)
        cf = cf >> 1
    return jax.lax.reduce(acc.astype(jnp.uint8), np.uint8(0),
                          lambda a, b: jax.lax.bitwise_xor(a, b), (1,))


# --------------------------------------------------------------------------
# CRS bit-plane layout helpers
# --------------------------------------------------------------------------
def packetize(blocks: jax.Array) -> jax.Array:
    """(k, B) byte blocks -> (k*8, B//8) packed bit-plane packets.

    Packet (j*8 + i) is bit-plane i of block j, packed little-endian
    (bit 0 of packed byte t = bit i of source byte 8t).
    """
    k, B = blocks.shape
    if B % _BITS:
        raise ValueError(f"block bytes {B} must be divisible by 8")
    x = blocks.astype(jnp.int32)
    planes = (x[:, None, :] >> jnp.arange(_BITS)[None, :, None]) & 1  # (k, 8, B)
    grp = planes.reshape(k, _BITS, B // _BITS, _BITS)  # last axis: 8 source bytes
    weights = (1 << jnp.arange(_BITS)).astype(jnp.int32)
    packed = jnp.sum(grp * weights[None, None, None, :], axis=-1)
    return packed.reshape(k * _BITS, B // _BITS).astype(jnp.uint8)


def unpacketize(packets: jax.Array) -> jax.Array:
    """Inverse of :func:`packetize`: (k*8, B//8) -> (k, B)."""
    k8, P = packets.shape
    k = k8 // _BITS
    x = packets.reshape(k, _BITS, P).astype(jnp.int32)
    bits = (x[:, :, :, None] >> jnp.arange(_BITS)[None, None, None, :]) & 1
    planes = bits.reshape(k, _BITS, P * _BITS)  # (k, plane, B)
    weights = (1 << jnp.arange(_BITS)).astype(jnp.int32)
    blocks = jnp.sum(planes * weights[None, :, None], axis=1)
    return blocks.astype(jnp.uint8)


def packetize_batched(blocks: jax.Array) -> jax.Array:
    """Batched :func:`packetize`: (S, k, B) -> (S, k*8, B//8)."""
    return jax.vmap(packetize)(blocks)


def unpacketize_batched(packets: jax.Array) -> jax.Array:
    """Batched :func:`unpacketize`: (S, k*8, B//8) -> (S, k, B)."""
    return jax.vmap(unpacketize)(packets)


def bitmatrix_encode_ref(bitmatrix: jax.Array, packets: jax.Array) -> jax.Array:
    """CRS encode on packed bit-plane packets: out[i] = XOR_{j: bm[i,j]=1} packets[j].

    bitmatrix: (R8, K8) of {0,1}; packets: (K8, P) packed bytes -> (R8, P).
    """
    bm = bitmatrix.astype(jnp.int32)
    pk = packets.astype(jnp.int32)
    sel = bm[:, :, None] * pk[None, :, :]  # 0/packet per (i, j)
    return jax.lax.reduce(sel.astype(jnp.uint8), np.uint8(0),
                          lambda a, b: jax.lax.bitwise_xor(a, b), (1,))


def bitmatrix_encode_batched_ref(bitmatrix: jax.Array,
                                 packets: jax.Array) -> jax.Array:
    """Batched oracle for the stripe-grid CRS kernel: ``bitmatrix (R8, K8) x
    packets (S, K8, P) -> (S, R8, P)`` — vmap over the stripe axis, bit-exact
    lockstep for :func:`repro.kernels.bitmatrix_encode.bitmatrix_encode_batched`."""
    return jax.vmap(bitmatrix_encode_ref, in_axes=(None, 0))(bitmatrix, packets)


def mod2_matmul_encode_batched_ref(bitmatrix: jax.Array,
                                   packets: jax.Array) -> jax.Array:
    """Batched MXU-formulation oracle: vmap of :func:`mod2_matmul_encode_ref`
    over the stripe axis. Must equal :func:`bitmatrix_encode_batched_ref`."""
    return jax.vmap(mod2_matmul_encode_ref, in_axes=(None, 0))(bitmatrix, packets)


def mod2_matmul_encode_ref(bitmatrix: jax.Array, packets: jax.Array) -> jax.Array:
    """The MXU formulation oracle: unpack packets to bits, real matmul,
    reduce mod 2, repack. Must equal :func:`bitmatrix_encode_ref` exactly."""
    k8, P = packets.shape
    x = packets.astype(jnp.int32)
    bits = ((x[:, :, None] >> jnp.arange(_BITS)[None, None, :]) & 1)  # (K8, P, 8)
    bits = bits.reshape(k8, P * _BITS).astype(jnp.float32)
    counts = jnp.dot(bitmatrix.astype(jnp.float32), bits,
                     precision=jax.lax.Precision.HIGHEST)
    outbits = counts.astype(jnp.int32) & 1  # (R8, P*8)
    outbits = outbits.reshape(-1, P, _BITS)
    weights = (1 << jnp.arange(_BITS)).astype(jnp.int32)
    out = jnp.sum(outbits * weights[None, None, :], axis=-1)
    return out.astype(jnp.uint8)
