"""Pallas TPU kernels: Cauchy-RS bitmatrix (CRS) encode on packed bit-planes.

Two TPU-native formulations of the same GF(2) product
``out[i] = XOR_{j : bm[i,j]=1} packets[j]`` (see DESIGN.md §3):

* ``bitmatrix_encode`` — VPU path: select-and-XOR accumulation over packet
  rows. Zero multiplies; the inner loop is one masked XOR per (row, packet).
* ``mod2_matmul_encode`` — MXU path (beyond-paper optimization): XOR-sums
  over GF(2) are ordinary sums mod 2, so unpack bytes to 0/1 bit lanes,
  run a *real* bf16 matmul on the systolic array (counts <= k*8 << 2^24 are
  exact in f32 accumulation), reduce mod 2 and repack. The whole
  unpack->dot->mod2->repack chain is fused in one kernel so the 8x-inflated
  bit tensor never leaves VMEM.

Inputs use the packed bit-plane layout of ``repro.kernels.ref.packetize``:
packets (k*8, P) where P = block_bytes / 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BITS = 8


# --------------------------------------------------------------------------
# VPU select-and-XOR path
# --------------------------------------------------------------------------
def _bitmatrix_kernel(bm_ref, pk_ref, out_ref, *, k8: int):
    bm = bm_ref[...].astype(jnp.int32)   # (TR, K8)
    pk = pk_ref[...].astype(jnp.int32)   # (K8, TP)
    tr, tp = out_ref.shape

    def step(j, acc):
        row = jax.lax.dynamic_slice(pk, (j, 0), (1, tp))   # (1, TP)
        sel = jax.lax.dynamic_slice(bm, (0, j), (tr, 1))   # (TR, 1)
        # sel is {0,1}: multiply == select; XOR-accumulate.
        return acc ^ (sel * row)

    acc = jax.lax.fori_loop(0, k8, step, jnp.zeros((tr, tp), jnp.int32))
    out_ref[...] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_p", "interpret"))
def bitmatrix_encode(bitmatrix: jax.Array, packets: jax.Array, *,
                     tile_r: int = 8, tile_p: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """CRS encode: bitmatrix (R8, K8) {0,1} x packets (K8, P) -> (R8, P)."""
    r8, k8 = bitmatrix.shape
    k8b, p = packets.shape
    if k8 != k8b:
        raise ValueError(f"shape mismatch {bitmatrix.shape} vs {packets.shape}")
    tr = min(tile_r, r8)
    tp = min(tile_p, p)
    if r8 % tr or p % tp:
        raise ValueError(f"(R8={r8}, P={p}) must divide tiles ({tr}, {tp})")
    return pl.pallas_call(
        functools.partial(_bitmatrix_kernel, k8=k8),
        grid=(r8 // tr, p // tp),
        in_specs=[
            pl.BlockSpec((tr, k8), lambda i, j: (i, 0)),
            pl.BlockSpec((k8, tp), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tr, tp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r8, p), jnp.uint8),
        interpret=interpret,
    )(bitmatrix, packets)


def _bitmatrix_batched_kernel(bm_ref, pk_ref, out_ref, *, k8: int):
    """One stripe's (TR, TP) output tile of the (S, R8, P) batched apply.

    The grid's leading axis walks stripes (like ``gf256_matmul_batched``);
    the bitmatrix block is shared across all of them — one compiled plan's
    bit expansion, S payloads.
    """
    bm = bm_ref[...].astype(jnp.int32)   # (TR, K8)
    pk = pk_ref[0].astype(jnp.int32)     # block (1, K8, TP) -> (K8, TP)
    tr, tp = out_ref.shape[1:]

    def step(j, acc):
        row = jax.lax.dynamic_slice(pk, (j, 0), (1, tp))   # (1, TP)
        sel = jax.lax.dynamic_slice(bm, (0, j), (tr, 1))   # (TR, 1)
        return acc ^ (sel * row)

    acc = jax.lax.fori_loop(0, k8, step, jnp.zeros((tr, tp), jnp.int32))
    out_ref[0] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_p", "interpret"))
def bitmatrix_encode_batched(bitmatrix: jax.Array, packets: jax.Array, *,
                             tile_r: int = 8, tile_p: int = 1024,
                             interpret: bool = False) -> jax.Array:
    """Batched CRS apply: ``bitmatrix (R8, K8) x packets (S, K8, P) ->
    (S, R8, P)``.

    One Pallas launch covers every stripe: the grid gains a leading stripe
    axis ``(S, R8/TR, P/TP)`` and the packet/output BlockSpecs index it,
    while the (small) bitmatrix block is broadcast to all stripes. This is
    the batched engine's bit-plane workhorse — repair/decode coefficient
    matrices expanded once per plan apply to a whole stripe batch in one
    launch (DESIGN.md §11).
    """
    r8, k8 = bitmatrix.shape
    s, k8b, p = packets.shape
    if k8 != k8b:
        raise ValueError(f"shape mismatch {bitmatrix.shape} vs {packets.shape}")
    tr = min(tile_r, r8)
    tp = min(tile_p, p)
    if r8 % tr or p % tp:
        raise ValueError(f"(R8={r8}, P={p}) must divide tiles ({tr}, {tp})")
    return pl.pallas_call(
        functools.partial(_bitmatrix_batched_kernel, k8=k8),
        grid=(s, r8 // tr, p // tp),
        in_specs=[
            pl.BlockSpec((tr, k8), lambda si, i, j: (i, 0)),
            pl.BlockSpec((1, k8, tp), lambda si, i, j: (si, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, tr, tp), lambda si, i, j: (si, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, r8, p), jnp.uint8),
        interpret=interpret,
    )(bitmatrix, packets)


# --------------------------------------------------------------------------
# MXU mod-2 matmul path
# --------------------------------------------------------------------------
def _mod2_kernel(bm_ref, pk_ref, out_ref):
    bm = bm_ref[...]                       # (R8, K8) bf16 of 0/1
    pk = pk_ref[...].astype(jnp.int32)     # (K8, TP) packed bytes
    r8, k8 = bm.shape
    _, tp = pk.shape
    # Unpack to bit lanes: (K8, TP, 8) -> (K8, TP*8), values {0,1}.
    bits = (pk[:, :, None] >> jax.lax.broadcasted_iota(jnp.int32, (1, 1, _BITS), 2)) & 1
    bits = bits.reshape(k8, tp * _BITS).astype(jnp.bfloat16)
    # Systolic matmul; f32 accumulation keeps counts (<= k8 < 2^24) exact.
    counts = jax.lax.dot_general(
        bm, bits, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    outbits = counts.astype(jnp.int32) & 1                    # (R8, TP*8)
    outbits = outbits.reshape(r8, tp, _BITS)
    weights = 1 << jax.lax.broadcasted_iota(jnp.int32, (1, 1, _BITS), 2)
    out_ref[...] = jnp.sum(outbits * weights, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def mod2_matmul_encode(bitmatrix: jax.Array, packets: jax.Array, *,
                       tile_p: int = 256, interpret: bool = False) -> jax.Array:
    """MXU-path CRS encode. bitmatrix (R8, K8) x packets (K8, P) -> (R8, P).

    VMEM per step (defaults, k=128 => K8=1024, TP=256): bits tensor
    1024 x 2048 bf16 = 4 MB + packets 256 KB + counts R8 x 2048 f32 — fits
    with double buffering. R8 (<= 72 for the paper's widest r+p) stays whole.
    """
    r8, k8 = bitmatrix.shape
    k8b, p = packets.shape
    if k8 != k8b:
        raise ValueError(f"shape mismatch {bitmatrix.shape} vs {packets.shape}")
    tp = min(tile_p, p)
    if p % tp:
        raise ValueError(f"P={p} must divide tile_p={tp}")
    bm16 = bitmatrix.astype(jnp.bfloat16)
    return pl.pallas_call(
        _mod2_kernel,
        grid=(p // tp,),
        in_specs=[
            pl.BlockSpec((r8, k8), lambda j: (0, 0)),
            pl.BlockSpec((k8, tp), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r8, tp), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r8, p), jnp.uint8),
        interpret=interpret,
    )(bm16, packets)


def _mod2_batched_kernel(bm_ref, pk_ref, out_ref):
    """One stripe's (R8, TP) output slab of the (S, R8, P) batched product.

    Same fused unpack->dot->mod2->repack chain as :func:`_mod2_kernel`; the
    grid's leading axis walks stripes, the bitmatrix rides along whole.
    """
    bm = bm_ref[...]                       # (R8, K8) bf16 of 0/1
    pk = pk_ref[0].astype(jnp.int32)       # block (1, K8, TP) -> (K8, TP)
    r8, k8 = bm.shape
    _, tp = pk.shape
    bits = (pk[:, :, None] >> jax.lax.broadcasted_iota(jnp.int32, (1, 1, _BITS), 2)) & 1
    bits = bits.reshape(k8, tp * _BITS).astype(jnp.bfloat16)
    counts = jax.lax.dot_general(
        bm, bits, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    outbits = counts.astype(jnp.int32) & 1                    # (R8, TP*8)
    outbits = outbits.reshape(r8, tp, _BITS)
    weights = 1 << jax.lax.broadcasted_iota(jnp.int32, (1, 1, _BITS), 2)
    out_ref[0] = jnp.sum(outbits * weights, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def mod2_matmul_encode_batched(bitmatrix: jax.Array, packets: jax.Array, *,
                               tile_p: int = 256,
                               interpret: bool = False) -> jax.Array:
    """Batched MXU-path apply: ``bitmatrix (R8, K8) x packets (S, K8, P) ->
    (S, R8, P)`` with a ``(S, P/TP)`` grid — one systolic launch per batch.

    VMEM per step matches :func:`mod2_matmul_encode` exactly (the stripe
    axis adds grid cells, not working-set bytes): for repair-sized plans
    (R8 <= 8*(r+p) <= 72) the bf16 bits tensor dominates, well inside the
    ~16 MB/core budget with double buffering.
    """
    r8, k8 = bitmatrix.shape
    s, k8b, p = packets.shape
    if k8 != k8b:
        raise ValueError(f"shape mismatch {bitmatrix.shape} vs {packets.shape}")
    tp = min(tile_p, p)
    if p % tp:
        raise ValueError(f"P={p} must divide tile_p={tp}")
    bm16 = bitmatrix.astype(jnp.bfloat16)
    return pl.pallas_call(
        _mod2_batched_kernel,
        grid=(s, p // tp),
        in_specs=[
            pl.BlockSpec((r8, k8), lambda si, j: (0, 0)),
            pl.BlockSpec((1, k8, tp), lambda si, j: (si, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, r8, tp), lambda si, j: (si, 0, j)),
        out_shape=jax.ShapeDtypeStruct((s, r8, p), jnp.uint8),
        interpret=interpret,
    )(bm16, packets)
