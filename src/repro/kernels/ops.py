"""Public ops for the erasure-coding kernels.

Dispatch layer: picks the Pallas kernel and falls back to interpreter
execution on CPU hosts (this container), with shape padding so callers never
worry about tile divisibility. ``backend``:

  "gf"    — gf256_matmul Pallas kernel (bit-serial VPU multiply)
  "crs"   — bitmatrix_encode Pallas kernel (select-and-XOR on bit-planes)
  "mxu"   — mod2_matmul_encode Pallas kernel (systolic mod-2 matmul)
  "ref"   — pure-jnp table oracle (no Pallas)

Every backend supports every op — encode, repair/decode combines, flat and
batched. The bit-plane backends ("crs"/"mxu") run general GF matmuls
through the packed bit-matrix expansion of the byte coefficient matrix
(``repro.core.gf.matrix_to_bitmatrix``): callers that hold a compiled plan
pass its cached expansion via ``bitmatrix=`` so the 8x blow-up is amortized
over every chunk of a failure pattern (DESIGN.md §11). There is no silent
backend downgrade anywhere in this module: unknown names raise, and the
one documented substitution (an interpreted "gf" batch runs the fused
table path, bit-identically, because the Pallas interpreter replays every
grid cell) is reported by :func:`effective_backend` and recorded in engine
and fleet telemetry.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gf import matrix_to_bitmatrix
from repro.dist.stripes import sharded_launch

from . import ref as ref_lib
from .bitmatrix_encode import (bitmatrix_encode, bitmatrix_encode_batched,
                               mod2_matmul_encode, mod2_matmul_encode_batched)
from .gf256_matmul import gf256_matmul, gf256_matmul_batched

BACKENDS = ("gf", "crs", "mxu", "ref")
# Backends whose general matmul runs on packed bit-planes (GF(2) algebra).
BIT_BACKENDS = ("crs", "mxu")


def require_backend(backend: str) -> str:
    """Validate a backend name, raising a clear error for unknown ones."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    return backend


def effective_backend(backend: str, *, interpret: bool | None = None,
                      force_pallas: bool = False) -> str:
    """The formulation a batched GF matmul with ``backend`` actually runs.

    Identical to ``backend`` everywhere except the one documented
    substitution: on interpreter hosts a "gf" batch executes the fused
    table path ("ref") instead of replaying the bit-serial kernel cell by
    cell — bit-identical, ~60x faster (see :func:`gf_matmul_batch_op`).
    The bit-plane backends keep their own formulation on every host (the
    interpreted path runs the same select-and-XOR / mod-2-matmul math as
    one fused XLA call), so they report as themselves. Engine and fleet
    telemetry record this value per launch; nothing downgrades silently.
    """
    require_backend(backend)
    if interpret is None:
        interpret = _on_cpu()
    if backend == "gf" and interpret and not force_pallas:
        return "ref"
    return backend


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _as_bitmatrix(coef, bitmatrix) -> jax.Array:
    """The GF(2) expansion of byte coeffs ``coef`` (m, t): the caller's
    precomputed ``bitmatrix`` (a compiled plan's cached expansion) when
    given — shape-checked against ``coef`` — else expanded here."""
    if bitmatrix is None:
        return jnp.asarray(matrix_to_bitmatrix(np.asarray(coef, np.uint8)))
    bm = jnp.asarray(bitmatrix, jnp.uint8)
    want = (coef.shape[0] * 8, coef.shape[1] * 8)
    if bm.shape != want:
        raise ValueError(f"bitmatrix shape {bm.shape} does not match the "
                         f"{coef.shape} coefficient matrix (want {want})")
    return bm


def gf_matmul_op(coef, data, *, backend: str = "gf",
                 interpret: bool | None = None,
                 bitmatrix=None) -> jax.Array:
    """GF(2^8) coef (m,k) @ data (k,B) -> (m,B); pads B to the tile size.

    All four backends: gf runs the bit-serial Pallas kernel, ref the jnp
    table oracle, and crs/mxu apply the coefficient matrix's packed
    bit-matrix on bit-plane packets (``bitmatrix=`` passes a precomputed
    expansion, e.g. a compiled plan's cached one).
    """
    require_backend(backend)
    if interpret is None:
        interpret = _on_cpu()
    coef = jnp.asarray(coef, jnp.uint8)
    data = jnp.asarray(data, jnp.uint8)
    if backend == "ref":
        return ref_lib.gf256_matmul_ref(coef, data)
    if backend in BIT_BACKENDS:
        bm = _as_bitmatrix(coef, bitmatrix)
        return _crs_bitmatrix_apply(bm, data, backend=backend,
                                    interpret=interpret)
    tile_b = 512 if not interpret else 128
    padded, b = _pad_axis(data, 1, tile_b)
    coef_p, m = _pad_axis(coef, 0, 8)
    out = gf256_matmul(coef_p, padded, tile_m=8,
                       tile_b=tile_b, interpret=interpret)
    return out[:m, :b]


def _gf_batch_kernel(coef, data, *, backend: str, interpret: bool,
                     force_pallas: bool) -> jax.Array:
    """Single-device body of the batched GF matmul (shard_map-able)."""
    if backend == "ref":
        return ref_lib.gf256_matmul_batched_ref(coef, data)
    if interpret and not force_pallas:
        return ref_lib.gf256_matmul_batched_ref(coef, data)
    tile_b = 512 if not interpret else 128
    padded, b = _pad_axis(data, 2, tile_b)
    coef_p, m = _pad_axis(coef, 0, 8)
    out = gf256_matmul_batched(coef_p, padded, tile_m=8,
                               tile_b=tile_b, interpret=interpret)
    return out[:, :m, :b]


def _bit_matmul_batch_kernel(bm, data, *, backend: str, interpret: bool,
                             force_pallas: bool) -> jax.Array:
    """Single-device body of the batched bit-plane matmul (shard_map-able).

    ``bm`` is the packed (8m, 8t) GF(2) expansion of a byte coefficient
    matrix, ``data`` the (S, t, B) read stack. Pads B to the packet
    granule, packetizes per stripe, runs the stripe-grid kernel, unpacks.
    On CPU hosts the interpreter replays every grid cell, so an
    interpreted batch runs the *same formulation* as one fused XLA call
    (the vmapped jnp oracles) — still select-and-XOR for crs and
    mod-2 matmul for mxu, so the backend identity is preserved;
    ``force_pallas=True`` runs the batched-grid kernel under the
    interpreter anyway (lockstep tests).
    """
    tile_p = 1024 if backend == "crs" else 256
    if interpret:
        tile_p = 64
    gran = 8 if (interpret and not force_pallas) else 8 * tile_p
    padded, b = _pad_axis(data, 2, gran)
    packets = ref_lib.packetize_batched(padded)
    if interpret and not force_pallas:
        fn = (ref_lib.bitmatrix_encode_batched_ref if backend == "crs"
              else ref_lib.mod2_matmul_encode_batched_ref)
        par = fn(bm, packets)
    elif backend == "crs":
        par = bitmatrix_encode_batched(bm, packets, tile_p=tile_p,
                                       interpret=interpret)
    else:
        par = mod2_matmul_encode_batched(bm, packets, tile_p=tile_p,
                                         interpret=interpret)
    return ref_lib.unpacketize_batched(par)[:, :, :b]


def gf_matmul_batch_op(coef, data, *, backend: str = "gf",
                       interpret: bool | None = None,
                       force_pallas: bool = False,
                       mesh_rules=None, bitmatrix=None) -> jax.Array:
    """Batched GF(2^8) ``coef (m,k) @ data (S,k,B) -> (S,m,B)``.

    One launch for the whole stripe batch; pads B to the tile size and m to
    the TM granule, exactly like :func:`gf_matmul_op`. All four backends:
    gf/ref run the byte-table/bit-serial grid, crs/mxu run the stripe-grid
    bit-plane kernels on the coefficient matrix's packed GF(2) expansion
    (``bitmatrix=`` passes a precomputed one — the batched engine hands in
    its compiled plan's cached expansion so a whole pattern chunk pays for
    exactly one 8x blow-up).

    On CPU hosts the Pallas interpreter is a correctness tool, not a
    throughput path (it replays every grid cell), so an interpreted "gf"
    batch executes as one fused table-path XLA call instead — bit-identical,
    ~60x faster than S interpreted launches — and the bit-plane backends
    run their own formulation as fused XLA calls. :func:`effective_backend`
    names what actually ran. ``force_pallas=True`` runs the batched-grid
    kernels under the interpreter anyway (lockstep tests).

    ``mesh_rules`` shards the stripe axis over the mesh's data axes and runs
    one launch per device via ``shard_map`` (repro.dist.stripes); an
    indivisible S degrades to the single-device launch. Stripes are
    independent, so the result is bit-identical either way.

    ``data`` is handed to :func:`~repro.dist.stripes.sharded_launch`
    *unconverted*: a host numpy stack scatters straight onto the stripe
    sharding and a pre-sharded global array passes through with zero
    re-transfer, so the batch never materializes on one device first.
    """
    require_backend(backend)
    if interpret is None:
        interpret = _on_cpu()
    coef = jnp.asarray(coef, jnp.uint8)
    if isinstance(data, np.ndarray):
        if data.dtype != np.uint8:
            data = np.ascontiguousarray(data, np.uint8)
    elif not isinstance(data, jax.Array) or data.dtype != jnp.uint8:
        data = jnp.asarray(data, jnp.uint8)
    if data.ndim != 3:
        raise ValueError(f"expected (S, k, B) data, got {data.shape}")
    if backend in BIT_BACKENDS:
        bm = _as_bitmatrix(coef, bitmatrix)
        return sharded_launch(_bit_matmul_batch_kernel, bm, data, mesh_rules,
                              backend=backend, interpret=interpret,
                              force_pallas=force_pallas)
    return sharded_launch(_gf_batch_kernel, coef, data, mesh_rules,
                          backend=backend, interpret=interpret,
                          force_pallas=force_pallas)


def _crs_bitmatrix_apply(bm, blocks, *, backend: str,
                         interpret: bool) -> jax.Array:
    """Bit-plane encode of byte blocks (k, B) by a precomputed bitmatrix."""
    tile_p = 1024 if backend == "crs" else 256
    if interpret:
        tile_p = 64
    gran = 8 * tile_p
    padded, b = _pad_axis(blocks, 1, gran)
    packets = ref_lib.packetize(padded)
    if backend == "crs":
        par = bitmatrix_encode(bm, packets, tile_p=tile_p, interpret=interpret)
    elif backend == "mxu":
        par = mod2_matmul_encode(bm, packets, tile_p=tile_p, interpret=interpret)
    elif backend == "ref":
        par = ref_lib.bitmatrix_encode_ref(bm, packets)
    else:
        raise ValueError(f"unknown backend {backend}")
    return ref_lib.unpacketize(par)[:, :b]


def crs_encode_op(coding: np.ndarray, blocks, *, backend: str = "crs",
                  interpret: bool | None = None) -> jax.Array:
    """CRS path: byte blocks (k, B) -> parity (m, B) via the bitmatrix of the
    GF coding matrix. B is padded to a multiple of the packet granularity."""
    if interpret is None:
        interpret = _on_cpu()
    blocks = jnp.asarray(blocks, jnp.uint8)
    bm = jnp.asarray(matrix_to_bitmatrix(np.asarray(coding, np.uint8)))
    return _crs_bitmatrix_apply(bm, blocks, backend=backend,
                                interpret=interpret)


def encode_op(coding: np.ndarray, blocks, *, backend: str = "gf",
              interpret: bool | None = None) -> jax.Array:
    """Unified stripe-parity computation across all backends."""
    require_backend(backend)
    if backend in ("gf", "ref"):
        return gf_matmul_op(np.asarray(coding, np.uint8), blocks,
                            backend=backend, interpret=interpret)
    return crs_encode_op(coding, blocks, backend=backend, interpret=interpret)


def encode_batch_op(coding: np.ndarray, blocks, *, backend: str = "gf",
                    interpret: bool | None = None,
                    mesh_rules=None, bitmatrix=None) -> jax.Array:
    """Batched stripe-parity: ``blocks (S, k, B) -> parity (S, m, B)``.

    Parity is a matmul of the generator's parity rows, so every backend
    routes through :func:`gf_matmul_batch_op`: gf/ref run the batched table
    /bit-serial grid, crs/mxu the stripe-grid bit-plane kernels (the coding
    matrix's packed expansion, passed via ``bitmatrix=`` when the caller
    caches it). ``mesh_rules`` shards the stripe axis over the mesh's data
    axes, one launch per device.
    """
    require_backend(backend)
    blocks = jnp.asarray(blocks, jnp.uint8)
    if blocks.ndim != 3:
        raise ValueError(f"expected (S, k, B) blocks, got {blocks.shape}")
    return gf_matmul_batch_op(np.asarray(coding, np.uint8), blocks,
                              backend=backend, interpret=interpret,
                              mesh_rules=mesh_rules, bitmatrix=bitmatrix)


def default_backend(fallback: str | None = None) -> str:
    """``REPRO_BACKEND`` when set (CI backend-matrix legs), else ``fallback``
    when given (e.g. the store's serving-tuned "ref"), else the MXU path on
    TPU (the §Perf winner for wide stripes) and gf elsewhere. Uncached so a
    test can monkeypatch the env var; constructors resolve it once via
    ``dataclasses.field(default_factory=...)``."""
    env = os.environ.get("REPRO_BACKEND")
    if env:
        return require_backend(env)
    if fallback is not None:
        return require_backend(fallback)
    return "mxu" if jax.default_backend() == "tpu" else "gf"
