"""Public ops for the erasure-coding kernels.

Dispatch layer: picks the Pallas kernel and falls back to interpreter
execution on CPU hosts (this container), with shape padding so callers never
worry about tile divisibility. ``backend``:

  "gf"    — gf256_matmul Pallas kernel (bit-serial VPU multiply)
  "crs"   — bitmatrix_encode Pallas kernel (select-and-XOR on bit-planes)
  "mxu"   — mod2_matmul_encode Pallas kernel (systolic mod-2 matmul)
  "ref"   — pure-jnp table oracle (no Pallas)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gf import matrix_to_bitmatrix
from repro.dist.stripes import sharded_launch

from . import ref as ref_lib
from .bitmatrix_encode import bitmatrix_encode, mod2_matmul_encode
from .gf256_matmul import gf256_matmul, gf256_matmul_batched

BACKENDS = ("gf", "crs", "mxu", "ref")


def require_backend(backend: str) -> str:
    """Validate a backend name, raising a clear error for unknown ones."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    return backend


def matmul_backend(backend: str) -> str:
    """Backend for general GF matmuls (repair/decode combines).

    The bit-plane encode backends ("crs"/"mxu") have no general-matmul
    formulation, so solve-style ops run on the jnp table path instead;
    anything outside BACKENDS raises.
    """
    require_backend(backend)
    return backend if backend in ("gf", "ref") else "ref"


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def gf_matmul_op(coef, data, *, backend: str = "gf",
                 interpret: bool | None = None) -> jax.Array:
    """GF(2^8) coef (m,k) @ data (k,B) -> (m,B); pads B to the tile size."""
    if interpret is None:
        interpret = _on_cpu()
    coef = jnp.asarray(coef, jnp.uint8)
    data = jnp.asarray(data, jnp.uint8)
    if backend == "ref":
        return ref_lib.gf256_matmul_ref(coef, data)
    if backend != "gf":
        raise ValueError(f"gf_matmul_op supports gf/ref, got {backend}")
    tile_b = 512 if not interpret else 128
    padded, b = _pad_axis(data, 1, tile_b)
    coef_p, m = _pad_axis(coef, 0, 8)
    out = gf256_matmul(coef_p, padded, tile_m=8,
                       tile_b=tile_b, interpret=interpret)
    return out[:m, :b]


def _gf_batch_kernel(coef, data, *, backend: str, interpret: bool,
                     force_pallas: bool) -> jax.Array:
    """Single-device body of the batched GF matmul (shard_map-able)."""
    if backend == "ref":
        return ref_lib.gf256_matmul_batched_ref(coef, data)
    if interpret and not force_pallas:
        return ref_lib.gf256_matmul_batched_ref(coef, data)
    tile_b = 512 if not interpret else 128
    padded, b = _pad_axis(data, 2, tile_b)
    coef_p, m = _pad_axis(coef, 0, 8)
    out = gf256_matmul_batched(coef_p, padded, tile_m=8,
                               tile_b=tile_b, interpret=interpret)
    return out[:, :m, :b]


def gf_matmul_batch_op(coef, data, *, backend: str = "gf",
                       interpret: bool | None = None,
                       force_pallas: bool = False,
                       mesh_rules=None) -> jax.Array:
    """Batched GF(2^8) ``coef (m,k) @ data (S,k,B) -> (S,m,B)``.

    One launch for the whole stripe batch; pads B to the tile size and m to
    the TM granule, exactly like :func:`gf_matmul_op`.

    On CPU hosts the Pallas interpreter is a correctness tool, not a
    throughput path (it replays every grid cell), so an interpreted "gf"
    batch executes as one fused table-path XLA call instead — bit-identical,
    ~60x faster than S interpreted launches. ``force_pallas=True`` runs the
    batched-grid kernel under the interpreter anyway (lockstep tests).

    ``mesh_rules`` shards the stripe axis over the mesh's data axes and runs
    one launch per device via ``shard_map`` (repro.dist.stripes); an
    indivisible S degrades to the single-device launch. Stripes are
    independent, so the result is bit-identical either way.

    ``data`` is handed to :func:`~repro.dist.stripes.sharded_launch`
    *unconverted*: a host numpy stack scatters straight onto the stripe
    sharding and a pre-sharded global array passes through with zero
    re-transfer, so the batch never materializes on one device first.
    """
    if interpret is None:
        interpret = _on_cpu()
    coef = jnp.asarray(coef, jnp.uint8)
    if isinstance(data, np.ndarray):
        if data.dtype != np.uint8:
            data = np.ascontiguousarray(data, np.uint8)
    elif not isinstance(data, jax.Array) or data.dtype != jnp.uint8:
        data = jnp.asarray(data, jnp.uint8)
    if data.ndim != 3:
        raise ValueError(f"expected (S, k, B) data, got {data.shape}")
    if backend not in ("gf", "ref"):
        raise ValueError(f"gf_matmul_batch_op supports gf/ref, got {backend}")
    return sharded_launch(_gf_batch_kernel, coef, data, mesh_rules,
                          backend=backend, interpret=interpret,
                          force_pallas=force_pallas)


def _crs_bitmatrix_apply(bm, blocks, *, backend: str,
                         interpret: bool) -> jax.Array:
    """Bit-plane encode of byte blocks (k, B) by a precomputed bitmatrix."""
    tile_p = 1024 if backend == "crs" else 256
    if interpret:
        tile_p = 64
    gran = 8 * tile_p
    padded, b = _pad_axis(blocks, 1, gran)
    packets = ref_lib.packetize(padded)
    if backend == "crs":
        par = bitmatrix_encode(bm, packets, tile_p=tile_p, interpret=interpret)
    elif backend == "mxu":
        par = mod2_matmul_encode(bm, packets, tile_p=tile_p, interpret=interpret)
    elif backend == "ref":
        par = ref_lib.bitmatrix_encode_ref(bm, packets)
    else:
        raise ValueError(f"unknown backend {backend}")
    return ref_lib.unpacketize(par)[:, :b]


def crs_encode_op(coding: np.ndarray, blocks, *, backend: str = "crs",
                  interpret: bool | None = None) -> jax.Array:
    """CRS path: byte blocks (k, B) -> parity (m, B) via the bitmatrix of the
    GF coding matrix. B is padded to a multiple of the packet granularity."""
    if interpret is None:
        interpret = _on_cpu()
    blocks = jnp.asarray(blocks, jnp.uint8)
    bm = jnp.asarray(matrix_to_bitmatrix(np.asarray(coding, np.uint8)))
    return _crs_bitmatrix_apply(bm, blocks, backend=backend,
                                interpret=interpret)


def _crs_batch_kernel(bm, blocks, *, backend: str,
                      interpret: bool) -> jax.Array:
    """Single-device body of the batched bit-plane encode (shard_map-able).

    The coding matrix applies column-wise, so the stripe axis folds into the
    byte axis — ``(S,k,B) -> (k, S*B)`` — and one 2-D launch covers the local
    batch (each output byte depends only on its own column; exact).
    """
    s, k, b = blocks.shape
    folded = jnp.transpose(blocks, (1, 0, 2)).reshape(k, s * b)
    par = _crs_bitmatrix_apply(bm, folded, backend=backend,
                               interpret=interpret)
    return jnp.transpose(par.reshape(-1, s, b), (1, 0, 2))


def encode_op(coding: np.ndarray, blocks, *, backend: str = "gf",
              interpret: bool | None = None) -> jax.Array:
    """Unified stripe-parity computation across all backends."""
    require_backend(backend)
    if backend in ("gf", "ref"):
        return gf_matmul_op(np.asarray(coding, np.uint8), blocks,
                            backend=backend, interpret=interpret)
    return crs_encode_op(coding, blocks, backend=backend, interpret=interpret)


def encode_batch_op(coding: np.ndarray, blocks, *, backend: str = "gf",
                    interpret: bool | None = None,
                    mesh_rules=None) -> jax.Array:
    """Batched stripe-parity: ``blocks (S, k, B) -> parity (S, m, B)``.

    gf/ref run the batched kernel directly; the bit-plane backends (crs/mxu)
    fold the stripe axis into the byte axis per device (see
    :func:`_crs_batch_kernel`). ``mesh_rules`` shards the stripe axis over
    the mesh's data axes, one launch per device.
    """
    require_backend(backend)
    blocks = jnp.asarray(blocks, jnp.uint8)
    if blocks.ndim != 3:
        raise ValueError(f"expected (S, k, B) blocks, got {blocks.shape}")
    if backend in ("gf", "ref"):
        return gf_matmul_batch_op(np.asarray(coding, np.uint8), blocks,
                                  backend=backend, interpret=interpret,
                                  mesh_rules=mesh_rules)
    if interpret is None:
        interpret = _on_cpu()
    bm = jnp.asarray(matrix_to_bitmatrix(np.asarray(coding, np.uint8)))
    return sharded_launch(_crs_batch_kernel, bm, blocks, mesh_rules,
                          backend=backend, interpret=interpret)


@functools.lru_cache(maxsize=None)
def default_backend() -> str:
    """MXU path on TPU (the §Perf winner for wide stripes), gf elsewhere."""
    return "mxu" if jax.default_backend() == "tpu" else "gf"
