"""Pallas TPU kernel: GF(2^8) matrix multiply for stripe encode/decode.

``out[m, B] = XOR_k gfmul(coef[m, k], data[k, B])``

TPU adaptation (see DESIGN.md §3): Jerasure's table-driven SIMD lookups do
not map to the TPU VPU (no fast byte gather across lanes). Instead each
scalar coefficient multiplies a whole VMEM tile of data bytes with the
bit-serial "Russian peasant" algorithm — 8 rounds of conditional-XOR plus an
``xtime`` step — which lowers to pure int32 lane ops. The coefficient matrix
is tiny (r x k <= 9 x 128) and rides along as a whole; the byte dimension is
tiled through VMEM with an explicit BlockSpec grid.

VMEM budget per grid step (defaults, int32 working set):
  data tile  k x TB x 4      = 128 x 512 x 4  = 256 KB
  out tile   TM x TB x 4     = 16 x 512 x 4   = 32 KB
  coef       TM x k x 4      = 8 KB
comfortably inside the ~16 MB/core VMEM including double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gf import PRIM_POLY

_XT = PRIM_POLY & 0xFF  # 0x1D: xtime reduction constant


def _gf256_tile_product(coef, data, *, k: int):
    """(TM, k) x (k, TB) GF(2^8) tile product on int32 working values.

    Shared body of the flat and batched kernels: loop data rows, bit-serial
    GF multiply, XOR-accumulate.
    """
    tm = coef.shape[0]
    tb = data.shape[1]

    def row_step(kk, acc):
        d = jax.lax.dynamic_slice(data, (kk, 0), (1, tb))       # (1, TB)
        c = jax.lax.dynamic_slice(coef, (0, kk), (tm, 1))       # (TM, 1)
        cur = jnp.broadcast_to(d, (tm, tb))
        cf = jnp.broadcast_to(c, (tm, tb))
        prod = jnp.zeros((tm, tb), jnp.int32)
        for _ in range(8):  # unrolled: static 8 rounds, pure VPU ops
            prod = prod ^ jnp.where((cf & 1) != 0, cur, 0)
            cur = ((cur << 1) & 0xFF) ^ jnp.where((cur & 0x80) != 0, _XT, 0)
            cf = cf >> 1
        return acc ^ prod

    return jax.lax.fori_loop(0, k, row_step, jnp.zeros((tm, tb), jnp.int32))


def _gf256_matmul_kernel(coef_ref, data_ref, out_ref, *, k: int):
    """One (TM, TB) output tile: loop data rows, bit-serial GF multiply."""
    coef = coef_ref[...].astype(jnp.int32)  # (TM, k)
    data = data_ref[...].astype(jnp.int32)  # (k, TB)
    out_ref[...] = _gf256_tile_product(coef, data, k=k).astype(jnp.uint8)


def _gf256_matmul_batched_kernel(coef_ref, data_ref, out_ref, *, k: int):
    """One stripe's (TM, TB) output tile of the (S, m, B) batched product.

    The grid's leading axis walks stripes; the coefficient block is shared
    across all of them (one compiled plan, S payloads).
    """
    coef = coef_ref[...].astype(jnp.int32)   # (TM, k)
    data = data_ref[0].astype(jnp.int32)     # block (1, k, TB) -> (k, TB)
    out_ref[0] = _gf256_tile_product(coef, data, k=k).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_b", "interpret"))
def gf256_matmul(coef: jax.Array, data: jax.Array, *,
                 tile_m: int = 8, tile_b: int = 512,
                 interpret: bool = False) -> jax.Array:
    """GF(2^8) product ``coef (m,k) @ data (k,B) -> (m,B)``, all uint8.

    ``interpret=True`` runs the kernel body in the Pallas interpreter (CPU
    correctness path); on TPU it compiles to a Mosaic kernel.
    """
    m, k = coef.shape
    k2, b = data.shape
    if k != k2:
        raise ValueError(f"shape mismatch: coef {coef.shape} vs data {data.shape}")
    tm = min(tile_m, m)
    tb = min(tile_b, b)
    if m % tm or b % tb:
        raise ValueError(f"(m={m}, B={b}) must divide tiles ({tm}, {tb}); pad first")
    grid = (m // tm, b // tb)
    return pl.pallas_call(
        functools.partial(_gf256_matmul_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, b), jnp.uint8),
        interpret=interpret,
    )(coef, data)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_b", "interpret"))
def gf256_matmul_batched(coef: jax.Array, data: jax.Array, *,
                         tile_m: int = 8, tile_b: int = 512,
                         interpret: bool = False) -> jax.Array:
    """Batched GF(2^8) product ``coef (m,k) @ data (S,k,B) -> (S,m,B)``.

    One Pallas launch covers every stripe in the batch: the grid gains a
    leading stripe axis ``(S, m/TM, B/TB)`` and the data/output BlockSpecs
    index it, while the (tiny) coefficient block is broadcast to all stripes.
    This is the executor's workhorse — a fleet repair becomes a single launch
    per failure pattern instead of S dispatches (DESIGN.md §4).
    """
    m, k = coef.shape
    s, k2, b = data.shape
    if k != k2:
        raise ValueError(f"shape mismatch: coef {coef.shape} vs data {data.shape}")
    tm = min(tile_m, m)
    tb = min(tile_b, b)
    if m % tm or b % tb:
        raise ValueError(f"(m={m}, B={b}) must divide tiles ({tm}, {tb}); pad first")
    grid = (s, m // tm, b // tb)
    return pl.pallas_call(
        functools.partial(_gf256_matmul_batched_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda si, i, j: (i, 0)),
            pl.BlockSpec((1, k, tb), lambda si, i, j: (si, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, tm, tb), lambda si, i, j: (si, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, m, b), jnp.uint8),
        interpret=interpret,
    )(coef, data)
