"""Pallas TPU kernels for the erasure-coding hot path.

Kernels (each with a pure-jnp oracle in ``ref.py``):
  gf256_matmul     — bit-serial GF(2^8) matmul (VPU)
  bitmatrix_encode — CRS select-and-XOR on packed bit-planes (VPU)
  mod2_matmul_encode — fused unpack/matmul-mod-2/repack (MXU)

``ops.py`` is the dispatch layer used by ``repro.core.codec`` and the
checkpoint stripe store.
"""
from .gf256_matmul import gf256_matmul  # noqa: F401
from .bitmatrix_encode import bitmatrix_encode, mod2_matmul_encode  # noqa: F401
from .ops import crs_encode_op, encode_op, gf_matmul_op  # noqa: F401
from . import ref  # noqa: F401
