"""Double-buffered async repair pipeline (DESIGN.md §7).

The batched engine made repair compute-efficient — one compiled plan and one
kernel launch per failure-pattern chunk — but ``StripeStore.repair_all``
remained *serial*: gather every surviving block for a chunk on the host,
then launch, then write back, leaving the device idle during I/O and the
disks idle during compute. The paper's repair wins are bandwidth-bound
(§VI; XORing Elephants makes the same point for HDFS), so the read path is
the wall-clock floor and the compute should hide behind it.

This module overlaps the three stages with a classic double buffer over
*stripe windows*:

* each failure-pattern group is split into windows of
  ``StoreConfig.pipeline_window`` stripes (capped by ``batch_stripes`` and
  the gathered-stack byte budget, and rounded to the mesh's stripe-axis
  device span so sharded launches keep their full parallelism);
* window *i+1*'s surviving blocks prefetch through *per-shard* reader
  pools: under a sharded mesh each device shard gets its own
  ``prefetch_threads``-wide pool — modelling each host's independent
  disks/NIC — filling its own host buffer with only the blocks its stripes
  need, assembled into the global batch via
  ``repro.dist.placement.assemble_shards`` (no single-host stack). Every
  read still goes through ``StripeStore._read_block`` — node liveness and
  the simulated per-node latency/bandwidth model apply unchanged, with the
  ``PlacementMap`` charging cross-shard reads at the configured remote
  multiplier — while window *i* runs through
  ``BatchedCodecEngine.execute`` (zero re-transfer on the pre-sharded
  batch);
* write-back of window *i*'s rebuilt blocks happens on a dedicated writer
  thread, overlapped with the launch of window *i+1*.

Window creation runs the locality-aware stripe scheduler
(``repro.dist.schedule``, ``schedule="locality"``): each window's sid list
is permuted so every stripe lands on the device slice whose serving host
shard owns the most of its surviving blocks — the per-shard reader pools
then fetch mostly shard-local blocks with no further changes, since the
pools follow the window's sid order by construction. Bit-identical (write-
back is keyed by sid) and never predicted worse than the contiguous order.

Failure injection mid-pipeline is first-class: a node that dies between
prefetch and launch surfaces as ``IOError`` on the affected read futures,
and the window *re-plans* — fresh ``_down_blocks`` per stripe, fresh
compiled plans for the (now larger) patterns — until it drains or the
pattern is genuinely unrecoverable. Results are bit-identical to the
synchronous path by construction: GF(2^8) decoding is exact, so windowing,
thread scheduling and re-planning change wall-clock only, never bytes.

Every stage records wall spans; :class:`PipelineResult` aggregates them so
overlap is *observable*: ``read+compute+write > wall`` is the pipeline
working, and ``overlap_seconds`` quantifies it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor, wait
from typing import Callable, Optional, Sequence

import numpy as np

from repro.dist.placement import assemble_shards, plan_gather
from repro.dist.stripes import align_stripe_window, stripe_axis_span

# A hook receives (stage, window_index) at: "prefetch" (reads submitted),
# "launch" (about to execute), "writeback" (write submitted), "replan"
# (window re-planning after mid-pipeline failures). Tests use it to inject
# node failures at precise pipeline points.
PipelineHook = Callable[[str, int], None]


def run_double_buffered(windows: Sequence, *, produce, consume,
                        writer: ThreadPoolExecutor) -> None:
    """The double-buffer loop shared by every windowed pipeline.

    Repair runs it forward (read → decode → write-back) and checkpoint
    encode runs it "in reverse" (pack → encode → persist); the loop itself
    is direction-agnostic:

    * ``produce(win)`` submits asynchronous production of ``win``'s input
      (reader-pool prefetch, host packing, ...) and returns a token;
    * ``consume(win, token)`` waits the token out, runs the window's
      device work, and returns either ``None`` (the window was handled
      entirely inline — e.g. a repair re-plan) or a zero-argument drain
      callable;
    * the drain callable runs on the dedicated ``writer`` thread,
      overlapped with the next window's consume.

    Window *i+1*'s production is always submitted before window *i* is
    consumed, so at steady state three consecutive windows are in flight:
    one producing, one computing, one draining. Drain errors surface after
    the last window (every future's result is collected).
    """
    drains: list[Future] = []
    pending = produce(windows[0]) if windows else None
    for i, win in enumerate(windows):
        nxt = produce(windows[i + 1]) if i + 1 < len(windows) else None
        drain = consume(win, pending)
        if drain is not None:
            drains.append(writer.submit(drain))
        pending = nxt
    wait(drains)
    for f in drains:
        f.result()                       # surface writer-thread errors


def _record_span(lock: threading.Lock, res: "PipelineResult", stage: str,
                 index: int, t0: float, t1: float) -> None:
    """Append a stage span and bump its aggregate, under the result lock
    (stages land from the coordinator, packer and writer threads)."""
    with lock:
        res.spans.append((stage, index, t0, t1))
        setattr(res, f"{stage}_seconds",
                getattr(res, f"{stage}_seconds") + (t1 - t0))


@dataclasses.dataclass(frozen=True)
class RepairWindow:
    """One pipeline unit: a slice of stripes sharing a failure pattern."""
    index: int
    sids: tuple[int, ...]
    down: frozenset[int]
    compiled: object                       # CompiledPlan


@dataclasses.dataclass
class _Fetch:
    """An in-flight window prefetch: futures filling per-shard buffers.

    ``layout`` is the window's device-shard geometry (None = degraded /
    single device, one buffer). With a layout, ``bufs[i]`` is shard *i*'s
    slice of the ``(S, |reads|, B)`` batch, filled only by that shard's
    reader pool.
    """
    window: RepairWindow
    shape: tuple[int, int, int]
    layout: Optional[list]                 # list[ShardSlice] | None
    bufs: list[np.ndarray]
    futures: list[Future]
    t_submit: float


@dataclasses.dataclass
class PipelineResult:
    """Aggregate spans + launch accounting for one pipeline run."""
    windows: int = 0
    launches: int = 0
    devices: int = 1
    device_launches: int = 0
    replans: int = 0
    read_seconds: float = 0.0              # sum of per-window prefetch spans
    compute_seconds: float = 0.0           # sum of launch (+ host copy) spans
    write_seconds: float = 0.0             # sum of write-back spans
    wall_seconds: float = 0.0
    spans: list = dataclasses.field(default_factory=list)  # (stage, win, t0, t1)
    # Stripe-scheduler predictions (repro.dist.schedule): shard-local reads
    # under the order the windows actually used vs. the contiguous order,
    # over schedule_total gather reads. Re-planned sub-windows are excluded
    # (the slow path repairs in regroup order).
    scheduled_local: int = 0
    contiguous_local: int = 0
    schedule_total: int = 0

    @property
    def busy_seconds(self) -> float:
        return self.read_seconds + self.compute_seconds + self.write_seconds

    @property
    def overlap_seconds(self) -> float:
        """Stage time hidden by pipelining (0 for a fully serial run)."""
        return max(0.0, self.busy_seconds - self.wall_seconds)


class RepairPipeline:
    """Drives windowed, double-buffered repair against one ``StripeStore``.

    One instance serves one ``repair_all`` call; the reader pool and writer
    thread live only for the duration of :meth:`run`.
    """

    def __init__(self, store, *, spare_of: Optional[dict[int, int]] = None,
                 dest_of: Optional[dict[tuple[int, int], int]] = None,
                 threads: Optional[int] = None,
                 byte_budget: Optional[int] = None,
                 options=None):
        from .options import RepairOptions

        o = options if options is not None else RepairOptions()
        self.store = store
        self.spare_of = spare_of
        # Per-block rebuild destinations ((sid, block) -> surviving node),
        # pre-computed by repair_all from the pre-repair placement snapshot;
        # applied at write-back (re-planned sub-windows included).
        self.dest_of = dest_of
        self.mesh_rules = o.mesh_rules
        self.placement = o.placement
        # Stripe->device-shard assignment per window ("locality" permutes
        # each window onto the shards owning its surviving blocks;
        # repro.dist.schedule). Applied at window creation, before any
        # prefetch is submitted, so the per-shard reader pools follow the
        # scheduled order automatically.
        self.schedule = o.schedule or "none"
        cfg = store.cfg
        self.window = int(o.window or cfg.pipeline_window or cfg.batch_stripes)
        # Reader width is per gather shard: each simulated host prefetches
        # its own shard's blocks through its own pool (its own disks/NIC),
        # so sharded gathers scale I/O with the shard count instead of
        # funnelling every read through one host-wide pool.
        self.threads = max(1, int(threads or cfg.prefetch_threads))
        self.byte_budget = byte_budget
        self.hook = o.pipeline_hook or (lambda stage, index: None)
        self._span_lock = threading.Lock()

    # ------------------------------------------------------------- windows
    def _windows(self, work: Sequence[tuple[list[int], frozenset[int], object]],
                 res: PipelineResult) -> list[RepairWindow]:
        from repro.dist.schedule import schedule_group

        from .stripestore import launch_step

        cfg = self.store.cfg
        out: list[RepairWindow] = []
        for sids, down, compiled in work:
            step = launch_step(cfg, len(compiled.reads), self.window,
                               **({} if self.byte_budget is None
                                  else {"byte_budget": self.byte_budget}))
            step = align_stripe_window(step, self.mesh_rules)
            # "global" assigns the whole pattern group's stripes across all
            # its windows in one exact solve (stripes may migrate between
            # windows); "locality"/"none" reduce to the per-chunk schedule.
            for cs in schedule_group(sids, compiled.reads, self.placement,
                                     self.mesh_rules, step=step,
                                     mode=self.schedule):
                res.scheduled_local += cs.scheduled_local
                res.contiguous_local += cs.contiguous_local
                res.schedule_total += cs.total_reads
                out.append(RepairWindow(len(out), cs.sids, down, compiled))
        return out

    # ------------------------------------------------------------- stages
    def _fill(self, buf: np.ndarray, i: int, j: int, sid: int, b: int,
              shard: int) -> None:
        buf[i, j] = self.store._read_block(sid, b, shard=shard,
                                           placement=self.placement)

    def _prefetch(self, pools: list[ThreadPoolExecutor], win: RepairWindow
                  ) -> _Fetch:
        """Submit a window's reads, partitioned per gather shard.

        Sharded windows fill one buffer per device shard through that
        shard's own reader pool; degraded windows (no mesh, or a ragged
        tail the span does not divide) fall back to one buffer on pool 0,
        attributed to gather shard 0 — matching the synchronous path
        bit-for-bit and count-for-count.
        """
        reads = win.compiled.reads
        shape = (len(win.sids), len(reads), self.store.cfg.block_size)
        layout, parts = plan_gather(shape, self.mesh_rules, self.placement)
        t0 = time.perf_counter()
        futures: list[Future] = []
        for part in parts:
            pool = pools[part.slice_.index % len(pools)] if layout \
                else pools[0]
            futures += [pool.submit(self._fill, part.buf, i, j, sid, b,
                                    part.shard)
                        for i, sid in enumerate(win.sids[part.lo:part.hi])
                        for j, b in enumerate(reads)]
        return _Fetch(win, shape, layout, [p.buf for p in parts],
                      futures, t0)

    def _collect(self, fetch: _Fetch, res: PipelineResult):
        """Wait out a prefetch. Returns the batch — a host stack for
        degraded windows, or the pre-sharded global array assembled from
        the per-shard buffers — or None when node deaths invalidated it
        (the window must re-plan). Non-I/O errors raise."""
        wait(fetch.futures)
        t1 = time.perf_counter()
        self._span(res, "read", fetch.window.index, fetch.t_submit, t1)
        io_failed = False
        for f in fetch.futures:
            err = f.exception()
            if err is None:
                continue
            if isinstance(err, IOError):
                io_failed = True
            else:
                raise err
        if io_failed:
            return None
        if fetch.layout is None:
            return fetch.bufs[0]
        return assemble_shards(fetch.shape, self.mesh_rules, fetch.layout,
                               fetch.bufs)

    def _launch(self, win: RepairWindow, stacked,
                res: PipelineResult) -> dict[int, np.ndarray]:
        engine = self.store.engine
        t0 = time.perf_counter()
        out = np.asarray(engine.execute(win.compiled, stacked,
                                        self.mesh_rules))
        t1 = time.perf_counter()
        self._span(res, "compute", win.index, t0, t1)
        res.launches += 1
        res.devices = max(res.devices, engine.last_span)
        res.device_launches += engine.last_span
        return {b: out[:, t, :] for t, b in enumerate(win.compiled.targets)}

    def _writeback(self, win: RepairWindow, rebuilt: dict[int, np.ndarray],
                   res: PipelineResult) -> None:
        t0 = time.perf_counter()
        self.store._finish_repair(list(win.sids), win.down, win.compiled.meta,
                                  rebuilt, self.spare_of, self.dest_of)
        t1 = time.perf_counter()
        self._span(res, "write", win.index, t0, t1)

    def _span(self, res: PipelineResult, stage: str, index: int,
              t0: float, t1: float) -> None:
        _record_span(self._span_lock, res, stage, index, t0, t1)

    # ------------------------------------------------------------- replan
    def _replan(self, pools: list[ThreadPoolExecutor], win: RepairWindow,
                res: PipelineResult) -> None:
        """Slow path: nodes died under this window's prefetch. Regroup its
        stripes by their *current* down sets, compile fresh plans, and
        repair synchronously (reads still fan out over the shard pools).
        Loops while further failures land; every retry consumes a new
        failure, so the node count bounds the iterations."""
        store = self.store
        pending = list(win.sids)
        for _ in range(1 + len(store.nodes)):
            if not pending:
                return
            res.replans += 1
            self.hook("replan", win.index)
            retry: list[int] = []
            groups: dict[frozenset[int], list[int]] = {}
            for sid in pending:
                groups.setdefault(store._down_blocks(sid), []).append(sid)
            for down, sids in sorted(groups.items(), key=lambda kv: kv[1][0]):
                try:
                    compiled = store.engine.planner.multi_plan(down)
                except RuntimeError:
                    raise IOError(f"stripes {sids} unrecoverable: "
                                  f"{sorted(down)}") from None
                sub = RepairWindow(win.index, tuple(sids), down, compiled)
                stacked = self._collect(self._prefetch(pools, sub), res)
                if stacked is None:          # yet another failure; go again
                    retry.extend(sids)
                    continue
                self._writeback(sub, self._launch(sub, stacked, res), res)
            pending = retry
        raise IOError(f"stripes {pending}: nodes kept failing during re-plan")

    # ---------------------------------------------------------------- run
    def run(self, work: Sequence[tuple[list[int], frozenset[int], object]]
            ) -> PipelineResult:
        """Repair ``[(sids, down, compiled), ...]`` pattern groups.

        The double buffer: wait on window *i*'s prefetch, immediately
        submit window *i+1*'s, then launch *i* and hand its write-back to
        the writer thread — so at steady state reads, compute and writes
        for three consecutive windows run concurrently.
        """
        res = PipelineResult()
        windows = self._windows(work, res)
        res.windows = len(windows)
        if not windows:
            return res
        t_run = time.perf_counter()
        # One reader pool per gather shard (each simulated host's own
        # disks); a single pool when the mesh degrades to one device.
        num_pools = max(1, stripe_axis_span(self.mesh_rules))
        with contextlib.ExitStack() as stack:
            readers = [stack.enter_context(ThreadPoolExecutor(
                self.threads, thread_name_prefix=f"repair-read-s{s}"))
                for s in range(num_pools)]
            writer = stack.enter_context(ThreadPoolExecutor(
                1, thread_name_prefix="repair-write"))

            def produce(win: RepairWindow) -> _Fetch:
                fetch = self._prefetch(readers, win)
                self.hook("prefetch", win.index)
                return fetch

            def consume(win: RepairWindow, fetch: _Fetch):
                stacked = self._collect(fetch, res)
                self.hook("launch", win.index)
                if stacked is None:
                    self._replan(readers, win, res)
                    return None
                rebuilt = self._launch(win, stacked, res)
                self.hook("writeback", win.index)
                return lambda: self._writeback(win, rebuilt, res)

            run_double_buffered(windows, produce=produce, consume=consume,
                                writer=writer)
        res.wall_seconds = time.perf_counter() - t_run
        return res


@dataclasses.dataclass(frozen=True)
class EncodeWindow:
    """One encode-pipeline unit: a run of consecutive stream stripes and
    the byte range of the snapshot buffer that fills them."""
    index: int
    first: int                             # first stream stripe
    count: int                             # stripes in this window
    lo: int                                # snapshot byte range [lo, hi)
    hi: int


class EncodePipeline:
    """The repair pipeline run in reverse: stream a frozen host buffer
    through batched encode into a store's streaming put path.

    The stage machinery is :func:`run_double_buffered` with the data flow
    mirrored — instead of reader pools filling a batch from disk for the
    decoder, a packer thread slices window *i+1*'s ``(S, k, B)`` plaintext
    batch out of the snapshot buffer (zero-padding the tail stripe exactly
    like ``seal``), window *i* encodes through
    ``BatchedCodecEngine.encode`` (MeshRules-sharded, any backend), and
    window *i-1*'s encoded stripes drain to disk on the writer thread via
    :meth:`StripeStreamWriter.write_window`. Chunking reuses
    ``launch_step`` (byte-budget-capped, mesh-span-aligned), so encode
    launches shard exactly like repair launches.

    Spans land in the same :class:`PipelineResult` vocabulary as repair:
    ``read_seconds`` is host packing, ``compute_seconds`` encode + device
    copy-off, ``write_seconds`` the drain, and ``overlap_seconds`` the
    stall the double buffer hides — the checkpoint benchmark's
    encode-overlap fraction is ``overlap / busy``.

    ``pipelined=False`` runs the identical stages strictly in sequence
    (the benchmark's serial baseline); bytes are identical either way.
    ``drain_stall`` sleeps that many wall seconds per drained window —
    the write-side analogue of ``StoreConfig.io_stall_scale``, making a
    slow persistence medium wall-real for overlap experiments.

    ``hook(stage, window_index)`` fires at "pack" (slice submitted),
    "encode" (window encoded), "drain" (window persisted) — tests use it
    to crash saves at precise pipeline points.
    """

    def __init__(self, store, *, window: Optional[int] = None,
                 mesh_rules=None, hook: Optional[PipelineHook] = None,
                 pipelined: bool = True, drain_stall: float = 0.0):
        self.store = store
        cfg = store.cfg
        self.mesh_rules = mesh_rules
        self.window = int(window or cfg.pipeline_window or cfg.batch_stripes)
        self.hook = hook or (lambda stage, index: None)
        self.pipelined = pipelined
        self.drain_stall = float(drain_stall)
        self._span_lock = threading.Lock()

    # ------------------------------------------------------------- windows
    def _windows(self, total_stripes: int) -> list[EncodeWindow]:
        from .stripestore import launch_step

        cfg = self.store.cfg
        # The "reads" of an encode window are the n blocks it will hold on
        # the host at once (k plaintext in, n encoded out).
        step = align_stripe_window(
            launch_step(cfg, self.store.n, self.window), self.mesh_rules)
        extent = cfg.k * cfg.block_size
        out: list[EncodeWindow] = []
        for first in range(0, total_stripes, step):
            count = min(step, total_stripes - first)
            out.append(EncodeWindow(len(out), first, count,
                                    first * extent, (first + count) * extent))
        return out

    # ------------------------------------------------------------- stages
    def _pack(self, flat: np.ndarray, win: EncodeWindow) -> np.ndarray:
        """Slice + zero-pad one window's plaintext batch off the snapshot."""
        cfg = self.store.cfg
        batch = np.zeros(win.count * cfg.k * cfg.block_size, np.uint8)
        src = flat[win.lo:min(win.hi, len(flat))]
        batch[:len(src)] = src
        return batch.reshape(win.count, cfg.k, cfg.block_size)

    def _encode(self, win: EncodeWindow, batch: np.ndarray,
                res: PipelineResult) -> np.ndarray:
        engine = self.store.engine
        t0 = time.perf_counter()
        out = np.asarray(engine.encode(batch, self.mesh_rules))
        t1 = time.perf_counter()
        _record_span(self._span_lock, res, "compute", win.index, t0, t1)
        res.launches += 1
        res.devices = max(res.devices, engine.last_span)
        res.device_launches += engine.last_span
        return out

    def _drain(self, stream, win: EncodeWindow, encoded: np.ndarray,
               res: PipelineResult) -> None:
        t0 = time.perf_counter()
        stream.write_window(win.first, encoded)
        if self.drain_stall > 0.0:
            time.sleep(self.drain_stall)
        t1 = time.perf_counter()
        _record_span(self._span_lock, res, "write", win.index, t0, t1)
        self.hook("drain", win.index)

    # ---------------------------------------------------------------- run
    def run(self, stream, flat: np.ndarray) -> PipelineResult:
        """Encode ``flat`` (the frozen snapshot bytes) into ``stream`` (a
        :class:`StripeStreamWriter` sized for it). The caller closes or
        aborts the stream — on error this raises with windows possibly
        half-drained, and the stream refuses to ``close``."""
        flat = np.asarray(flat, np.uint8).reshape(-1)
        res = PipelineResult()
        windows = self._windows(stream.num_stripes)
        res.windows = len(windows)
        if not windows:
            return res
        t_run = time.perf_counter()
        with contextlib.ExitStack() as stack:
            packer = stack.enter_context(ThreadPoolExecutor(
                1, thread_name_prefix="ckpt-pack"))
            writer = stack.enter_context(ThreadPoolExecutor(
                1, thread_name_prefix="ckpt-write"))

            def produce(win: EncodeWindow):
                t0 = time.perf_counter()
                fut = packer.submit(self._pack, flat, win)
                self.hook("pack", win.index)
                return (fut, t0)

            def consume(win: EncodeWindow, token):
                fut, t0 = token
                batch = fut.result()
                _record_span(self._span_lock, res, "read", win.index,
                             t0, time.perf_counter())
                encoded = self._encode(win, batch, res)
                self.hook("encode", win.index)
                return lambda: self._drain(stream, win, encoded, res)

            if self.pipelined:
                run_double_buffered(windows, produce=produce,
                                    consume=consume, writer=writer)
            else:
                for win in windows:        # serial baseline: no overlap
                    consume(win, produce(win))()
        res.wall_seconds = time.perf_counter() - t_run
        return res
