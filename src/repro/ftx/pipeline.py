"""Double-buffered async repair pipeline (DESIGN.md §7).

The batched engine made repair compute-efficient — one compiled plan and one
kernel launch per failure-pattern chunk — but ``StripeStore.repair_all``
remained *serial*: gather every surviving block for a chunk on the host,
then launch, then write back, leaving the device idle during I/O and the
disks idle during compute. The paper's repair wins are bandwidth-bound
(§VI; XORing Elephants makes the same point for HDFS), so the read path is
the wall-clock floor and the compute should hide behind it.

This module overlaps the three stages with a classic double buffer over
*stripe windows*:

* each failure-pattern group is split into windows of
  ``StoreConfig.pipeline_window`` stripes (capped by ``batch_stripes`` and
  the gathered-stack byte budget, and rounded to the mesh's stripe-axis
  device span so sharded launches keep their full parallelism);
* window *i+1*'s surviving blocks prefetch through *per-shard* reader
  pools: under a sharded mesh each device shard gets its own
  ``prefetch_threads``-wide pool — modelling each host's independent
  disks/NIC — filling its own host buffer with only the blocks its stripes
  need, assembled into the global batch via
  ``repro.dist.placement.assemble_shards`` (no single-host stack). Every
  read still goes through ``StripeStore._read_block`` — node liveness and
  the simulated per-node latency/bandwidth model apply unchanged, with the
  ``PlacementMap`` charging cross-shard reads at the configured remote
  multiplier — while window *i* runs through
  ``BatchedCodecEngine.execute`` (zero re-transfer on the pre-sharded
  batch);
* write-back of window *i*'s rebuilt blocks happens on a dedicated writer
  thread, overlapped with the launch of window *i+1*.

Window creation runs the locality-aware stripe scheduler
(``repro.dist.schedule``, ``schedule="locality"``): each window's sid list
is permuted so every stripe lands on the device slice whose serving host
shard owns the most of its surviving blocks — the per-shard reader pools
then fetch mostly shard-local blocks with no further changes, since the
pools follow the window's sid order by construction. Bit-identical (write-
back is keyed by sid) and never predicted worse than the contiguous order.

Failure injection mid-pipeline is first-class: a node that dies between
prefetch and launch surfaces as ``IOError`` on the affected read futures,
and the window *re-plans* — fresh ``_down_blocks`` per stripe, fresh
compiled plans for the (now larger) patterns — until it drains or the
pattern is genuinely unrecoverable. Results are bit-identical to the
synchronous path by construction: GF(2^8) decoding is exact, so windowing,
thread scheduling and re-planning change wall-clock only, never bytes.

Every stage records wall spans; :class:`PipelineResult` aggregates them so
overlap is *observable*: ``read+compute+write > wall`` is the pipeline
working, and ``overlap_seconds`` quantifies it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor, wait
from typing import Callable, Optional, Sequence

import numpy as np

from repro.dist.placement import assemble_shards, plan_gather
from repro.dist.stripes import align_stripe_window, stripe_axis_span

# A hook receives (stage, window_index) at: "prefetch" (reads submitted),
# "launch" (about to execute), "writeback" (write submitted), "replan"
# (window re-planning after mid-pipeline failures). Tests use it to inject
# node failures at precise pipeline points.
PipelineHook = Callable[[str, int], None]


@dataclasses.dataclass(frozen=True)
class RepairWindow:
    """One pipeline unit: a slice of stripes sharing a failure pattern."""
    index: int
    sids: tuple[int, ...]
    down: frozenset[int]
    compiled: object                       # CompiledPlan


@dataclasses.dataclass
class _Fetch:
    """An in-flight window prefetch: futures filling per-shard buffers.

    ``layout`` is the window's device-shard geometry (None = degraded /
    single device, one buffer). With a layout, ``bufs[i]`` is shard *i*'s
    slice of the ``(S, |reads|, B)`` batch, filled only by that shard's
    reader pool.
    """
    window: RepairWindow
    shape: tuple[int, int, int]
    layout: Optional[list]                 # list[ShardSlice] | None
    bufs: list[np.ndarray]
    futures: list[Future]
    t_submit: float


@dataclasses.dataclass
class PipelineResult:
    """Aggregate spans + launch accounting for one pipeline run."""
    windows: int = 0
    launches: int = 0
    devices: int = 1
    device_launches: int = 0
    replans: int = 0
    read_seconds: float = 0.0              # sum of per-window prefetch spans
    compute_seconds: float = 0.0           # sum of launch (+ host copy) spans
    write_seconds: float = 0.0             # sum of write-back spans
    wall_seconds: float = 0.0
    spans: list = dataclasses.field(default_factory=list)  # (stage, win, t0, t1)
    # Stripe-scheduler predictions (repro.dist.schedule): shard-local reads
    # under the order the windows actually used vs. the contiguous order,
    # over schedule_total gather reads. Re-planned sub-windows are excluded
    # (the slow path repairs in regroup order).
    scheduled_local: int = 0
    contiguous_local: int = 0
    schedule_total: int = 0

    @property
    def busy_seconds(self) -> float:
        return self.read_seconds + self.compute_seconds + self.write_seconds

    @property
    def overlap_seconds(self) -> float:
        """Stage time hidden by pipelining (0 for a fully serial run)."""
        return max(0.0, self.busy_seconds - self.wall_seconds)


class RepairPipeline:
    """Drives windowed, double-buffered repair against one ``StripeStore``.

    One instance serves one ``repair_all`` call; the reader pool and writer
    thread live only for the duration of :meth:`run`.
    """

    def __init__(self, store, *, spare_of: Optional[dict[int, int]] = None,
                 threads: Optional[int] = None,
                 byte_budget: Optional[int] = None,
                 options=None, **legacy):
        from .options import RepairOptions, resolve_options

        # The legacy ``hook=`` kwarg is the options object's
        # ``pipeline_hook`` field; translate before folding.
        if "hook" in legacy:
            legacy["pipeline_hook"] = legacy.pop("hook")
        o = resolve_options(options, legacy, RepairOptions, "RepairPipeline")
        self.store = store
        self.spare_of = spare_of
        self.mesh_rules = o.mesh_rules
        self.placement = o.placement
        # Stripe->device-shard assignment per window ("locality" permutes
        # each window onto the shards owning its surviving blocks;
        # repro.dist.schedule). Applied at window creation, before any
        # prefetch is submitted, so the per-shard reader pools follow the
        # scheduled order automatically.
        self.schedule = o.schedule or "none"
        cfg = store.cfg
        self.window = int(o.window or cfg.pipeline_window or cfg.batch_stripes)
        # Reader width is per gather shard: each simulated host prefetches
        # its own shard's blocks through its own pool (its own disks/NIC),
        # so sharded gathers scale I/O with the shard count instead of
        # funnelling every read through one host-wide pool.
        self.threads = max(1, int(threads or cfg.prefetch_threads))
        self.byte_budget = byte_budget
        self.hook = o.pipeline_hook or (lambda stage, index: None)
        self._span_lock = threading.Lock()

    # ------------------------------------------------------------- windows
    def _windows(self, work: Sequence[tuple[list[int], frozenset[int], object]],
                 res: PipelineResult) -> list[RepairWindow]:
        from repro.dist.schedule import schedule_chunk

        from .stripestore import launch_step

        cfg = self.store.cfg
        out: list[RepairWindow] = []
        for sids, down, compiled in work:
            step = launch_step(cfg, len(compiled.reads), self.window,
                               **({} if self.byte_budget is None
                                  else {"byte_budget": self.byte_budget}))
            step = align_stripe_window(step, self.mesh_rules)
            for lo in range(0, len(sids), step):
                cs = schedule_chunk(sids[lo:lo + step], compiled.reads,
                                    self.placement, self.mesh_rules,
                                    self.schedule)
                res.scheduled_local += cs.scheduled_local
                res.contiguous_local += cs.contiguous_local
                res.schedule_total += cs.total_reads
                out.append(RepairWindow(len(out), cs.sids, down, compiled))
        return out

    # ------------------------------------------------------------- stages
    def _fill(self, buf: np.ndarray, i: int, j: int, sid: int, b: int,
              shard: int) -> None:
        buf[i, j] = self.store._read_block(sid, b, shard=shard,
                                           placement=self.placement)

    def _prefetch(self, pools: list[ThreadPoolExecutor], win: RepairWindow
                  ) -> _Fetch:
        """Submit a window's reads, partitioned per gather shard.

        Sharded windows fill one buffer per device shard through that
        shard's own reader pool; degraded windows (no mesh, or a ragged
        tail the span does not divide) fall back to one buffer on pool 0,
        attributed to gather shard 0 — matching the synchronous path
        bit-for-bit and count-for-count.
        """
        reads = win.compiled.reads
        shape = (len(win.sids), len(reads), self.store.cfg.block_size)
        layout, parts = plan_gather(shape, self.mesh_rules, self.placement)
        t0 = time.perf_counter()
        futures: list[Future] = []
        for part in parts:
            pool = pools[part.slice_.index % len(pools)] if layout \
                else pools[0]
            futures += [pool.submit(self._fill, part.buf, i, j, sid, b,
                                    part.shard)
                        for i, sid in enumerate(win.sids[part.lo:part.hi])
                        for j, b in enumerate(reads)]
        return _Fetch(win, shape, layout, [p.buf for p in parts],
                      futures, t0)

    def _collect(self, fetch: _Fetch, res: PipelineResult):
        """Wait out a prefetch. Returns the batch — a host stack for
        degraded windows, or the pre-sharded global array assembled from
        the per-shard buffers — or None when node deaths invalidated it
        (the window must re-plan). Non-I/O errors raise."""
        wait(fetch.futures)
        t1 = time.perf_counter()
        self._span(res, "read", fetch.window.index, fetch.t_submit, t1)
        io_failed = False
        for f in fetch.futures:
            err = f.exception()
            if err is None:
                continue
            if isinstance(err, IOError):
                io_failed = True
            else:
                raise err
        if io_failed:
            return None
        if fetch.layout is None:
            return fetch.bufs[0]
        return assemble_shards(fetch.shape, self.mesh_rules, fetch.layout,
                               fetch.bufs)

    def _launch(self, win: RepairWindow, stacked,
                res: PipelineResult) -> dict[int, np.ndarray]:
        engine = self.store.engine
        t0 = time.perf_counter()
        out = np.asarray(engine.execute(win.compiled, stacked,
                                        self.mesh_rules))
        t1 = time.perf_counter()
        self._span(res, "compute", win.index, t0, t1)
        res.launches += 1
        res.devices = max(res.devices, engine.last_span)
        res.device_launches += engine.last_span
        return {b: out[:, t, :] for t, b in enumerate(win.compiled.targets)}

    def _writeback(self, win: RepairWindow, rebuilt: dict[int, np.ndarray],
                   res: PipelineResult) -> None:
        t0 = time.perf_counter()
        self.store._finish_repair(list(win.sids), win.down, win.compiled.meta,
                                  rebuilt, self.spare_of)
        t1 = time.perf_counter()
        self._span(res, "write", win.index, t0, t1)

    def _span(self, res: PipelineResult, stage: str, index: int,
              t0: float, t1: float) -> None:
        with self._span_lock:
            res.spans.append((stage, index, t0, t1))
            setattr(res, f"{stage}_seconds",
                    getattr(res, f"{stage}_seconds") + (t1 - t0))

    # ------------------------------------------------------------- replan
    def _replan(self, pools: list[ThreadPoolExecutor], win: RepairWindow,
                res: PipelineResult) -> None:
        """Slow path: nodes died under this window's prefetch. Regroup its
        stripes by their *current* down sets, compile fresh plans, and
        repair synchronously (reads still fan out over the shard pools).
        Loops while further failures land; every retry consumes a new
        failure, so the node count bounds the iterations."""
        store = self.store
        pending = list(win.sids)
        for _ in range(1 + len(store.nodes)):
            if not pending:
                return
            res.replans += 1
            self.hook("replan", win.index)
            retry: list[int] = []
            groups: dict[frozenset[int], list[int]] = {}
            for sid in pending:
                groups.setdefault(store._down_blocks(sid), []).append(sid)
            for down, sids in sorted(groups.items(), key=lambda kv: kv[1][0]):
                try:
                    compiled = store.engine.planner.multi_plan(down)
                except RuntimeError:
                    raise IOError(f"stripes {sids} unrecoverable: "
                                  f"{sorted(down)}") from None
                sub = RepairWindow(win.index, tuple(sids), down, compiled)
                stacked = self._collect(self._prefetch(pools, sub), res)
                if stacked is None:          # yet another failure; go again
                    retry.extend(sids)
                    continue
                self._writeback(sub, self._launch(sub, stacked, res), res)
            pending = retry
        raise IOError(f"stripes {pending}: nodes kept failing during re-plan")

    # ---------------------------------------------------------------- run
    def run(self, work: Sequence[tuple[list[int], frozenset[int], object]]
            ) -> PipelineResult:
        """Repair ``[(sids, down, compiled), ...]`` pattern groups.

        The double buffer: wait on window *i*'s prefetch, immediately
        submit window *i+1*'s, then launch *i* and hand its write-back to
        the writer thread — so at steady state reads, compute and writes
        for three consecutive windows run concurrently.
        """
        res = PipelineResult()
        windows = self._windows(work, res)
        res.windows = len(windows)
        if not windows:
            return res
        t_run = time.perf_counter()
        # One reader pool per gather shard (each simulated host's own
        # disks); a single pool when the mesh degrades to one device.
        num_pools = max(1, stripe_axis_span(self.mesh_rules))
        with contextlib.ExitStack() as stack:
            readers = [stack.enter_context(ThreadPoolExecutor(
                self.threads, thread_name_prefix=f"repair-read-s{s}"))
                for s in range(num_pools)]
            writer = stack.enter_context(ThreadPoolExecutor(
                1, thread_name_prefix="repair-write"))
            writes: list[Future] = []
            cur = self._prefetch(readers, windows[0])
            self.hook("prefetch", 0)
            for i, win in enumerate(windows):
                nxt = None
                if i + 1 < len(windows):
                    nxt = self._prefetch(readers, windows[i + 1])
                    self.hook("prefetch", i + 1)
                stacked = self._collect(cur, res)
                self.hook("launch", i)
                if stacked is None:
                    self._replan(readers, win, res)
                else:
                    rebuilt = self._launch(win, stacked, res)
                    writes.append(writer.submit(self._writeback, win,
                                                rebuilt, res))
                    self.hook("writeback", i)
                cur = nxt
            wait(writes)
            for f in writes:
                f.result()                   # surface writer-thread errors
        res.wall_seconds = time.perf_counter() - t_run
        return res
