"""Background rebalancer: windowed block migration after fleet changes.

Repair restores *durability*; it does not restore *balance*. After a
failure-domain loss, ``pick_destinations`` (repro.dist.topology) piles the
rebuilt blocks onto the least-loaded survivors — correct, but the survivors
now carry more than their share, and after a fleet *expansion* the new
nodes carry nothing at all. This module closes the loop (DESIGN.md §14):

* :func:`plan_moves` computes a deterministic list of single-block
  :class:`Move`\\ s that smooths the resident-block load across UP nodes —
  greedy max-to-min transfers, each filtered through
  :func:`~repro.dist.topology.placement_ok` so a move never violates the
  placement policy's durability invariants (copyset width for ``spread``,
  per-domain dispersion for ``round_robin``).
* :class:`Rebalancer` executes the plan through the same double-buffer
  loop the repair and checkpoint pipelines use
  (:func:`~repro.ftx.pipeline.run_double_buffered`): window *i+1*'s source
  blocks prefetch on a reader pool while window *i* commits on the writer
  thread — migration is pure data movement, so the "compute" stage is
  empty and the overlap is read-vs-write.

A move commits atomically from the store's point of view: the block's
bytes land at the destination path, the stripe's ``node_of_block`` entry
flips, and only then is the source replica unlinked — a crash between
write and unlink leaves a harmless orphan file, never a missing block.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.dist.placement import block_loads
from repro.dist.topology import placement_ok

from .pipeline import PipelineHook, run_double_buffered


@dataclasses.dataclass(frozen=True)
class Move:
    """One planned migration: stripe ``sid``'s ``block`` from node ``src``
    to node ``dst``."""
    sid: int
    block: int
    src: int
    dst: int


@dataclasses.dataclass
class RebalanceReport:
    """What a rebalance pass planned, moved, and won."""
    planned: int = 0                   # moves the planner emitted
    moved: int = 0                     # moves actually committed
    windows: int = 0
    bytes_moved: int = 0
    imbalance_before: int = 0          # max - min resident blocks (UP nodes)
    imbalance_after: int = 0
    read_seconds: float = 0.0
    write_seconds: float = 0.0
    wall_seconds: float = 0.0

    @property
    def overlap_seconds(self) -> float:
        """Stage time the double buffer hid (0 for a serial pass)."""
        return max(0.0, self.read_seconds + self.write_seconds
                   - self.wall_seconds)


def _imbalance(loads: dict[int, int], alive) -> int:
    vals = [loads.get(n, 0) for n in alive]
    return (max(vals) - min(vals)) if vals else 0


def _no_worse(policy: str, topo, trial: list[int],
              current: list[int]) -> bool:
    """Move legality: the trial placement satisfies the policy invariant,
    or is at least no worse than the current one.

    After a saturated-copyset relocation a stripe can already exceed the
    policy's width/dispersion bound; rejecting every move would then
    freeze exactly the stripes most in need of rebalancing. Distinctness
    is always mandatory; beyond it a move may keep the violation level,
    never raise it."""
    if placement_ok(policy, topo, trial):
        return True
    if len(set(trial)) != len(trial):
        return False
    if policy == "spread":
        def width(nodes):
            return len({topo.domain_of(n) for n in nodes})
        return width(trial) <= width(current)
    if policy == "round_robin":
        def worst(nodes):
            per: dict[int, int] = {}
            for n in nodes:
                d = topo.domain_of(n)
                per[d] = per.get(d, 0) + 1
            return max(per.values())
        return worst(trial) <= worst(current)
    return False


def plan_moves(store, *, max_moves: Optional[int] = None) -> list[Move]:
    """Plan load-smoothing single-block moves for ``store``.

    Greedy max-to-min: repeatedly take the most-loaded UP node and move one
    of its blocks to the least-loaded UP node that (a) holds no block of
    the same stripe and (b) keeps :func:`placement_ok` true for the
    stripe's policy — so rebalancing never widens a ``spread`` copyset
    beyond the policy bound and never breaks ``round_robin`` dispersion.
    Stops when the UP-node spread is <= 1 block (perfectly smooth up to
    integrality) or no legal move reduces it.

    Blocks still resident on DOWN nodes are treated as *must-move*
    (drained first): after an in-place repair of a permanently lost node
    they are unreadable addresses, and draining them is exactly the
    "migrate stripes after domain loss" case.

    Deterministic in the store's stripe index and node states: candidate
    blocks scan in ``(sid, block)`` order, destinations break ties on the
    lower node id.

    Args:
        store: a ``StripeStore``; the plan reads its live placement only.
        max_moves: optional cap on the plan length.

    Returns:
        Moves in commit order. Later moves assume earlier ones applied
        (the planner tracks loads on a scratch copy).
    """
    alive = sorted(n for n, s in store.nodes.items() if s.name == "UP")
    if not alive:
        return []
    topo = store.topology
    policy = store.cfg.placement_policy
    # Scratch placement the plan mutates; skips the open (unsealed) stripe
    # whose blocks have no disk replicas yet.
    placed = {sid: list(st.node_of_block)
              for sid, st in store.stripes.items()
              if sid != store._open_sid}
    loads = block_loads(placed.values(), store.num_nodes)
    blocks_of: dict[int, list[tuple[int, int]]] = {n: [] for n in loads}
    for sid in sorted(placed):
        for b, n in enumerate(placed[sid]):
            blocks_of[n].append((sid, b))
    alive_set = set(alive)

    # Each (sid, block) moves at most once per plan: a re-move would let a
    # later window's prefetch race the earlier window's source unlink.
    moved_keys: set[tuple[int, int]] = set()

    def try_move(src: int) -> Optional[Move]:
        """Cheapest legal move off ``src``, or None."""
        dsts = sorted((n for n in alive if n != src),
                      key=lambda n: (loads.get(n, 0), n))
        for sid, b in blocks_of[src]:
            if (sid, b) in moved_keys:
                continue
            nodes = placed[sid]
            for dst in dsts:
                if loads.get(dst, 0) >= loads.get(src, 0) - 1 \
                        and src in alive_set:
                    break                  # no dst strictly smooths an UP src
                if dst in nodes:
                    continue
                trial = list(nodes)
                trial[b] = dst
                if _no_worse(policy, topo, trial, nodes):
                    return Move(sid=sid, block=b, src=src, dst=dst)
        return None

    out: list[Move] = []

    def commit(m: Move) -> None:
        placed[m.sid][m.block] = m.dst
        blocks_of[m.src].remove((m.sid, m.block))
        blocks_of[m.dst].append((m.sid, m.block))
        loads[m.src] = loads.get(m.src, 0) - 1
        loads[m.dst] = loads.get(m.dst, 0) + 1
        moved_keys.add((m.sid, m.block))
        out.append(m)

    # Phase 1 — drain DOWN nodes that still hold block addresses.
    for src in sorted(n for n in blocks_of
                      if n not in alive_set and blocks_of[n]):
        while blocks_of[src]:
            if max_moves is not None and len(out) >= max_moves:
                return out
            m = try_move(src)
            if m is None:
                break                      # stripe has no legal live home
            commit(m)

    # Phase 2 — smooth the UP-node spread toward <= 1. Donors are scanned
    # in descending load order: the max-loaded node may have no legal move
    # (every candidate violates the policy invariant) while a lighter one
    # still does, so one stuck donor must not end the pass.
    while max_moves is None or len(out) < max_moves:
        if _imbalance(loads, alive) <= 1:
            break
        floor = min(loads.get(n, 0) for n in alive)
        m = None
        for src in sorted(alive, key=lambda n: (-loads.get(n, 0), n)):
            if loads.get(src, 0) - floor <= 1:
                break                      # remaining donors are smooth
            m = try_move(src)
            if m is not None:
                break
        if m is None:
            break                          # no legal smoothing move left
        commit(m)
    return out


class Rebalancer:
    """Executes a move plan through the shared double-buffer loop.

    One instance serves one :meth:`run` call. Windows are fixed-size
    slices of the plan (``window`` moves each, default the store's
    ``pipeline_window`` or ``batch_stripes``); window *i+1*'s source
    blocks prefetch on the reader pool while window *i*'s writes drain on
    the writer thread — the same three-windows-in-flight steady state as
    :class:`~repro.ftx.pipeline.RepairPipeline`, with an empty compute
    stage.

    ``hook(stage, window_index)`` fires at ``"prefetch"`` (reads
    submitted) and ``"commit"`` (window committed), mirroring the repair
    pipeline's hook vocabulary for failure-injection tests.
    """

    def __init__(self, store, *, window: Optional[int] = None,
                 hook: Optional[PipelineHook] = None, readers: int = 4,
                 pipelined: bool = True):
        self.store = store
        cfg = store.cfg
        self.window = int(window or cfg.pipeline_window or cfg.batch_stripes)
        self.hook = hook or (lambda stage, index: None)
        self.readers = max(1, int(readers))
        self.pipelined = pipelined

    # ------------------------------------------------------------- stages
    def _prefetch(self, pool: ThreadPoolExecutor,
                  win: list[Move]) -> list[Future]:
        # Reads go through the serving path: a live source is a direct
        # disk read, a source on a DOWN node (the phase-1 drain case) is
        # rebuilt through the degraded-read decode — moving a block never
        # trusts a dead node's address.
        return [pool.submit(self.store.read, m.sid, m.block)
                for m in win]

    def _commit(self, win: list[Move], blocks: list[np.ndarray],
                rep: RebalanceReport) -> None:
        t0 = time.perf_counter()
        st = self.store
        for m, data in zip(win, blocks):
            stripe = st.stripes[m.sid]
            if stripe.node_of_block[m.block] != m.src:
                continue                   # placement changed under us: skip
            old_path = st._block_path(m.sid, m.block)
            stripe.node_of_block[m.block] = m.dst
            st._write_block(m.sid, m.block, data)
            old_path.unlink(missing_ok=True)
            rep.moved += 1
            rep.bytes_moved += int(data.size)
        rep.write_seconds += time.perf_counter() - t0

    # ---------------------------------------------------------------- run
    def run(self, moves: Optional[list[Move]] = None, *,
            max_moves: Optional[int] = None) -> RebalanceReport:
        """Plan (unless ``moves`` is given) and execute a rebalance pass.

        Returns a :class:`RebalanceReport`; the store's placement and the
        on-disk replicas reflect every committed move on return, and
        ``save_manifest`` persists the new placement like any other.
        """
        st = self.store
        alive = [n for n, s in st.nodes.items() if s.name == "UP"]
        before = block_loads(
            (s.node_of_block for sid, s in st.stripes.items()
             if sid != st._open_sid), st.num_nodes)
        if moves is None:
            moves = plan_moves(st, max_moves=max_moves)
        rep = RebalanceReport(planned=len(moves),
                              imbalance_before=_imbalance(before, alive))
        windows = [(i, moves[lo:lo + self.window]) for i, lo in
                   enumerate(range(0, len(moves), self.window))]
        rep.windows = len(windows)
        t_run = time.perf_counter()
        if windows:
            with ThreadPoolExecutor(self.readers,
                                    thread_name_prefix="rebal-read") as pool, \
                    ThreadPoolExecutor(1, thread_name_prefix="rebal-write") \
                    as writer:

                def produce(win):
                    idx, chunk = win
                    t0 = time.perf_counter()
                    futs = self._prefetch(pool, chunk)
                    self.hook("prefetch", idx)
                    return (futs, t0)

                def consume(win, token):
                    idx, chunk = win
                    futs, t0 = token
                    blocks = [f.result() for f in futs]
                    rep.read_seconds += time.perf_counter() - t0

                    def drain():
                        self._commit(chunk, blocks, rep)
                        self.hook("commit", idx)
                    return drain

                if self.pipelined:
                    run_double_buffered(windows, produce=produce,
                                        consume=consume, writer=writer)
                else:
                    for win in windows:
                        drain = consume(win, produce(win))
                        drain()
        rep.wall_seconds = time.perf_counter() - t_run
        after = block_loads(
            (s.node_of_block for sid, s in st.stripes.items()
             if sid != st._open_sid), st.num_nodes)
        rep.imbalance_after = _imbalance(after, alive)
        return rep


def rebalance(store, *, window: Optional[int] = None,
              max_moves: Optional[int] = None,
              hook: Optional[PipelineHook] = None,
              pipelined: bool = True) -> RebalanceReport:
    """One-call rebalance pass: plan + windowed execution."""
    return Rebalancer(store, window=window, hook=hook,
                      pipelined=pipelined).run(max_moves=max_moves)
