"""Erasure-coded checkpointing: async sharded save, parallel degraded restore.

Training state (params + optimizer moments + step) is flattened to a byte
stream and striped through the CP-LRC StripeStore. Losing up to ``r``
arbitrary hosts — or more when failures spread across local repair groups —
costs only a local-group repair instead of a cold re-read of the full
checkpoint: the paper's repair-bandwidth win applied to elastic training
restart.

**Save** is asynchronous and pipelined (DESIGN.md §13). ``save_async``
snapshots the train state on the caller's thread — one device→host copy
into a frozen byte buffer, so the next ``train_step`` can mutate or donate
its buffers immediately — and hands the buffer to a background
:class:`repro.ftx.pipeline.EncodePipeline`: the repair pipeline's
reader/writer thread machinery run in reverse, packing stripe windows off
the snapshot while the previous window encodes through
``BatchedCodecEngine.encode`` (MeshRules-sharded, any backend) and the one
before that drains to disk through the store's streaming put path. The
whole store is built under ``step<N>.tmp`` and atomically renamed on seal,
so a crash mid-save can never corrupt — or even make visible — a partial
checkpoint; orphaned ``.tmp``/meta-less directories are swept on manager
init.

**Restore** gathers all k data shards in parallel through per-host reader
pools (``read_range``), and after host failures reconstructs the lost
blocks via the serving planner (local group first, cascade next, global
last) *concurrently* with the live-shard reads — decode launches consume
live data sources straight from the already-gathered restore buffer and
touch disk only for the plan's extra (parity) sources, so a degraded
restore reads barely more than a healthy one and strictly fewer blocks
than a replication system's full re-read plus re-replication.

The manager keeps an in-memory pytree template so restore() rebuilds the
exact params/opt_state structure (dtypes + shapes) from bytes.
"""
from __future__ import annotations

import dataclasses
import json
import re
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor, wait
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from .pipeline import EncodePipeline, PipelineHook
from .stripestore import StoreConfig, StripeStore, launch_step

PyTree = Any

_STEP_DIR = re.compile(r"^step(\d+)$")

# The head key of the checkpoint byte stream inside each step's store
# (continuations follow the standard #cont chain, one per stripe).
_STATE_KEY = "state"


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    store: StoreConfig = StoreConfig(k=8, r=2, p=2, block_size=1 << 18)
    keep: int = 3
    encode_window: Optional[int] = None   # stripes per encode window (None =
    #                                       the store's pipeline_window)
    restore_threads: int = 2              # reader-pool width per host on the
    #                                       parallel restore path
    decode_threads: int = 2               # concurrent degraded-decode tasks
    #                                       during restore


def _flatten_bytes(tree: PyTree) -> tuple[np.ndarray, list]:
    """Flatten a pytree to one contiguous host byte buffer + leaf metadata.

    Always copies (``tobytes`` + ``concatenate``): the result is the
    checkpoint *snapshot*, guaranteed to not alias any device buffer or
    live numpy array the training loop may mutate after this returns.
    """
    leaves = jax.tree.leaves(tree)
    bufs, meta = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        meta.append({"dtype": str(arr.dtype), "shape": list(arr.shape),
                     "nbytes": len(raw)})
        bufs.append(np.frombuffer(raw, np.uint8))
    flat = np.concatenate(bufs) if bufs else np.zeros(0, np.uint8)
    return flat, meta


def _unflatten_bytes(template: PyTree, flat: np.ndarray, meta: list) -> PyTree:
    leaves = []
    pos = 0
    for m in meta:
        n = m["nbytes"]
        chunk = flat[pos:pos + n].tobytes()
        arr = np.frombuffer(chunk, dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        leaves.append(arr)
        pos += n
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves)


class CheckpointFuture:
    """Handle to an in-flight asynchronous save.

    The snapshot has already been taken when ``save_async`` returns this;
    ``result()`` joins the background encode and returns the save info
    dict (or raises the encode's error). ``snapshot_seconds`` is the only
    time the training loop was stalled.
    """

    def __init__(self, step: int, future: Future, snapshot_seconds: float):
        self.step = step
        self.snapshot_seconds = snapshot_seconds
        self._future = future

    def done(self) -> bool:
        return self._future.done()

    def exception(self, timeout: Optional[float] = None):
        return self._future.exception(timeout)

    def result(self, timeout: Optional[float] = None) -> dict:
        return self._future.result(timeout)


class CheckpointManager:
    def __init__(self, root: str | Path, cfg: Optional[CheckpointConfig] = None):
        self.root = Path(root)
        self.cfg = cfg or CheckpointConfig()
        self.root.mkdir(parents=True, exist_ok=True)
        self._stores: dict[int, StripeStore] = {}
        self._meta: dict[int, dict] = {}
        # One background worker serializes saves: retention and the
        # atomic renames never race each other.
        self._encoder = ThreadPoolExecutor(1, thread_name_prefix="ckpt-encode")
        self._lock = threading.Lock()
        self._clean_orphans()

    def _clean_orphans(self) -> None:
        """Sweep the debris of crashed saves: ``step<N>.tmp`` staging dirs
        and ``step<N>`` dirs missing their ``ckpt_meta.json`` (a crash
        inside the pre-atomic-rename era). ``available()`` already refused
        to list them; now they are reclaimed instead of leaking forever."""
        for p in self.root.glob("step*"):
            if not p.is_dir():
                continue
            complete = (_STEP_DIR.match(p.name)
                        and (p / "ckpt_meta.json").exists())
            if not complete:
                shutil.rmtree(p, ignore_errors=True)

    # -------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, *, mesh_rules=None) -> dict:
        """Encode + persist one checkpoint synchronously; returns telemetry.

        Exactly ``save_async(...).result()`` — the bytes on disk are
        identical, the caller just waits out the encode."""
        return self.save_async(step, state, mesh_rules=mesh_rules).result()

    def save_async(self, step: int, state: PyTree, *, mesh_rules=None,
                   pipelined: bool = True, drain_stall: float = 0.0,
                   hook: Optional[PipelineHook] = None) -> CheckpointFuture:
        """Snapshot ``state`` and encode it to disk in the background.

        The snapshot (flatten + host copy) happens here, on the caller's
        thread — when this returns, the training loop may freely mutate or
        donate every buffer in ``state``. Everything else (windowed encode,
        drain, manifest, atomic rename, retention) runs on the manager's
        background thread; the returned :class:`CheckpointFuture` joins it.

        ``mesh_rules`` shards the encode launches (default: the ambient
        ``with_rules`` context *of the caller* — captured now, since the
        background thread has no ambient context). ``pipelined=False``
        runs the encode stages serially (the benchmark baseline);
        ``drain_stall``/``hook`` are forwarded to the
        :class:`EncodePipeline`.
        """
        from repro.dist.sharding import current_rules

        if mesh_rules is None:
            mesh_rules = current_rules()
        t0 = time.perf_counter()
        flat, leaves = _flatten_bytes(state)
        snapshot_seconds = time.perf_counter() - t0
        fut = self._encoder.submit(self._encode_and_seal, step, flat, leaves,
                                   mesh_rules, snapshot_seconds, pipelined,
                                   drain_stall, hook)
        return CheckpointFuture(step, fut, snapshot_seconds)

    def _encode_and_seal(self, step: int, flat: np.ndarray, leaves: list,
                         mesh_rules, snapshot_seconds: float,
                         pipelined: bool, drain_stall: float,
                         hook: Optional[PipelineHook]) -> dict:
        """Background half of a save: stream-encode into ``step<N>.tmp``,
        then atomically rename. Any failure tears the staging dir down and
        re-raises — the previous checkpoint is never touched."""
        tmp = self.root / f"step{step}.tmp"
        final = self.root / f"step{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        t0 = time.perf_counter()
        try:
            store = StripeStore(tmp, self.cfg.store)
            stream = store.stream_writer(_STATE_KEY, len(flat))
            pipe = EncodePipeline(store, window=self.cfg.encode_window,
                                  mesh_rules=mesh_rules, hook=hook,
                                  pipelined=pipelined,
                                  drain_stall=drain_stall)
            res = pipe.run(stream, flat)
            stream.close()
            store.save_manifest()
            info = {"step": step, "bytes": int(len(flat)),
                    "stripes": stream.num_stripes,
                    "snapshot_seconds": snapshot_seconds,
                    "encode_seconds": time.perf_counter() - t0,
                    "encode": {
                        "pipelined": pipelined,
                        "windows": res.windows,
                        "launches": res.launches,
                        "pack_seconds": res.read_seconds,
                        "compute_seconds": res.compute_seconds,
                        "write_seconds": res.write_seconds,
                        "wall_seconds": res.wall_seconds,
                        "overlap_seconds": res.overlap_seconds,
                        "overlap_fraction": (res.overlap_seconds
                                             / res.busy_seconds
                                             if res.busy_seconds else 0.0)},
                    "leaves": leaves}
            (tmp / "ckpt_meta.json").write_text(json.dumps(info))
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        # The atomic commit point: a complete checkpoint appears under its
        # final name in one rename, or not at all.
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        store.root = final
        with self._lock:
            self._stores[step] = store
            self._meta[step] = info
        self._retain()
        return info

    def _retain(self) -> None:
        steps = sorted(self.available())
        for old in steps[:-self.cfg.keep]:
            shutil.rmtree(self.root / f"step{old}", ignore_errors=True)
            with self._lock:
                self._stores.pop(old, None)
                self._meta.pop(old, None)

    def available(self) -> list[int]:
        out = []
        for p in self.root.glob("step*"):
            m = _STEP_DIR.match(p.name)
            if m and (p / "ckpt_meta.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    # ------------------------------------------------------------ restore
    def store_for(self, step: int) -> StripeStore:
        with self._lock:
            if step not in self._stores:
                self._stores[step] = StripeStore.load(self.root / f"step{step}")
            return self._stores[step]

    def restore(self, step: int, template: PyTree, *, parallel: bool = True,
                mesh_rules=None) -> tuple[PyTree, dict]:
        """Rebuild state at ``step``; degraded reads repair automatically.

        ``parallel=True`` (the default) gathers shards through per-host
        reader pools and decodes lost blocks concurrently with the live
        reads; ``parallel=False`` is the serial object-read fallback (the
        benchmark baseline). Both return bit-identical state.
        """
        from repro.dist.sharding import current_rules

        if mesh_rules is None:
            mesh_rules = current_rules()
        t0 = time.perf_counter()
        store = self.store_for(step)
        info = json.loads(
            (self.root / f"step{step}" / "ckpt_meta.json").read_text())
        before = store.telemetry.copy()
        if parallel:
            flat, extra = self._gather_parallel(store, info["bytes"],
                                                mesh_rules)
        else:
            flat, extra = store.get(_STATE_KEY)[:info["bytes"]], {}
        state = _unflatten_bytes(template, flat, info["leaves"])
        t = store.telemetry
        tele = {"restore_seconds": time.perf_counter() - t0,
                "blocks_read": t.blocks_read - before.blocks_read,
                "bytes_read": t.bytes_read - before.bytes_read,
                "sim_seconds": t.sim_seconds - before.sim_seconds,
                "parallel": parallel, **extra}
        return state, tele

    def _gather_parallel(self, store: StripeStore, num_bytes: int,
                         mesh_rules) -> tuple[np.ndarray, dict]:
        """The parallel (and degraded-capable) restore read path.

        Live data blocks fan out over one reader pool per host — every
        host's disks stream their shard of the checkpoint concurrently.
        Stripes with lost data blocks are grouped by failure pattern and
        decoded in batched ``serving_plan`` launches that run *while* the
        live gather is still in flight: each decode waits only on the read
        futures of its own live data sources (served from the restore
        buffer — already paid for) and reads just the plan's extra parity
        sources from disk. The buffer is zero-initialized, so the
        stream-writer's zero padding never needs reading or reconstructing.
        """
        cfg = store.cfg
        k, B = cfg.k, cfg.block_size
        extent = k * B
        # The checkpoint object chain: one stripe per link, in stream order.
        metas = []
        cur = _STATE_KEY
        while cur in store.objects:
            metas.append(store.objects[cur])
            cur += "#cont"
        if not metas:
            raise KeyError(_STATE_KEY)
        flat = np.zeros(len(metas) * extent, np.uint8)

        read_futs: dict[tuple[int, int], Future] = {}
        stats = {"degraded_blocks": 0, "restore_decode_launches": 0,
                 "extra_source_reads": 0}
        slock = threading.Lock()
        patterns: dict[frozenset[int], list[tuple[int, int]]] = {}

        def read_live(sid: int, b: int, dst: int, hi: int) -> None:
            flat[dst:dst + hi] = store.read_range(sid, b, 0, hi)

        def decode_group(down: frozenset[int], group: list[tuple[int, int]]
                         ) -> None:
            lost = [b for b in sorted(down) if b < k]
            covered: set[int] = set()
            for b in lost:
                if b in covered:
                    continue
                plan = store.engine.planner.serving_plan(b, down)
                covered.update(t for t in plan.targets if t < k)
                step = launch_step(cfg, len(plan.reads),
                                   cfg.pipeline_window or None)
                for lo in range(0, len(group), step):
                    chunk = group[lo:lo + step]
                    stacked = np.empty((len(chunk), len(plan.reads), B),
                                       np.uint8)
                    for i, (sid, off) in enumerate(chunk):
                        for j, r in enumerate(plan.reads):
                            if r < k and r not in down:
                                f = read_futs.get((sid, r))
                                if f is not None:
                                    f.result()
                                stacked[i, j] = flat[off + r * B:
                                                     off + (r + 1) * B]
                            else:
                                stacked[i, j] = store._read_block(sid, r)
                                with slock:
                                    stats["extra_source_reads"] += 1
                    out = np.asarray(store.engine.execute(plan, stacked,
                                                          mesh_rules))
                    with slock:
                        stats["restore_decode_launches"] += 1
                    for t, tb in enumerate(plan.targets):
                        if tb >= k:
                            continue
                        for i, (sid, off) in enumerate(chunk):
                            flat[off + tb * B:off + (tb + 1) * B] = out[i, t]

        with ThreadPoolExecutor(self.cfg.decode_threads,
                                thread_name_prefix="restore-decode") as dpool:
            pools: dict[int, ThreadPoolExecutor] = {}
            try:
                for i, meta in enumerate(metas):
                    sid, off = meta.sid, i * extent
                    down = store._down_blocks(sid)
                    stripe = store.stripes[sid]
                    for b in range(k):
                        hi = min(meta.size - b * B, B)
                        if hi <= 0:
                            break            # zero padding: nothing to read
                        if b in down:
                            stats["degraded_blocks"] += 1
                            continue
                        node = stripe.node_of_block[b]
                        pool = pools.get(node)
                        if pool is None:
                            pool = pools[node] = ThreadPoolExecutor(
                                self.cfg.restore_threads,
                                thread_name_prefix=f"restore-h{node}")
                        read_futs[(sid, b)] = pool.submit(read_live, sid, b,
                                                          off + b * B, hi)
                    # Only patterns that lose a *needed* data block decode;
                    # blocks entirely inside the zero padding reconstruct
                    # to zeros the buffer already holds.
                    needed = min(k, -(-meta.size // B))
                    if down & set(range(needed)):
                        patterns.setdefault(down, []).append((sid, off))
                decode_futs = [dpool.submit(decode_group, down, group)
                               for down, group in patterns.items()]
                wait(list(read_futs.values()))
                wait(decode_futs)
                for f in [*read_futs.values(), *decode_futs]:
                    f.result()               # surface read/decode errors
            finally:
                for pool in pools.values():
                    pool.shutdown(wait=True)
        return flat[:num_bytes], stats

    def fail_hosts(self, step: int, hosts: list[int]) -> None:
        store = self.store_for(step)
        for h in hosts:
            store.fail_node(h)

    def repair(self, step: int) -> dict:
        return self.store_for(step).repair_all()
