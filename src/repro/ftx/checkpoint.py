"""Erasure-coded checkpointing.

Training state (params + optimizer moments + step) is flattened to a byte
stream, split into per-host shards (one per data-parallel host in the
production fleet), and striped through the CP-LRC StripeStore. Losing up to
``r`` arbitrary hosts — or more when failures spread across local repair
groups — costs only a local-group repair instead of a cold re-read of the
full checkpoint: the paper's repair-bandwidth win applied to elastic
training restart.

The manager also keeps an in-memory pytree template so restore() rebuilds
the exact params/opt_state structure (dtypes + shapes) from bytes.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from .stripestore import StoreConfig, StripeStore

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    store: StoreConfig = StoreConfig(k=8, r=2, p=2, block_size=1 << 18)
    keep: int = 3


def _flatten_bytes(tree: PyTree) -> tuple[np.ndarray, list]:
    leaves = jax.tree.leaves(tree)
    bufs, meta = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        meta.append({"dtype": str(arr.dtype), "shape": list(arr.shape),
                     "nbytes": len(raw)})
        bufs.append(np.frombuffer(raw, np.uint8))
    flat = np.concatenate(bufs) if bufs else np.zeros(0, np.uint8)
    return flat, meta


def _unflatten_bytes(template: PyTree, flat: np.ndarray, meta: list) -> PyTree:
    leaves = []
    pos = 0
    for m in meta:
        n = m["nbytes"]
        chunk = flat[pos:pos + n].tobytes()
        arr = np.frombuffer(chunk, dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        leaves.append(arr)
        pos += n
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, root: str | Path, cfg: Optional[CheckpointConfig] = None):
        self.root = Path(root)
        self.cfg = cfg or CheckpointConfig()
        self.root.mkdir(parents=True, exist_ok=True)
        self._stores: dict[int, StripeStore] = {}
        self._meta: dict[int, dict] = {}

    # -------------------------------------------------------------- save
    def save(self, step: int, state: PyTree) -> dict:
        """Encode + persist one checkpoint; returns telemetry."""
        t0 = time.perf_counter()
        flat, meta = _flatten_bytes(state)
        store = StripeStore(self.root / f"step{step}", self.cfg.store)
        shard_bytes = int(np.ceil(len(flat) / self.cfg.store.k)) or 1
        for h in range(self.cfg.store.k):
            shard = flat[h * shard_bytes:(h + 1) * shard_bytes]
            store.put(f"shard{h}", shard.tobytes())
        store.seal()
        store.save_manifest()
        info = {"step": step, "bytes": int(len(flat)),
                "shard_bytes": shard_bytes, "leaves": meta,
                "encode_seconds": time.perf_counter() - t0}
        (self.root / f"step{step}" / "ckpt_meta.json").write_text(
            json.dumps({k: v for k, v in info.items() if k != "leaves"}
                       | {"leaves": meta}))
        self._stores[step] = store
        self._meta[step] = info
        self._retain()
        return info

    def _retain(self) -> None:
        steps = sorted(self.available())
        for old in steps[:-self.cfg.keep]:
            import shutil

            shutil.rmtree(self.root / f"step{old}", ignore_errors=True)
            self._stores.pop(old, None)
            self._meta.pop(old, None)

    def available(self) -> list[int]:
        return sorted(int(p.name[4:]) for p in self.root.glob("step*")
                      if (p / "ckpt_meta.json").exists())

    # ------------------------------------------------------------ restore
    def store_for(self, step: int) -> StripeStore:
        if step not in self._stores:
            self._stores[step] = StripeStore.load(self.root / f"step{step}")
        return self._stores[step]

    def restore(self, step: int, template: PyTree) -> tuple[PyTree, dict]:
        """Rebuild state at ``step``; degraded reads repair automatically."""
        t0 = time.perf_counter()
        store = self.store_for(step)
        info = json.loads(
            (self.root / f"step{step}" / "ckpt_meta.json").read_text())
        before = store.telemetry.copy()
        shards = [store.get(f"shard{h}") for h in range(self.cfg.store.k)]
        flat = np.concatenate(shards)[:info["bytes"]]
        state = _unflatten_bytes(template, flat, info["leaves"])
        t = store.telemetry
        tele = {"restore_seconds": time.perf_counter() - t0,
                "blocks_read": t.blocks_read - before.blocks_read,
                "bytes_read": t.bytes_read - before.bytes_read,
                "sim_seconds": t.sim_seconds - before.sim_seconds}
        return state, tele

    def fail_hosts(self, step: int, hosts: list[int]) -> None:
        store = self.store_for(step)
        for h in hosts:
            store.fail_node(h)

    def repair(self, step: int) -> dict:
        return self.store_for(step).repair_all()
