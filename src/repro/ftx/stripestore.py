"""Erasure-coded stripe store over virtual nodes.

Mirrors the paper's prototype (§V): a coordinator (this class) holds stripe/
block/object/node indexes; "data nodes" are directories (one per virtual
node) holding block files. Encode/decode/repair byte-crunching runs through
the JAX/Pallas codec; repair *planning* uses the paper's local-first
algorithms, and every operation is bandwidth-accounted (blocks and bytes
read) so the cloud experiments (Figs 6-9) can be reproduced as simulations
with a configurable link-speed model.

Also implements the paper's file-level optimization (§V-C): objects packed
into stripes with byte-offsets, degraded reads fetch only the needed byte
ranges of surviving blocks; plus straggler-hedged reads (read k+h candidate
sources, use the first k by simulated node latency).
"""
from __future__ import annotations

import dataclasses
import enum
import json
import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Optional

import numpy as np

from repro.core.codec import StripeCodec
from repro.core.engine import BatchedCodecEngine
from repro.core.repair import (MultiRepairPlan, multi_repair_plan,
                               single_repair_plan)
from repro.core.schemes import make_scheme
from repro.kernels.ops import default_backend as _default_backend
from repro.serve.telemetry import LatencyRecorder

from .options import RepairOptions, ServeOptions

# Shared all-defaults ServeOptions: every read without explicit options
# resolves its knobs through this one frozen instance.
_DEFAULT_SERVE = ServeOptions()


class NodeState(enum.Enum):
    UP = "up"
    DOWN = "down"


# Cap on the gathered (S, |reads|, B) host stack per batched repair launch;
# chunking shrinks S below cfg.batch_stripes when reads x block_size is wide.
_BATCH_BYTE_BUDGET = 256 << 20


def launch_step(cfg: "StoreConfig", num_reads: int,
                window: Optional[int] = None,
                byte_budget: int = _BATCH_BYTE_BUDGET) -> int:
    """Stripes per batched launch: the requested ``window`` (default
    ``cfg.batch_stripes``) capped by ``batch_stripes`` and the gathered-
    stack byte budget. Shared by the synchronous chunk loop and the async
    pipeline so both paths always chunk identically."""
    per_stripe = num_reads * cfg.block_size
    return max(1, min(window or cfg.batch_stripes, cfg.batch_stripes,
                      byte_budget // max(1, per_stripe)))


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    scheme: str = "cp-azure"
    k: int = 24
    r: int = 2
    p: int = 2
    block_size: int = 1 << 20          # bytes per block
    # Kernel backend: REPRO_BACKEND when set, else the serving-tuned jnp
    # table path ("gf"/"crs"/"mxu" = Pallas; see kernels.ops.BACKENDS).
    backend: str = dataclasses.field(
        default_factory=lambda: _default_backend("ref"))
    bandwidth_gbps: float = 1.0        # per-link model for simulated time
    hedge: int = 0                     # extra sources for hedged reads
    seed: int = 0
    batch_stripes: int = 64            # max stripes per batched repair launch
    pipeline_window: int = 32          # stripes per async-repair window (0 = sync)
    prefetch_threads: int = 8          # reader pool width, per gather shard
    io_stall_scale: float = 0.0        # fraction of each read's *simulated*
    #                                    time actually slept (wall-clock),
    #                                    making the per-node latency model
    #                                    real for overlap experiments
    remote_read_multiplier: float = 1.0  # simulated link-time cost of a read
    #                                    whose source node lives outside the
    #                                    reading shard (PlacementMap); 1.0
    #                                    keeps the locality-blind model
    placement_policy: str = "contiguous"  # block-placement policy at stripe
    #                                    open (repro.dist.topology.POLICIES):
    #                                    contiguous arcs (seed behavior),
    #                                    round_robin across domains, or
    #                                    copyset-style spread
    stripe_schedule: str = "global"    # stripe->device-shard assignment for
    #                                    sharded repair launches
    #                                    (repro.dist.schedule): "global"
    #                                    solves one exact min-cost
    #                                    assignment across all windows of a
    #                                    pattern group (never worse than
    #                                    "locality"); "locality" permutes
    #                                    each chunk greedily onto the shards
    #                                    owning most of its surviving blocks
    #                                    (never predicted worse than
    #                                    contiguous); "none" keeps the
    #                                    contiguous default
    rebuild_destinations: str = "in_place"  # where repair_all persists
    #                                    rebuilt blocks: "in_place" writes
    #                                    back to the failed block's original
    #                                    node address (seed behavior);
    #                                    "topology" re-homes each rebuilt
    #                                    block on the least-loaded surviving
    #                                    domain while preserving the
    #                                    placement policy's invariants
    #                                    (repro.dist.topology.
    #                                    pick_destinations)
    read_cache_blocks: int = 64        # hot-block reconstruction cache: max
    #                                    reconstructed blocks kept for the
    #                                    degraded serving path (LRU;
    #                                    0 disables caching entirely)
    coalesce_reads: bool = True        # merge concurrent degraded reads of
    #                                    one lost block into a single decode
    #                                    launch (per-block in-flight future);
    #                                    False = naive per-request decode
    #                                    (the benchmark baseline)
    read_latency_samples: int = 8192   # bounded reservoir behind the read
    #                                    path's p50/p99 latency telemetry


@dataclasses.dataclass
class Stripe:
    sid: int
    node_of_block: list[int]           # block index -> node id


@dataclasses.dataclass
class ObjectMeta:
    key: str
    size: int
    sid: int
    block: int                         # first data block index within stripe
    offset: int                        # byte offset within that block


@dataclasses.dataclass
class Telemetry:
    blocks_read: int = 0
    bytes_read: int = 0
    repairs_local: int = 0
    repairs_global: int = 0
    sim_seconds: float = 0.0
    # Wall-clock stage spans of repair work (read gather / device compute /
    # write-back). Under the pipeline these overlap, so their sum exceeding
    # the repair's wall time is the overlap being won.
    read_seconds: float = 0.0
    compute_seconds: float = 0.0
    write_seconds: float = 0.0
    # Locality accounting (PlacementMap): reads served from the reading
    # shard's own nodes vs. cross-shard fetches, and how many gather bytes
    # each shard pulled from disk during repair gathers.
    local_reads: int = 0
    remote_reads: int = 0
    gather_bytes_per_shard: dict = dataclasses.field(default_factory=dict)
    # Rebuild-destination accounting: blocks whose repair write-back landed
    # on a topology-chosen surviving node instead of the failed block's
    # original address (repro.dist.topology.pick_destinations).
    blocks_relocated: int = 0
    # Degraded-read serving path (read/read_range): requests served straight
    # from live blocks vs. reconstructed inline; how many of the degraded
    # ones piggybacked on another request's in-flight decode (coalescing) or
    # on the hot-block cache; how many decode launches actually reached the
    # engine and whether their plans were local (group/cascade) or global.
    direct_reads: int = 0
    degraded_reads: int = 0
    coalesced_reads: int = 0
    serve_decode_launches: int = 0
    serve_local_decodes: int = 0
    serve_global_decodes: int = 0
    serve_replans: int = 0            # decodes re-planned after a source died
    cache_hits: int = 0
    cache_misses: int = 0
    cache_invalidations: int = 0      # entries dropped by repair/write-back
    served_bytes: int = 0             # payload bytes returned to read clients

    def copy(self) -> "Telemetry":
        snap = dataclasses.replace(self)
        snap.gather_bytes_per_shard = dict(self.gather_bytes_per_shard)
        return snap

    def reset(self) -> "Telemetry":
        snap = self.copy()
        self.blocks_read = self.bytes_read = 0
        self.repairs_local = self.repairs_global = 0
        self.sim_seconds = 0.0
        self.read_seconds = self.compute_seconds = self.write_seconds = 0.0
        self.local_reads = self.remote_reads = 0
        self.gather_bytes_per_shard = {}
        self.blocks_relocated = 0
        self.direct_reads = self.degraded_reads = self.coalesced_reads = 0
        self.serve_decode_launches = 0
        self.serve_local_decodes = self.serve_global_decodes = 0
        self.serve_replans = 0
        self.cache_hits = self.cache_misses = self.cache_invalidations = 0
        self.served_bytes = 0
        return snap


class _InflightDecode:
    """One lost block's in-flight reconstruction: the request coalescing
    unit. The first degraded reader of a (stripe, block) becomes the leader
    and decodes; every concurrent reader of the same block parks on the
    event and is served from ``result`` — N requests, one decode launch."""
    __slots__ = ("event", "result", "error", "waiters")

    def __init__(self):
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self.waiters = 0


class StripeStore:
    def __init__(self, root: str | Path, cfg: StoreConfig,
                 num_nodes: Optional[int] = None, placement=None,
                 topology=None):
        from repro.dist.topology import (POLICIES, Topology,
                                         placement_from_topology)

        self.cfg = cfg
        if cfg.placement_policy not in POLICIES:
            raise ValueError(f"unknown placement_policy "
                             f"{cfg.placement_policy!r} "
                             f"(choose from {', '.join(POLICIES)})")
        if cfg.stripe_schedule not in ("none", "locality", "global"):
            raise ValueError(f"unknown stripe_schedule "
                             f"{cfg.stripe_schedule!r} "
                             f"(choose from none, locality, global)")
        if cfg.rebuild_destinations not in ("in_place", "topology"):
            raise ValueError(f"unknown rebuild_destinations "
                             f"{cfg.rebuild_destinations!r} "
                             f"(choose from in_place, topology)")
        self.scheme = make_scheme(cfg.scheme, cfg.k, cfg.r, cfg.p)
        self.codec = StripeCodec(self.scheme, backend=cfg.backend)
        # Batched executor sharing the codec's plan cache: fleet repair
        # issues one launch per (failure pattern, <=batch_stripes chunk).
        self.engine = BatchedCodecEngine(self.scheme, backend=cfg.backend,
                                         planner=self.codec.planner)
        self.root = Path(root)
        self.n = self.scheme.n
        self.num_nodes = num_nodes or self.n
        if self.num_nodes < self.n:
            raise ValueError("need at least n nodes for one stripe")
        # Fleet topology (repro.dist.topology): failure domains plus the
        # block-placement policy _open() consults. The single-domain
        # default with the "contiguous" policy reproduces the seed store's
        # stride-7 arcs exactly.
        self.topology = topology if topology is not None \
            else Topology(num_nodes=self.num_nodes)
        # Whether a topology was supplied (vs the inert single-domain
        # default): decides placement derivation and manifest persistence,
        # so a reloaded store keeps placing stripes under the original
        # domains instead of silently reverting to the default.
        self._topology_explicit = topology is not None
        if self.topology.num_nodes != self.num_nodes:
            raise ValueError(f"topology has {self.topology.num_nodes} "
                             f"nodes, store has {self.num_nodes}")
        # Default PlacementMap for repairs (repro.dist.placement): an
        # explicit map wins; a topology derives one (domains = gather
        # shards); None derives one per repair from the node->shard
        # default and the active mesh's stripe-axis span.
        if placement is None and topology is not None:
            placement = placement_from_topology(self, self.topology)
        self.placement = placement
        self.nodes = {i: NodeState.UP for i in range(self.num_nodes)}
        self.latency_ms = {
            i: float(l) for i, l in enumerate(
                np.random.default_rng(cfg.seed).gamma(2.0, 5.0, self.num_nodes))}
        # Pipeline prefetch threads and the write-back thread mutate
        # telemetry concurrently with the coordinator; counters stay exact
        # under this lock.
        self._tele_lock = threading.Lock()
        self.stripes: dict[int, Stripe] = {}
        self.objects: dict[str, ObjectMeta] = {}
        self.telemetry = Telemetry()
        # Degraded-read serving state (read/read_range): the per-block
        # in-flight futures behind request coalescing, the bounded LRU
        # hot-block reconstruction cache, and the latency reservoir for
        # p50/p99 read telemetry. One lock serializes cache/in-flight
        # bookkeeping; decodes themselves run outside it.
        self._serve_lock = threading.Lock()
        self._inflight: dict[tuple[int, int], _InflightDecode] = {}
        self._hot_cache: OrderedDict[tuple[int, int], np.ndarray] = \
            OrderedDict()
        self.read_latency = LatencyRecorder(cfg.read_latency_samples)
        # Diagnostic callback ``(stage, sid, block)`` with stages "plan",
        # "gather", "decode" — the serving-path analogue of pipeline_hook,
        # used by the coalescing and mid-read failure-injection tests.
        self.read_hook = None
        self._next_sid = 0
        self._open_sid: Optional[int] = None
        self._open_fill = 0
        for i in range(self.num_nodes):
            (self.root / f"node{i}").mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------- helpers
    def _block_path(self, sid: int, block: int) -> Path:
        node = self.stripes[sid].node_of_block[block]
        return self.root / f"node{node}" / f"s{sid}_b{block}.blk"

    def _read_block(self, sid: int, block: int,
                    rng: Optional[tuple[int, int]] = None, *,
                    shard: Optional[int] = None,
                    placement=None) -> np.ndarray:
        """Read one block (or byte range), charging the simulated link model.

        ``shard``/``placement`` attribute the read to a gather shard: a read
        whose source node lives outside ``shard`` is *remote* and pays the
        placement's ``remote_multiplier`` on its link time. Reads with no
        shard (client/degraded paths) are charged as local.
        """
        node = self.stripes[sid].node_of_block[block]
        if self.nodes[node] is NodeState.DOWN:
            raise IOError(f"node {node} is down")
        data = np.fromfile(self._block_path(sid, block), dtype=np.uint8)
        lo, hi = rng if rng else (0, len(data))
        local = placement is None or placement.is_local(node, shard)
        dt = ((hi - lo) * 8 / (self.cfg.bandwidth_gbps * 1e9)
              + self.latency_ms[node] / 1e3)
        if not local:
            dt *= placement.remote_multiplier
        if self.cfg.io_stall_scale > 0.0:
            # Make the simulated link model wall-real (scaled): serial
            # readers pay it in full, the pipeline's prefetch pool overlaps
            # it with compute — exactly the effect under measurement.
            time.sleep(self.cfg.io_stall_scale * dt)
        with self._tele_lock:
            self.telemetry.blocks_read += 1
            self.telemetry.bytes_read += hi - lo
            self.telemetry.sim_seconds += dt
            if local:
                self.telemetry.local_reads += 1
            else:
                self.telemetry.remote_reads += 1
            if shard is not None:
                gbs = self.telemetry.gather_bytes_per_shard
                gbs[shard] = gbs.get(shard, 0) + (hi - lo)
        return data[lo:hi]

    def _write_block(self, sid: int, block: int, data: np.ndarray) -> None:
        path = self._block_path(sid, block)
        np.asarray(data, np.uint8).tofile(path)
        # Cache-invalidation-on-write-back: the disk copy is now the truth,
        # so a cached reconstruction of this block must never be served
        # again (it is byte-identical today, but a future overwrite path
        # must not inherit a stale entry — DESIGN.md §10).
        self._cache_invalidate(sid, block)

    # ------------------------------------------------- hot-block cache
    def _cache_invalidate(self, sid: int, block: int) -> None:
        with self._serve_lock:
            dropped = self._hot_cache.pop((sid, block), None)
        if dropped is not None:
            with self._tele_lock:
                self.telemetry.cache_invalidations += 1

    def _cache_put(self, sid: int, block: int, data: np.ndarray) -> None:
        cap = self.cfg.read_cache_blocks
        if cap <= 0:
            return
        with self._serve_lock:
            self._hot_cache[(sid, block)] = data
            self._hot_cache.move_to_end((sid, block))
            while len(self._hot_cache) > cap:
                self._hot_cache.popitem(last=False)

    def _cache_get(self, sid: int, block: int) -> Optional[np.ndarray]:
        with self._serve_lock:
            data = self._hot_cache.get((sid, block))
            if data is not None:
                self._hot_cache.move_to_end((sid, block))
        return data

    # ------------------------------------------------------------- writes
    def put(self, key: str, payload: bytes | np.ndarray) -> ObjectMeta:
        """Pack an object into the open stripe (padding + sealing as needed).

        Objects larger than one block span blocks; larger than a stripe's
        data extent span stripes (key#1, key#2 continuation objects).
        """
        payload = np.frombuffer(payload, np.uint8) if isinstance(payload, bytes) \
            else np.asarray(payload, np.uint8).reshape(-1)
        extent = self.cfg.k * self.cfg.block_size
        if self._open_sid is None:
            self._open()
        # Iterative chunking: fill the open stripe, seal, continue into fresh
        # stripes with #cont objects (get() follows the chain).
        first_meta = None
        cur_key = key
        pos = 0
        while True:
            if self._open_sid is None:
                self._open()
            room = extent - self._open_fill
            if room == 0:
                self.seal()
                continue
            take = min(room, len(payload) - pos)
            meta = self._append(cur_key, payload[pos:pos + take])
            if first_meta is None:
                first_meta = meta
            pos += take
            if pos >= len(payload):
                return first_meta
            cur_key = cur_key + "#cont"

    def _alloc_stripe(self) -> int:
        from repro.dist.topology import place_stripe

        sid = self._next_sid
        self._next_sid += 1
        # Block placement is policy-driven (repro.dist.topology): the
        # default "contiguous" policy is the seed behavior — a stride-7
        # rotated arc, so parities spread across nodes.
        placement = place_stripe(self.cfg.placement_policy, self.topology,
                                 sid, self.n)
        self.stripes[sid] = Stripe(sid=sid, node_of_block=placement)
        return sid

    def _open(self) -> None:
        self._open_sid = self._alloc_stripe()
        self._open_fill = 0
        self._open_buf = np.zeros(self.cfg.k * self.cfg.block_size, np.uint8)

    def _append(self, key: str, payload: np.ndarray) -> ObjectMeta:
        sid = self._open_sid
        start = self._open_fill
        self._open_buf[start:start + len(payload)] = payload
        self._open_fill = start + len(payload)
        meta = ObjectMeta(key=key, size=len(payload), sid=sid,
                          block=start // self.cfg.block_size,
                          offset=start % self.cfg.block_size)
        self.objects[key] = meta
        return meta

    def seal(self) -> None:
        """Encode the open stripe and persist all n blocks."""
        if self._open_sid is None:
            return
        sid = self._open_sid
        data = self._open_buf.reshape(self.cfg.k, self.cfg.block_size)
        stripe = np.asarray(self.codec.encode(data))
        for b in range(self.n):
            self._write_block(sid, b, stripe[b])
        self._open_sid = None
        self._open_fill = 0

    def stream_writer(self, key: str, total_bytes: int) -> "StripeStreamWriter":
        """Open the streaming put path: pre-allocate every stripe for a
        ``total_bytes``-sized object so fully *encoded* windows can be
        persisted — in any order, from a writer thread — while upstream
        windows are still encoding (the checkpoint pipeline's drain stage).
        ``close()`` registers exactly the object chain ``put`` + ``seal``
        would have produced (head key plus ``#cont`` continuations, one per
        stripe, zero-padded tail), so ``get``/``read_range`` serve streamed
        bytes identically to packed ones."""
        if self._open_sid is not None:
            raise RuntimeError("seal() the open stripe before stream_writer")
        return StripeStreamWriter(self, key, int(total_bytes))

    # ------------------------------------------------------------- reads
    def get(self, key: str) -> np.ndarray:
        """Read an object; degraded reads repair through the planner and,
        per §V-C, touch only the byte ranges the object needs. Follows
        #cont continuation chains iteratively (objects can span stripes)."""
        parts = []
        cur = key
        while cur in self.objects:
            meta = self.objects[cur]
            out = np.zeros(meta.size, np.uint8)
            pos = 0
            block = meta.block
            offset = meta.offset
            while pos < meta.size:
                take = min(self.cfg.block_size - offset, meta.size - pos)
                out[pos:pos + take] = self._get_range(meta.sid, block,
                                                      offset, offset + take)
                pos += take
                block += 1
                offset = 0
            parts.append(out)
            cur = cur + "#cont"
        if not parts:
            raise KeyError(key)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def _down_blocks(self, sid: int) -> frozenset[int]:
        st = self.stripes[sid]
        return frozenset(b for b, node in enumerate(st.node_of_block)
                         if self.nodes[node] is NodeState.DOWN)

    def _get_range(self, sid: int, block: int, lo: int, hi: int) -> np.ndarray:
        down = self._down_blocks(sid)
        if block not in down:
            return self._read_block(sid, block, (lo, hi))
        # A hot reconstruction from the serving path covers this range for
        # free (no disk reads at all beats §V-C's minimal byte ranges).
        cached = self._cache_get(sid, block)
        if cached is not None:
            with self._tele_lock:
                self.telemetry.cache_hits += 1
            return cached[lo:hi].copy()
        # degraded read: plan repair for just this block, fetch only [lo, hi)
        plan = self._pick_single_plan(sid, block, down)
        if plan is None:                      # plan sources dead -> multi plan
            mplan = multi_repair_plan(self.scheme, down)
            if not mplan.feasible:
                raise IOError(f"stripe {sid}: unrecoverable ({sorted(down)})")
            rebuilt, _ = self._execute_multi(sid, mplan, down, (lo, hi))
            return rebuilt[block]
        reads = sorted(plan.reads)
        coeffs = self.codec.reconstruction_coeffs(block, reads)
        chunks = [self._read_block(sid, b, (lo, hi)) for b in reads]
        import jax.numpy as jnp
        piece = self.codec.combine(coeffs, [jnp.asarray(c) for c in chunks])
        return np.asarray(piece)

    def _pick_single_plan(self, sid: int, block: int, down: frozenset[int]):
        """Pick a single-block repair plan whose sources are all alive.

        With hedging on (straggler mitigation), all structural candidates
        compete on *simulated completion time* — the critical-path node
        latency plus the transfer — instead of block count alone; the paper's
        cascaded group gives CP-LRCs more alternatives to hedge across.
        """
        from repro.core.repair import single_repair_candidates

        cands = [c for c in single_repair_candidates(self.scheme, block)
                 if not (c.reads & down)]
        if not cands:
            return None
        if not self.cfg.hedge:
            paper = single_repair_plan(self.scheme, block)
            if not (paper.reads & down):
                return paper
            return min(cands, key=lambda c: c.cost)
        node_of = self.stripes[sid].node_of_block

        def sim_time(c):
            lat = max(self.latency_ms[node_of[b]] for b in c.reads)
            return lat / 1e3 + c.cost * self.cfg.block_size * 8 / (
                self.cfg.bandwidth_gbps * 1e9)

        pool = sorted(cands, key=sim_time)[:1 + self.cfg.hedge]
        return pool[0]

    # ------------------------------------------------------------- serving
    def read(self, sid: int, block: int, *,
             options: Optional["ServeOptions"] = None) -> np.ndarray:
        """Serve one block of one stripe, reconstructing inline if lost.

        The degraded-read serving path (DESIGN.md §10): live blocks are
        read straight from their node; a block on a DOWN node is rebuilt
        through the planner's cheapest feasible plan (local group first,
        cascaded group as fallback, global decode last —
        ``RepairPlanner.serving_plan``) in a single
        :class:`BatchedCodecEngine` launch. Concurrent reads of one lost
        block coalesce onto a single in-flight decode
        (``cfg.coalesce_reads``), reconstructions are kept in a bounded
        hot-block LRU (``cfg.read_cache_blocks``, invalidated whenever the
        block is written back), and every request's wall latency lands in
        ``read_latency`` (p50/p99 telemetry).

        ``options`` (:class:`repro.ftx.options.ServeOptions`) carries
        per-request overrides of the serving knobs — coalescing and
        hot-cache participation; ``None`` keeps the store defaults.

        Raises ``KeyError``/``IndexError`` for unknown stripes/blocks and
        ``IOError`` when the stripe's failure pattern is unrecoverable.
        """
        return self.read_range(sid, block, 0, self.cfg.block_size,
                               options=options)

    def read_range(self, sid: int, block: int, lo: int = 0,
                   hi: Optional[int] = None, *,
                   options: Optional["ServeOptions"] = None) -> np.ndarray:
        """``read`` restricted to the byte range ``[lo, hi)`` of the block.

        Live blocks read only the range from disk (the §V-C byte-range
        optimization); lost blocks are reconstructed whole — the unit of
        coalescing and caching — and sliced, so N range reads of one hot
        lost block still cost one decode launch.
        """
        t0 = time.perf_counter()
        if sid not in self.stripes:
            raise KeyError(f"unknown stripe {sid}")
        if not 0 <= block < self.n:
            raise IndexError(f"block {block} out of range for n={self.n}")
        hi = self.cfg.block_size if hi is None else hi
        if not 0 <= lo <= hi <= self.cfg.block_size:
            raise ValueError(f"bad byte range [{lo}, {hi}) for block size "
                             f"{self.cfg.block_size}")
        if block not in self._down_blocks(sid):
            try:
                data = self._read_block(sid, block, (lo, hi))
            except IOError:
                # The node died between the down-set check and the read:
                # take the degraded path with a fresh down-set.
                data = self._read_degraded(sid, block, options)[lo:hi].copy()
                self._account_read(t0, lo, hi, degraded=True)
                return data
            self._account_read(t0, lo, hi, degraded=False)
            return data
        data = self._read_degraded(sid, block, options)[lo:hi].copy()
        self._account_read(t0, lo, hi, degraded=True)
        return data

    def _account_read(self, t0: float, lo: int, hi: int, *,
                      degraded: bool) -> None:
        with self._tele_lock:
            if degraded:
                self.telemetry.degraded_reads += 1
            else:
                self.telemetry.direct_reads += 1
            self.telemetry.served_bytes += hi - lo
        self.read_latency.record(time.perf_counter() - t0, hi - lo)

    def _read_degraded(self, sid: int, block: int,
                       options: Optional["ServeOptions"] = None) -> np.ndarray:
        """Serve a lost block: cache, then coalesce, then lead a decode.

        The cache probe and the in-flight registration happen under one
        lock acquisition, so there is no window in which a block is neither
        cached nor in flight while a decode for it is running: the leader
        inserts the reconstruction into the cache *before* retiring its
        in-flight entry. ``options`` opts this one request out of
        coalescing and/or cache participation.
        """
        o = options if options is not None else _DEFAULT_SERVE
        key = (sid, block)
        coalesce = o.coalesce_for(self.cfg)
        use_cache = o.cache_for(self.cfg)
        leader = False
        entry: Optional[_InflightDecode] = None
        with self._serve_lock:
            cached = self._hot_cache.get(key) if use_cache else None
            if cached is not None:
                self._hot_cache.move_to_end(key)
            elif coalesce:
                entry = self._inflight.get(key)
                if entry is None:
                    entry = _InflightDecode()
                    self._inflight[key] = entry
                    leader = True
                else:
                    entry.waiters += 1
        if cached is not None:
            with self._tele_lock:
                self.telemetry.cache_hits += 1
            return cached
        with self._tele_lock:
            self.telemetry.cache_misses += 1
        if entry is not None and not leader:
            entry.event.wait()
            with self._tele_lock:
                self.telemetry.coalesced_reads += 1
            if entry.error is not None:
                raise entry.error
            return entry.result
        try:
            data = self._decode_block(sid, block, cache_self=use_cache)
            if leader:
                entry.result = data
            return data
        except BaseException as e:
            if leader:
                entry.error = e
            raise
        finally:
            if leader:
                # Retire the future only after the cache holds the result
                # (or the error is recorded): late readers either hit the
                # cache or start a fresh decode — never a stale future.
                with self._serve_lock:
                    self._inflight.pop(key, None)
                entry.event.set()

    def _decode_block(self, sid: int, block: int, *,
                      cache_self: bool = True) -> np.ndarray:
        """One serving-path reconstruction: plan, gather, single launch.

        A source node dying between plan selection and gather surfaces as
        an IOError on the read; the loop re-plans against the fresh
        down-set (``serve_replans`` counts these) until a feasible plan's
        sources all survive the gather, or the pattern goes unrecoverable.
        """
        attempts = 0
        while True:
            down = self._down_blocks(sid)
            if self.read_hook:
                self.read_hook("plan", sid, block)
            try:
                plan = self.engine.planner.serving_plan(block, down)
            except RuntimeError:
                raise IOError(f"stripe {sid}: block {block} unrecoverable "
                              f"({sorted(down)})") from None
            if self.read_hook:
                self.read_hook("gather", sid, block)
            try:
                stacked = np.stack(
                    [self._read_block(sid, b) for b in plan.reads])[None]
            except IOError:
                attempts += 1
                with self._tele_lock:
                    self.telemetry.serve_replans += 1
                if attempts > self.n:
                    raise
                continue
            if self.read_hook:
                self.read_hook("decode", sid, block)
            out = np.asarray(self.engine.execute(plan, stacked))
            meta = plan.meta
            local = (meta.all_local if isinstance(meta, MultiRepairPlan)
                     else meta is not None and meta.method != "global")
            with self._tele_lock:
                self.telemetry.serve_decode_launches += 1
                if local:
                    self.telemetry.serve_local_decodes += 1
                else:
                    self.telemetry.serve_global_decodes += 1
            # The multi-plan fallback rebuilds the stripe's whole failure
            # pattern in the same launch; cache every target so sibling
            # lost blocks serve for free.
            result = None
            for t, b in enumerate(plan.targets):
                rebuilt = out[0, t, :]
                if cache_self or b != block:
                    self._cache_put(sid, b, rebuilt)
                if b == block:
                    result = rebuilt
            assert result is not None, "plan targets must include the block"
            return result

    # ------------------------------------------------------------- repair
    def fail_node(self, node: int) -> None:
        self.nodes[node] = NodeState.DOWN

    def revive_node(self, node: int) -> None:
        self.nodes[node] = NodeState.UP

    def expand(self, topology) -> list[int]:
        """Grow the fleet to ``topology`` (same or more nodes) in place.

        The fleet-expansion half of the rebalancing story (DESIGN.md §14):
        new nodes join UP and empty, existing node ids keep their state,
        placement, and simulated latency (the latency model re-draws from
        the same seed, so the original prefix is bit-identical), and the
        new topology drives all future placement, gather sharding, and
        destination selection. Existing stripes are *not* moved — run the
        rebalancer (``repro.ftx.rebalance``) to smooth load onto the new
        capacity.

        Returns the newly added node ids (empty when only the domain
        geometry changed).
        """
        from repro.dist.topology import placement_from_topology

        if topology.num_nodes < self.num_nodes:
            raise ValueError(f"cannot shrink: store has {self.num_nodes} "
                             f"nodes, topology has {topology.num_nodes}")
        added = list(range(self.num_nodes, topology.num_nodes))
        self.num_nodes = topology.num_nodes
        self.topology = topology
        self._topology_explicit = True
        lat = np.random.default_rng(self.cfg.seed).gamma(
            2.0, 5.0, self.num_nodes)
        for i in added:
            self.nodes[i] = NodeState.UP
            self.latency_ms[i] = float(lat[i])
            (self.root / f"node{i}").mkdir(parents=True, exist_ok=True)
        self.placement = placement_from_topology(self, topology)
        return added

    def repair_all(self, spare_of: Optional[dict[int, int]] = None, *,
                   options: Optional["RepairOptions"] = None) -> dict:
        """Rebuild every block resident on DOWN nodes onto spares (or back in
        place) using the multi-node planner. Returns telemetry for the repair
        (the paper's repair-time experiments).

        Execution knobs arrive in one ``options``
        (:class:`repro.ftx.options.RepairOptions`); the pre-PR-8 loose
        keyword spellings were removed after their one deprecation cycle.

        ``options.batched=True`` (default) groups affected stripes by failure
        pattern and repairs each group through the batched engine — one
        compiled plan and one kernel launch per ``(pattern, chunk)`` of up to
        ``cfg.batch_stripes`` stripes — instead of one solve + one launch per
        stripe. ``batched=False`` keeps the seed per-stripe loop (benchmark
        baseline). Results are bit-identical between the two paths.

        ``pipeline`` routes the batched path through the double-buffered
        async pipeline (``repro.ftx.pipeline``): pattern chunks split into
        ``cfg.pipeline_window``-stripe windows (``window`` overrides) whose
        disk reads, device launches and write-backs overlap. ``None``
        defaults to pipelining whenever ``cfg.pipeline_window > 0``;
        ``False`` is the synchronous fallback. Bit-identical either way.
        ``pipeline_hook`` is a diagnostic callback ``(stage, window_index)``
        (see ``repro.ftx.pipeline.PipelineHook``) used by the failure-
        injection tests.

        ``mesh_rules`` (or an ambient ``with_rules`` context) shards each
        launch's stripe axis over the mesh's data axes: one device-parallel
        launch per pattern chunk. Telemetry reports ``devices`` (widest
        device span seen) and ``device_launches`` (total per-device kernel
        executions across all launches). ``read/compute/write_seconds``
        report per-stage wall spans; ``overlap_seconds`` is the stage time
        the pipeline hid (0 on the synchronous paths).

        ``placement`` (a ``repro.dist.placement.PlacementMap``; defaults to
        the store's, else one derived from the node->shard default for the
        mesh's stripe-axis span) drives the *sharded gather*: each device
        shard's slice of the batched ``(S, |reads|, B)`` input is filled
        into its own host buffer and device_put directly onto that shard —
        no single-host stack exists — and every read is charged local or
        remote against the placement's locality cost model
        (``local_reads``/``remote_reads``/``gather_bytes_per_shard``).

        ``schedule`` (default ``cfg.stripe_schedule``) picks the stripe ->
        device-shard assignment of each batched chunk
        (``repro.dist.schedule``): ``"global"`` solves one exact min-cost
        assignment across *all* windows of each pattern group (stripes may
        migrate between windows; never predicted worse than the greedy
        per-chunk result); ``"locality"`` permutes each chunk so every
        stripe lands on the device slice whose serving host shard owns the
        most of its surviving blocks (greedy cost-model argmax, kept only
        when it beats the contiguous assignment — the predicted local-read
        fraction never drops); ``"none"`` keeps the contiguous default.
        Bit-identical every way: write-back is keyed by stripe id, so a
        permutation changes which shard reads which bytes, never the
        bytes. The telemetry reports both predictions
        (``scheduled_local_read_fraction`` vs
        ``contiguous_local_read_fraction``) so the scheduler's uplift is
        observable in every repair.

        ``destinations`` (default ``cfg.rebuild_destinations``) picks
        where rebuilt blocks are persisted: ``"in_place"`` writes each
        block back to its original (failed) node address — the seed
        behavior, which leaves the rebuilt copy on a DOWN node until that
        node revives; ``"topology"`` re-homes every lost block onto the
        least-loaded *surviving* failure domain via
        ``repro.dist.topology.pick_destinations``, preserving the
        placement policy's invariants (copyset width for ``spread``,
        per-domain dispersion for ``round_robin``) so follow-up repairs
        stay local. ``spare_of`` (node-level spares) takes precedence for
        blocks whose node it maps. The telemetry reports
        ``blocks_relocated`` and ``destination_copyset_fraction`` (how
        many re-homed blocks landed in a domain the stripe already
        occupied).
        """
        from repro.dist.placement import PlacementMap
        from repro.dist.schedule import schedule_group
        from repro.dist.sharding import current_rules
        from repro.dist.stripes import stripe_axis_span
        from repro.dist.topology import pick_destinations

        o = options if options is not None else RepairOptions()
        batched, mesh_rules = o.batched, o.mesh_rules
        pipeline, window = o.pipeline, o.window
        pipeline_hook, placement, schedule = (o.pipeline_hook, o.placement,
                                              o.schedule)
        destinations = o.destinations
        mr = mesh_rules if mesh_rules is not None else current_rules()
        if placement is None:
            placement = self.placement
        if placement is None:
            placement = PlacementMap.from_store(
                self, num_shards=max(1, stripe_axis_span(mr)))
        if schedule is None:
            schedule = self.cfg.stripe_schedule
        if schedule not in ("none", "locality", "global"):
            raise ValueError(f"unknown stripe schedule {schedule!r} "
                             f"(choose from none, locality, global)")
        if destinations is None:
            destinations = self.cfg.rebuild_destinations
        if destinations not in ("in_place", "topology"):
            raise ValueError(f"unknown rebuild destinations "
                             f"{destinations!r} "
                             f"(choose from in_place, topology)")
        use_pipeline = batched and (pipeline if pipeline is not None
                                    else self.cfg.pipeline_window > 0)
        before = self.telemetry.copy()
        t0 = time.perf_counter()
        affected: dict[frozenset[int], list[int]] = {}
        for sid in self.stripes:
            down = self._down_blocks(sid)
            if down:
                affected.setdefault(down, []).append(sid)
        # Topology-aware rebuild destinations: decide, up front and from the
        # pre-repair placement snapshot, a surviving home for every lost
        # block (repro.dist.topology.pick_destinations). Applied at
        # write-back; deterministic in (topology, placements, alive set).
        dest_of: Optional[dict[tuple[int, int], int]] = None
        dest_copyset = dest_total = 0
        if destinations == "topology" and affected:
            from repro.dist.placement import block_loads

            alive = {n for n, s in self.nodes.items() if s is NodeState.UP}
            lost = [(sid, b) for down, g_sids in affected.items()
                    for sid in g_sids for b in down]
            placements = {sid: list(self.stripes[sid].node_of_block)
                          for _, g_sids in affected.items() for sid in g_sids}
            loads = block_loads((s.node_of_block
                                 for s in self.stripes.values()),
                                self.num_nodes)
            dest_of = pick_destinations(
                self.topology, self.cfg.placement_policy, placements,
                lost, alive, loads=loads)
            dest_total = len(dest_of)
            for (sid, b), node in dest_of.items():
                live = {self.topology.domain_of(n)
                        for i, n in enumerate(placements[sid])
                        if (sid, i) not in dest_of}
                if self.topology.domain_of(node) in live:
                    dest_copyset += 1
        launches = 0
        devices = 1
        device_launches = 0
        windows = 0
        replans = 0
        # Stripe-scheduler prediction accumulators: local reads the chosen
        # order will serve shard-locally vs. what the contiguous order
        # would have, over the same total (repro.dist.schedule).
        sched_local = contig_local = sched_total = 0
        # Planning stops at the first unrecoverable pattern, but groups
        # sorted before it still repair (matching the seed's loop order):
        # a mixed-failure fleet rebuilds everything it can before raising.
        unrecoverable: Optional[IOError] = None
        work: list[tuple[list[int], frozenset[int], object]] = []
        for down, sids in sorted(affected.items(), key=lambda kv: kv[1][0]):
            if not batched:
                for sid in sids:
                    plan = multi_repair_plan(self.scheme, down)
                    if not plan.feasible:
                        raise IOError(f"stripe {sid} unrecoverable: {sorted(down)}")
                    rebuilt, _ = self._execute_multi(sid, plan, down, None)
                    self._finish_repair([sid], down, plan,
                                        {b: v[None] for b, v in rebuilt.items()},
                                        spare_of, dest_of)
                    launches += 1
                    device_launches += 1
                continue
            try:
                compiled = self.engine.planner.multi_plan(down)
            except RuntimeError:
                unrecoverable = IOError(
                    f"stripes {sids} unrecoverable: {sorted(down)}")
                break
            work.append((sids, down, compiled))
        if use_pipeline and work:
            from .pipeline import RepairPipeline

            res = RepairPipeline(
                self, spare_of=spare_of, dest_of=dest_of,
                byte_budget=_BATCH_BYTE_BUDGET,
                options=RepairOptions(
                    mesh_rules=mr, window=window,
                    pipeline_hook=pipeline_hook, placement=placement,
                    schedule=schedule),
            ).run(work)
            launches += res.launches
            devices = max(devices, res.devices)
            device_launches += res.device_launches
            windows = res.windows
            replans = res.replans
            sched_local += res.scheduled_local
            contig_local += res.contiguous_local
            sched_total += res.schedule_total
            with self._tele_lock:
                self.telemetry.read_seconds += res.read_seconds
                self.telemetry.compute_seconds += res.compute_seconds
                self.telemetry.write_seconds += res.write_seconds
        else:
            for sids, down, compiled in work:
                # Chunk by stripe count AND gathered-stack bytes, so wide
                # read sets at large block sizes stay within a bounded
                # host-memory transient. schedule_group assigns the whole
                # pattern group's stripes to windows x device slices at
                # once ("global" solves the cross-window transportation
                # problem; "locality"/"none" reduce to per-chunk).
                step = launch_step(self.cfg, len(compiled.reads), window)
                for cs in schedule_group(sids, compiled.reads, placement,
                                         mr, step=step, mode=schedule):
                    sched_local += cs.scheduled_local
                    contig_local += cs.contiguous_local
                    sched_total += cs.total_reads
                    span = self._repair_group(list(cs.sids), down,
                                              compiled, spare_of, mr,
                                              placement, dest_of)
                    launches += 1
                    devices = max(devices, span)
                    device_launches += span
        if unrecoverable is not None:
            raise unrecoverable
        t = self.telemetry.copy()
        wall = time.perf_counter() - t0
        gather_shards = {
            s: t.gather_bytes_per_shard.get(s, 0)
            - before.gather_bytes_per_shard.get(s, 0)
            for s in t.gather_bytes_per_shard}
        gather_shards = {s: v for s, v in gather_shards.items() if v}
        stage_sum = ((t.read_seconds - before.read_seconds)
                     + (t.compute_seconds - before.compute_seconds)
                     + (t.write_seconds - before.write_seconds))
        from repro.kernels.ops import effective_backend as _eff

        return {
            "stripes_repaired": sum(len(sids) for sids in affected.values()),
            "patterns": len(affected),
            # The formulation the repair launches actually ran (see
            # kernels.ops.effective_backend). Batched launches take the
            # engine's per-launch record; with zero launches (or the
            # per-stripe path, which never substitutes) this is the
            # configured backend's static resolution.
            "effective_backend": ((self.engine.effective_backend
                                   or _eff(self.cfg.backend))
                                  if batched else self.cfg.backend),
            "launches": launches,
            "devices": devices,
            "device_launches": device_launches,
            "batched": batched,
            "pipelined": bool(use_pipeline and work),
            "windows": windows,
            "replans": replans,
            "blocks_read": t.blocks_read - before.blocks_read,
            "bytes_read": t.bytes_read - before.bytes_read,
            "sim_seconds": t.sim_seconds - before.sim_seconds,
            "wall_seconds": wall,
            "read_seconds": t.read_seconds - before.read_seconds,
            "compute_seconds": t.compute_seconds - before.compute_seconds,
            "write_seconds": t.write_seconds - before.write_seconds,
            "overlap_seconds": max(0.0, stage_sum - wall),
            "repairs_local": t.repairs_local - before.repairs_local,
            "repairs_global": t.repairs_global - before.repairs_global,
            "local_reads": t.local_reads - before.local_reads,
            "remote_reads": t.remote_reads - before.remote_reads,
            "gather_bytes_per_shard": gather_shards,
            "schedule": schedule if batched else "none",
            "destinations": destinations,
            "blocks_relocated": t.blocks_relocated - before.blocks_relocated,
            "destination_copyset_fraction":
                dest_copyset / dest_total if dest_total else 1.0,
            "scheduled_local_reads": sched_local,
            "contiguous_local_reads": contig_local,
            "schedule_total_reads": sched_total,
            "scheduled_local_read_fraction":
                sched_local / sched_total if sched_total else 1.0,
            "contiguous_local_read_fraction":
                contig_local / sched_total if sched_total else 1.0,
        }

    def _gather_group(self, sids: list[int], reads: tuple[int, ...],
                      mesh_rules, placement):
        """Gather surviving blocks for a stripe group, shard by shard.

        Under a sharded mesh each device shard's slice of the batched
        ``(S, |reads|, B)`` input fills its *own* host buffer — only the
        blocks the shard's stripes need — and the buffers are device_put
        directly onto their shards and stitched into the global array
        (``repro.dist.placement.assemble_shards``). No single-host stack of
        the full batch exists. Degraded/single-device launches keep the
        one-buffer fast path (attributed to gather shard 0). Every read is
        charged local/remote against ``placement``.
        """
        from repro.dist.placement import assemble_shards, plan_gather

        shape = (len(sids), len(reads), self.cfg.block_size)
        layout, parts = plan_gather(shape, mesh_rules, placement)
        for part in parts:
            for i, sid in enumerate(sids[part.lo:part.hi]):
                for j, b in enumerate(reads):
                    part.buf[i, j] = self._read_block(
                        sid, b, shard=part.shard, placement=placement)
        if layout is None:
            return parts[0].buf
        return assemble_shards(shape, mesh_rules, layout,
                               [p.buf for p in parts])

    def _repair_group(self, sids: list[int], down: frozenset[int],
                      compiled, spare_of: Optional[dict[int, int]],
                      mesh_rules=None, placement=None,
                      dest_of: Optional[dict[tuple[int, int], int]] = None
                      ) -> int:
        """Batched repair of stripes sharing one failure pattern: per-shard
        gathers land each device's slice of the (S, |reads|, B) input
        straight on its shard (one host buffer per shard, no full-batch
        stack) and run a single launch (device-parallel under
        ``mesh_rules``; no per-block intermediate copies). Stages run
        strictly serial here — the span accounting makes that visible next
        to the pipelined path. Returns the device span of the launch."""
        t0 = time.perf_counter()
        stacked = self._gather_group(sids, compiled.reads, mesh_rules,
                                     placement)
        t1 = time.perf_counter()
        out = np.asarray(self.engine.execute(compiled, stacked, mesh_rules))
        rebuilt = {b: out[:, t, :] for t, b in enumerate(compiled.targets)}
        t2 = time.perf_counter()
        self._finish_repair(sids, down, compiled.meta, rebuilt, spare_of,
                            dest_of)
        t3 = time.perf_counter()
        with self._tele_lock:
            self.telemetry.read_seconds += t1 - t0
            self.telemetry.compute_seconds += t2 - t1
            self.telemetry.write_seconds += t3 - t2
        return self.engine.last_span

    def _finish_repair(self, sids: list[int], down: frozenset[int], plan,
                       rebuilt: dict[int, np.ndarray],
                       spare_of: Optional[dict[int, int]],
                       dest_of: Optional[dict[tuple[int, int], int]] = None
                       ) -> None:
        """Account telemetry and persist rebuilt (S, B) blocks per stripe.

        ``spare_of`` (node-level spares) takes precedence over ``dest_of``
        (per-block topology destinations); blocks neither maps write back
        in place. Thread-safe against concurrent prefetch reads: the
        pipeline calls this from its writer thread while reader threads
        bump the read counters."""
        relocated = 0
        for i, sid in enumerate(sids):
            st = self.stripes[sid]
            for b, data in rebuilt.items():
                target_node = st.node_of_block[b]
                if spare_of and target_node in spare_of:
                    st.node_of_block[b] = spare_of[target_node]
                elif dest_of and (sid, b) in dest_of:
                    st.node_of_block[b] = dest_of[(sid, b)]
                    relocated += 1
                self._write_block(sid, b, data[i])
        with self._tele_lock:
            if plan.all_local:
                self.telemetry.repairs_local += len(sids)
            else:
                self.telemetry.repairs_global += len(sids)
            self.telemetry.blocks_relocated += relocated

    def _execute_multi(self, sid: int, plan, down: frozenset[int],
                       rng: Optional[tuple[int, int]]):
        import jax.numpy as jnp
        avail = {}
        for b in plan.reads:
            avail[b] = jnp.asarray(self._read_block(sid, b, rng))
        rebuilt, _ = self.codec.repair_multi(down, avail)
        return {b: np.asarray(v) for b, v in rebuilt.items()}, plan

    # ---------------------------------------------------------- persistence
    def save_manifest(self) -> None:
        manifest = {
            "cfg": dataclasses.asdict(self.cfg),
            # An explicit topology round-trips (its policies place future
            # stripes); the inert default is omitted so plain stores keep
            # the seed manifest shape and load-time placement derivation.
            "topology": dataclasses.asdict(self.topology)
            if self._topology_explicit else None,
            "stripes": {str(s.sid): s.node_of_block
                        for s in self.stripes.values()},
            "objects": {k: dataclasses.asdict(m)
                        for k, m in self.objects.items()},
        }
        (self.root / "manifest.json").write_text(json.dumps(manifest))

    @classmethod
    def load(cls, root: str | Path) -> "StripeStore":
        from repro.dist.topology import Topology

        root = Path(root)
        manifest = json.loads((root / "manifest.json").read_text())
        cfg = StoreConfig(**manifest["cfg"])
        topo_doc = manifest.get("topology")
        topology = Topology(**topo_doc) if topo_doc else None
        store = cls(root, cfg, num_nodes=topology.num_nodes if topology
                    else max(max(v) for v in manifest["stripes"].values()) + 1
                    if manifest["stripes"] else None,
                    topology=topology)
        for sid, placement in manifest["stripes"].items():
            store.stripes[int(sid)] = Stripe(sid=int(sid),
                                             node_of_block=list(placement))
        store._next_sid = 1 + max((int(s) for s in manifest["stripes"]), default=-1)
        for k, m in manifest["objects"].items():
            store.objects[k] = ObjectMeta(**m)
        return store


class StripeStreamWriter:
    """Streaming put path: persist pre-encoded stripes for one object.

    ``put`` buffers plaintext on the coordinator and ``seal`` encodes one
    stripe at a time; the checkpoint encode pipeline instead produces whole
    ``(S, n, B)`` *encoded* windows off the batched engine and drains them
    from a writer thread while later windows are still encoding. This
    writer pre-allocates all stripes (ids + policy-driven placement) for a
    known object size up front — cheap host bookkeeping, no buffers — then
    accepts encoded windows in any order from any thread. ``close``
    registers the exact object chain ``put`` + ``seal`` would have written
    (head key plus ``#cont`` continuations, one stripe-extent object per
    stripe, zero-padded tail), so the streamed object reads back
    byte-identically through ``get``/``read_range``.
    """

    def __init__(self, store: StripeStore, key: str, total_bytes: int):
        if total_bytes < 0:
            raise ValueError("total_bytes must be >= 0")
        if key in store.objects:
            raise ValueError(f"object {key!r} already exists")
        self.store = store
        self.key = key
        self.total_bytes = total_bytes
        extent = store.cfg.k * store.cfg.block_size
        # A zero-byte object still occupies one (all-zeros) stripe, same as
        # put() opening a stripe for it.
        self.num_stripes = max(1, -(-total_bytes // extent))
        self.sids = [store._alloc_stripe() for _ in range(self.num_stripes)]
        self._written: set[int] = set()
        self._lock = threading.Lock()
        self._closed = False

    def write_window(self, first: int, encoded: np.ndarray) -> None:
        """Persist ``encoded`` — shape ``(S, n, block_size)``, already
        through the codec — as stream stripes ``first .. first+S-1``.
        Thread-safe; windows may land in any order."""
        enc = np.asarray(encoded, np.uint8)
        n, B = self.store.n, self.store.cfg.block_size
        if enc.ndim != 3 or enc.shape[1:] != (n, B):
            raise ValueError(f"window shape {enc.shape} != (S, {n}, {B})")
        if first < 0 or first + enc.shape[0] > self.num_stripes:
            raise ValueError(f"window [{first}, {first + enc.shape[0]}) "
                             f"outside {self.num_stripes}-stripe stream")
        for i in range(enc.shape[0]):
            sid = self.sids[first + i]
            for b in range(n):
                self.store._write_block(sid, b, enc[i, b])
        with self._lock:
            if self._closed:
                raise RuntimeError("stream writer already closed")
            self._written.update(range(first, first + enc.shape[0]))

    def close(self) -> None:
        """Register the object chain. Every stripe must have been written —
        a partial stream must ``abort()`` instead."""
        with self._lock:
            if self._closed:
                return
            missing = self.num_stripes - len(self._written)
            if missing:
                raise RuntimeError(f"cannot close stream: {missing} of "
                                   f"{self.num_stripes} stripes unwritten")
            self._closed = True
        extent = self.store.cfg.k * self.store.cfg.block_size
        remaining = self.total_bytes
        cur = self.key
        for sid in self.sids:
            take = min(extent, remaining)
            self.store.objects[cur] = ObjectMeta(key=cur, size=take, sid=sid,
                                                 block=0, offset=0)
            remaining -= take
            cur = cur + "#cont"

    def abort(self) -> None:
        """Drop the allocated stripes (and any block files already written)
        so a failed encode leaves no phantom stripes behind."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for sid in self.sids:
            st = self.store.stripes.pop(sid, None)
            if st is None:
                continue
            for b, node in enumerate(st.node_of_block):
                path = self.store.root / f"node{node}" / f"s{sid}_b{b}.blk"
                path.unlink(missing_ok=True)
