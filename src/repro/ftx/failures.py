"""Failure injection + elastic re-striping.

``FailureInjector`` drives Poisson node failures over simulated time against
a StripeStore, invoking repair and tracking exposure (time at reduced
redundancy) — the ingredients of the paper's MTTDL story, executed against
real encoded bytes instead of a closed-form chain.

``restripe`` implements elastic scaling: when the fleet grows or shrinks,
re-encode open stripes to a new geometry with bandwidth accounting (the
wide-stripe generation cost that StripeMerge-style systems optimize).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .stripestore import NodeState, StoreConfig, StripeStore


@dataclasses.dataclass
class FailureEvent:
    t: float
    node: int
    repaired_at: float
    blocks_read: int
    sim_seconds: float
    local: bool


class FailureInjector:
    def __init__(self, store: StripeStore, mttf_hours: float = 1000.0,
                 seed: int = 0, pipeline: Optional[bool] = None):
        self.store = store
        self.mttf_hours = mttf_hours
        self.rng = np.random.default_rng(seed)
        self.events: list[FailureEvent] = []
        self.clock = 0.0
        # None: the store's default (pipelined when cfg.pipeline_window > 0);
        # simulated repair *time* is identical either way — the pipeline
        # changes wall-clock, not the bandwidth model.
        self.pipeline = pipeline

    def run(self, hours: float, repair_immediately: bool = True) -> list[FailureEvent]:
        """Simulate ``hours`` of operation; each failure repairs onto the
        same node id (a fresh replacement host) before the next event."""
        n = self.store.num_nodes
        rate = n / self.mttf_hours
        t = self.clock
        end = self.clock + hours
        while True:
            t += float(self.rng.exponential(1.0 / rate))
            if t >= end:
                break
            node = int(self.rng.integers(n))
            self.store.fail_node(node)
            if repair_immediately:
                tele = self.store.repair_all(pipeline=self.pipeline)
                self.store.revive_node(node)
                self.events.append(FailureEvent(
                    t=t, node=node,
                    repaired_at=t + tele["sim_seconds"] / 3600.0,
                    blocks_read=tele["blocks_read"],
                    sim_seconds=tele["sim_seconds"],
                    local=tele["repairs_global"] == 0))
        self.clock = end
        return self.events


def restripe(store: StripeStore, new_cfg: StoreConfig, root) -> tuple[StripeStore, dict]:
    """Re-encode every object into a store with new geometry (elastic
    scaling). Returns (new store, bandwidth telemetry)."""
    new_store = StripeStore(root, new_cfg)
    before = store.telemetry.copy()
    for key, meta in list(store.objects.items()):
        if key.endswith("#cont"):
            continue  # continuation objects ride along with their head
        payload = store.get(key)
        new_store.put(key, payload.tobytes())
    new_store.seal()
    new_store.save_manifest()
    t = store.telemetry
    tele = {"bytes_moved": t.bytes_read - before.bytes_read,
            "blocks_read": t.blocks_read - before.blocks_read,
            "sim_seconds": t.sim_seconds - before.sim_seconds}
    return new_store, tele
