"""Failure injection + trace replay + elastic re-striping.

``FailureInjector`` drives Poisson node failures over simulated time against
a StripeStore, invoking repair and tracking exposure (time at reduced
redundancy) — the ingredients of the paper's MTTDL story, executed against
real encoded bytes instead of a closed-form chain. Since PR 8 it emits the
unified :mod:`repro.ftx.events` schema (``NodeFailEvent`` +
``RepairDoneEvent`` pairs) and can *replay* any event trace in that schema
against another store (:meth:`FailureInjector.replay`) — the same
vocabulary the event-driven fleet simulator (``repro.sim``) speaks, so
injector logs, simulator output, and future real-cluster traces are
interchangeable.

``restripe`` implements elastic scaling: when the fleet grows or shrinks,
re-encode open stripes to a new geometry with bandwidth accounting (the
wide-stripe generation cost that StripeMerge-style systems optimize).
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from .events import (FleetEvent, NodeFailEvent, RackFailEvent,
                     RepairDoneEvent, sort_events)
from .options import RepairOptions
from .stripestore import StoreConfig, StripeStore


class FailureInjector:
    def __init__(self, store: StripeStore, mttf_hours: float = 1000.0,
                 seed: int = 0, pipeline: Optional[bool] = None):
        self.store = store
        self.mttf_hours = mttf_hours
        self.rng = np.random.default_rng(seed)
        self.events: list[FleetEvent] = []
        self.clock = 0.0
        # None: the store's default (pipelined when cfg.pipeline_window > 0);
        # simulated repair *time* is identical either way — the pipeline
        # changes wall-clock, not the bandwidth model.
        self.pipeline = pipeline

    def _fail_and_repair(self, t: float, node: int,
                         repair: bool) -> list[FleetEvent]:
        """Fail ``node`` at ``t`` (and repair it through the real pipeline
        when ``repair``), returning the emitted schema events."""
        out: list[FleetEvent] = [NodeFailEvent(t=t, node=node)]
        self.store.fail_node(node)
        if repair:
            tele = self.store.repair_all(
                options=RepairOptions(pipeline=self.pipeline))
            self.store.revive_node(node)
            out.append(RepairDoneEvent(
                t=t + tele["sim_seconds"] / 3600.0,
                unit=node, kind="node", started_at=t,
                blocks_read=tele["blocks_read"],
                sim_seconds=tele["sim_seconds"],
                local=tele["repairs_global"] == 0))
        return out

    def run(self, hours: float,
            repair_immediately: bool = True) -> list[FleetEvent]:
        """Simulate ``hours`` of operation; each failure repairs onto the
        same node id (a fresh replacement host) before the next event.

        Returns the full emitted event log (``NodeFailEvent`` followed by
        its ``RepairDoneEvent`` when repairs run), also accumulated on
        ``self.events``.
        """
        n = self.store.num_nodes
        rate = n / self.mttf_hours
        t = self.clock
        end = self.clock + hours
        while True:
            t += float(self.rng.exponential(1.0 / rate))
            if t >= end:
                break
            node = int(self.rng.integers(n))
            self.events.extend(
                self._fail_and_repair(t, node, repair_immediately))
        self.clock = end
        return self.events

    def replay(self, events: Iterable[FleetEvent],
               repair_immediately: bool = True) -> list[FleetEvent]:
        """Consume an event trace: apply every ``NodeFailEvent`` against
        the store in canonical order, repairing through the real pipeline.

        The consuming half of the unified schema: a trace emitted by
        another injector (different store geometry), by the fleet
        simulator, or parsed from a real cluster log replays against this
        store's actual codec and repair pipeline. Non-failure events
        (repair-done, scrub, ...) in the input are ignored — repairs are
        re-executed here, so the returned log carries *this* store's repair
        costs. Advances ``self.clock`` to the last event time.
        """
        out: list[FleetEvent] = []
        for ev in sort_events(events):
            if isinstance(ev, NodeFailEvent):
                if not 0 <= ev.node < self.store.num_nodes:
                    raise ValueError(f"trace node {ev.node} outside store "
                                     f"with {self.store.num_nodes} nodes")
                out.extend(self._fail_and_repair(ev.t, ev.node,
                                                 repair_immediately))
                self.clock = max(self.clock, ev.t)
        self.events.extend(out)
        return out

    def failures(self) -> list[NodeFailEvent]:
        """Just the failure events of the accumulated log."""
        return [e for e in self.events if isinstance(e, NodeFailEvent)]

    def repairs(self) -> list[RepairDoneEvent]:
        """Just the repair-done events of the accumulated log."""
        return [e for e in self.events if isinstance(e, RepairDoneEvent)]


def replay_trace(store: StripeStore, events: Iterable[FleetEvent], *,
                 options: Optional[RepairOptions] = None,
                 revive: bool = True,
                 rebalance_after: bool = False) -> dict:
    """Replay a failure trace with *correlated-arrival* repair batching.

    The orchestration entry point (DESIGN.md §14): where
    :meth:`FailureInjector.replay` repairs one node at a time,
    this groups every failure sharing a timestamp — the correlated
    rack/burst arrivals the trace fixtures encode — fails the whole batch,
    and runs **one** ``repair_all`` over it, which is exactly when the
    cross-window assignment (``options.schedule="global"``) and
    topology-aware destinations (``options.destinations="topology"``)
    have room to win. ``RackFailEvent`` rows expand to the rack's nodes
    through the store topology; nodes already DOWN are skipped.

    Args:
        store: the store to drive; mutated in place.
        events: any :mod:`repro.ftx.events` trace (only failure events are
            consumed; repair-done rows are re-earned here).
        options: forwarded to every ``repair_all`` batch.
        revive: bring failed nodes back UP after their batch repairs
            (fresh replacements). ``False`` leaves them DOWN — the
            permanent-loss mode destination selection exists for.
        rebalance_after: run one ``repro.ftx.rebalance`` pass after the
            last batch and report it.

    Returns:
        ``{"batches": [...], "events": [...], "totals": {...},
        "rebalance": ...}`` — one row per correlated batch carrying its
        time, failed nodes, and the repair telemetry deltas the
        orchestration benchmark gates (local/total reads, scheduled vs
        contiguous locality, blocks relocated); totals aggregate them.
    """
    options = options or RepairOptions()
    batches: dict[float, list[int]] = {}
    for ev in sort_events(events):
        nodes: list[int] = []
        if isinstance(ev, NodeFailEvent):
            nodes = [ev.node]
        elif isinstance(ev, RackFailEvent):
            nodes = store.topology.nodes_in(ev.rack)
        for n in nodes:
            if not 0 <= n < store.num_nodes:
                raise ValueError(f"trace node {n} outside store "
                                 f"with {store.num_nodes} nodes")
            batches.setdefault(ev.t, []).append(n)

    rows: list[dict] = []
    out_events: list[FleetEvent] = []
    for t in sorted(batches):
        failed = sorted(set(n for n in batches[t]
                            if store.nodes[n].name == "UP"))
        if not failed:
            continue
        for n in failed:
            store.fail_node(n)
            out_events.append(NodeFailEvent(t=t, node=n))
        before = store.telemetry.copy()
        tele = store.repair_all(options=options)
        diff = store.telemetry
        row = {"t": t, "nodes": failed,
               "blocks_read": tele["blocks_read"],
               "sim_seconds": tele["sim_seconds"],
               "local_reads": diff.local_reads - before.local_reads,
               "remote_reads": diff.remote_reads - before.remote_reads,
               "scheduled_local": tele.get("scheduled_local_reads", 0),
               "contiguous_local": tele.get("contiguous_local_reads", 0),
               "schedule_total": tele.get("schedule_total_reads", 0),
               "blocks_relocated": tele.get("blocks_relocated", 0),
               "repairs_local": tele["repairs_local"],
               "repairs_global": tele["repairs_global"]}
        rows.append(row)
        done_t = t + tele["sim_seconds"] / 3600.0
        for n in failed:
            if revive:
                store.revive_node(n)
            out_events.append(RepairDoneEvent(
                t=done_t, unit=n, kind="node", started_at=t,
                blocks_read=tele["blocks_read"],
                sim_seconds=tele["sim_seconds"],
                local=tele["repairs_global"] == 0))

    totals = {k: sum(r[k] for r in rows) for k in
              ("blocks_read", "local_reads", "remote_reads",
               "scheduled_local", "contiguous_local", "schedule_total",
               "blocks_relocated", "repairs_local", "repairs_global")}
    totals["sim_seconds"] = sum(r["sim_seconds"] for r in rows)
    result = {"batches": rows, "events": sort_events(out_events),
              "totals": totals, "rebalance": None}
    if rebalance_after:
        from .rebalance import rebalance

        rep = rebalance(store)
        result["rebalance"] = {
            "planned": rep.planned, "moved": rep.moved,
            "windows": rep.windows, "bytes_moved": rep.bytes_moved,
            "imbalance_before": rep.imbalance_before,
            "imbalance_after": rep.imbalance_after}
    return result


def restripe(store: StripeStore, new_cfg: StoreConfig, root) -> tuple[StripeStore, dict]:
    """Re-encode every object into a store with new geometry (elastic
    scaling). Returns (new store, bandwidth telemetry)."""
    new_store = StripeStore(root, new_cfg)
    before = store.telemetry.copy()
    for key, meta in list(store.objects.items()):
        if key.endswith("#cont"):
            continue  # continuation objects ride along with their head
        payload = store.get(key)
        new_store.put(key, payload.tobytes())
    new_store.seal()
    new_store.save_manifest()
    t = store.telemetry
    tele = {"bytes_moved": t.bytes_read - before.bytes_read,
            "blocks_read": t.blocks_read - before.blocks_read,
            "sim_seconds": t.sim_seconds - before.sim_seconds}
    return new_store, tele
