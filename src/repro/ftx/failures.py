"""Failure injection + trace replay + elastic re-striping.

``FailureInjector`` drives Poisson node failures over simulated time against
a StripeStore, invoking repair and tracking exposure (time at reduced
redundancy) — the ingredients of the paper's MTTDL story, executed against
real encoded bytes instead of a closed-form chain. Since PR 8 it emits the
unified :mod:`repro.ftx.events` schema (``NodeFailEvent`` +
``RepairDoneEvent`` pairs) and can *replay* any event trace in that schema
against another store (:meth:`FailureInjector.replay`) — the same
vocabulary the event-driven fleet simulator (``repro.sim``) speaks, so
injector logs, simulator output, and future real-cluster traces are
interchangeable.

``restripe`` implements elastic scaling: when the fleet grows or shrinks,
re-encode open stripes to a new geometry with bandwidth accounting (the
wide-stripe generation cost that StripeMerge-style systems optimize).
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from .events import (FleetEvent, NodeFailEvent, RepairDoneEvent,
                     sort_events)
from .options import RepairOptions
from .stripestore import StoreConfig, StripeStore


class FailureInjector:
    def __init__(self, store: StripeStore, mttf_hours: float = 1000.0,
                 seed: int = 0, pipeline: Optional[bool] = None):
        self.store = store
        self.mttf_hours = mttf_hours
        self.rng = np.random.default_rng(seed)
        self.events: list[FleetEvent] = []
        self.clock = 0.0
        # None: the store's default (pipelined when cfg.pipeline_window > 0);
        # simulated repair *time* is identical either way — the pipeline
        # changes wall-clock, not the bandwidth model.
        self.pipeline = pipeline

    def _fail_and_repair(self, t: float, node: int,
                         repair: bool) -> list[FleetEvent]:
        """Fail ``node`` at ``t`` (and repair it through the real pipeline
        when ``repair``), returning the emitted schema events."""
        out: list[FleetEvent] = [NodeFailEvent(t=t, node=node)]
        self.store.fail_node(node)
        if repair:
            tele = self.store.repair_all(
                options=RepairOptions(pipeline=self.pipeline))
            self.store.revive_node(node)
            out.append(RepairDoneEvent(
                t=t + tele["sim_seconds"] / 3600.0,
                unit=node, kind="node", started_at=t,
                blocks_read=tele["blocks_read"],
                sim_seconds=tele["sim_seconds"],
                local=tele["repairs_global"] == 0))
        return out

    def run(self, hours: float,
            repair_immediately: bool = True) -> list[FleetEvent]:
        """Simulate ``hours`` of operation; each failure repairs onto the
        same node id (a fresh replacement host) before the next event.

        Returns the full emitted event log (``NodeFailEvent`` followed by
        its ``RepairDoneEvent`` when repairs run), also accumulated on
        ``self.events``.
        """
        n = self.store.num_nodes
        rate = n / self.mttf_hours
        t = self.clock
        end = self.clock + hours
        while True:
            t += float(self.rng.exponential(1.0 / rate))
            if t >= end:
                break
            node = int(self.rng.integers(n))
            self.events.extend(
                self._fail_and_repair(t, node, repair_immediately))
        self.clock = end
        return self.events

    def replay(self, events: Iterable[FleetEvent],
               repair_immediately: bool = True) -> list[FleetEvent]:
        """Consume an event trace: apply every ``NodeFailEvent`` against
        the store in canonical order, repairing through the real pipeline.

        The consuming half of the unified schema: a trace emitted by
        another injector (different store geometry), by the fleet
        simulator, or parsed from a real cluster log replays against this
        store's actual codec and repair pipeline. Non-failure events
        (repair-done, scrub, ...) in the input are ignored — repairs are
        re-executed here, so the returned log carries *this* store's repair
        costs. Advances ``self.clock`` to the last event time.
        """
        out: list[FleetEvent] = []
        for ev in sort_events(events):
            if isinstance(ev, NodeFailEvent):
                if not 0 <= ev.node < self.store.num_nodes:
                    raise ValueError(f"trace node {ev.node} outside store "
                                     f"with {self.store.num_nodes} nodes")
                out.extend(self._fail_and_repair(ev.t, ev.node,
                                                 repair_immediately))
                self.clock = max(self.clock, ev.t)
        self.events.extend(out)
        return out

    def failures(self) -> list[NodeFailEvent]:
        """Just the failure events of the accumulated log."""
        return [e for e in self.events if isinstance(e, NodeFailEvent)]

    def repairs(self) -> list[RepairDoneEvent]:
        """Just the repair-done events of the accumulated log."""
        return [e for e in self.events if isinstance(e, RepairDoneEvent)]


def restripe(store: StripeStore, new_cfg: StoreConfig, root) -> tuple[StripeStore, dict]:
    """Re-encode every object into a store with new geometry (elastic
    scaling). Returns (new store, bandwidth telemetry)."""
    new_store = StripeStore(root, new_cfg)
    before = store.telemetry.copy()
    for key, meta in list(store.objects.items()):
        if key.endswith("#cont"):
            continue  # continuation objects ride along with their head
        payload = store.get(key)
        new_store.put(key, payload.tobytes())
    new_store.seal()
    new_store.save_manifest()
    t = store.telemetry
    tele = {"bytes_moved": t.bytes_read - before.bytes_read,
            "blocks_read": t.blocks_read - before.blocks_read,
            "sim_seconds": t.sim_seconds - before.sim_seconds}
    return new_store, tele
