"""Fleet-level durability sizing and fleet repair orchestration.

The paper's MTTDL analysis is per-stripe; an operator provisioning an
erasure-coded checkpoint store for an N-node training fleet needs the
fleet-level view: with S independent stripes, MTTDL_fleet ≈ MTTDL_stripe / S
(competing exponentials), and the overhead/durability frontier across
schemes and (k, r, p).

``size_fleet`` sweeps candidate geometries and returns those meeting a
target fleet MTTDL at minimal storage overhead — the decision the paper's
Tables II+VI support, automated.

``repair_failed_nodes`` is the fleet-repair entrypoint: mark nodes down and
rebuild every affected stripe through the store's batched engine, which
groups stripes by failure pattern and issues one compiled plan + one kernel
launch per pattern chunk (DESIGN.md §4) instead of a Python loop over
stripes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.core.reliability import ReliabilityParams, stripe_mttdl_years
from repro.core.schemes import make_scheme

from .options import RepairOptions


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    nodes: int                 # hosts contributing checkpoint shards
    state_bytes: int           # total protected state (params + moments)
    block_bytes: int = 1 << 28
    target_mttdl_years: float = 1e6
    params: ReliabilityParams = ReliabilityParams()


@dataclasses.dataclass(frozen=True)
class Candidate:
    scheme: str
    k: int
    r: int
    p: int
    overhead: float            # (n/k) - 1
    stripes: int
    stripe_mttdl_years: float
    fleet_mttdl_years: float

    @property
    def meets(self) -> bool:
        return self.fleet_mttdl_years >= 0


def evaluate(spec: FleetSpec, scheme: str, k: int, r: int, p: int,
             samples: int = 400, model: str = "paper") -> Candidate:
    s = make_scheme(scheme, k, r, p)
    stripes = max(1, -(-spec.state_bytes // (k * spec.block_bytes)))
    per = stripe_mttdl_years(s, spec.params, samples=samples, model=model)
    return Candidate(scheme=scheme, k=k, r=r, p=p,
                     overhead=s.n / k - 1.0, stripes=stripes,
                     stripe_mttdl_years=per,
                     fleet_mttdl_years=per / stripes)


def size_fleet(spec: FleetSpec,
               schemes: tuple[str, ...] = ("azure", "cp-azure", "cp-uniform"),
               geometries: Optional[list[tuple[int, int, int]]] = None,
               samples: int = 300, model: str = "paper") -> list[Candidate]:
    """All candidates meeting the target, cheapest overhead first."""
    geometries = geometries or [(12, 2, 2), (24, 2, 2), (24, 3, 3),
                                (48, 4, 3), (48, 4, 4), (96, 5, 4)]
    out = []
    for scheme in schemes:
        for (k, r, p) in geometries:
            if k + r + p > spec.nodes:
                continue
            try:
                c = evaluate(spec, scheme, k, r, p, samples=samples,
                             model=model)
            except Exception:
                continue
            out.append(c)
    ok = [c for c in out if c.fleet_mttdl_years >= spec.target_mttdl_years]
    pool = ok or out
    return sorted(pool, key=lambda c: (c.overhead, -c.fleet_mttdl_years))


# --------------------------------------------------------------------------
# fleet repair orchestration
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetRepairReport:
    """What a node-failure repair cost, fleet-wide."""
    failed_nodes: tuple[int, ...]
    stripes_repaired: int
    patterns: int               # distinct per-stripe failure patterns seen
    launches: int               # batched kernel launches issued
    blocks_read: int
    bytes_read: int
    sim_seconds: float          # link-model time (paper's repair-time metric)
    wall_seconds: float
    repairs_local: int
    repairs_global: int
    plan_cache: dict            # planner hit/miss/eviction counters
    devices: int = 1            # widest device span of any launch
    device_launches: int = 0    # per-device kernel executions, all launches
    # Async-pipeline observability (repro.ftx.pipeline): per-stage wall
    # spans plus how much of them the double buffer hid. Zero on the
    # synchronous paths except the stage spans, which are accounted there
    # too (serially, so overlap_seconds stays 0).
    pipelined: bool = False
    windows: int = 0            # pipeline windows executed
    replans: int = 0            # windows re-planned after mid-repair failures
    read_seconds: float = 0.0
    compute_seconds: float = 0.0
    write_seconds: float = 0.0
    overlap_seconds: float = 0.0
    # Locality accounting (repro.dist.placement.PlacementMap): repair reads
    # served shard-locally vs. across shards, and the gather bytes each
    # shard pulled — the per-shard split of the batched read stack.
    local_reads: int = 0
    remote_reads: int = 0
    gather_bytes_per_shard: dict = dataclasses.field(default_factory=dict)
    # Locality-aware stripe scheduling (repro.dist.schedule): which
    # stripe->device-shard assignment ran ("locality" or "none") and the
    # predicted shard-local read fraction it achieved vs. what the
    # contiguous assignment would have — the scheduler's uplift, observable
    # per repair. Both are 1.0 when nothing was batched/predicted.
    schedule: str = "none"
    scheduled_local_read_fraction: float = 1.0
    contiguous_local_read_fraction: float = 1.0
    # Rebuild-destination selection (repro.dist.topology.pick_destinations):
    # which write-back policy ran ("in_place" or "topology"), how many
    # rebuilt blocks were re-homed onto surviving nodes, and what fraction
    # of those landed in a domain the stripe already occupied (copyset
    # preservation — the spread policy's width bound, observable).
    destinations: str = "in_place"
    blocks_relocated: int = 0
    destination_copyset_fraction: float = 1.0
    # The kernel formulation the repair launches actually executed
    # (repro.kernels.ops.effective_backend): equals the store's configured
    # backend except the one documented substitution — an interpreted "gf"
    # batch runs the fused table path and reports "ref". Recorded per
    # repair so no backend choice is ever silently downgraded.
    effective_backend: str = ""

    @property
    def stripes_per_launch(self) -> float:
        return self.stripes_repaired / max(1, self.launches)

    @property
    def schedule_uplift(self) -> float:
        """Scheduled over contiguous predicted local fraction (1.0 = the
        scheduler found nothing to improve, or scheduling was off; ``inf``
        when it improved on a contiguous assignment with zero locality)."""
        if self.contiguous_local_read_fraction <= 0:
            return 1.0 if self.scheduled_local_read_fraction <= 0 \
                else float("inf")
        return (self.scheduled_local_read_fraction
                / self.contiguous_local_read_fraction)

    @property
    def overlap_ratio(self) -> float:
        """Fraction of stage time hidden by pipelining (0 = fully serial)."""
        busy = self.read_seconds + self.compute_seconds + self.write_seconds
        return self.overlap_seconds / busy if busy > 0 else 0.0

    @property
    def local_read_fraction(self) -> float:
        """Fraction of repair reads served from the reading shard's nodes."""
        total = self.local_reads + self.remote_reads
        return self.local_reads / total if total else 1.0


@dataclasses.dataclass(frozen=True)
class DegradedReadReport:
    """What the degraded-read serving path did, fleet-wide.

    The serving-side sibling of :class:`FleetRepairReport`: built from the
    store's serving counters (``StripeStore.read``/``read_range``) plus the
    read-latency reservoir, by :func:`read_report`. All counters are exact;
    the latency quantiles cover the recorder's retained window.
    """
    direct_reads: int           # requests served straight from live blocks
    degraded_reads: int         # requests that landed on a lost block
    coalesced_reads: int        # degraded requests served by another
    #                             request's in-flight decode
    decode_launches: int        # engine launches the serving path issued
    local_decodes: int          # ... with a local (group/cascade) plan
    global_decodes: int         # ... that fell back to a global decode
    replans: int                # decodes re-planned after a source died
    cache_hits: int
    cache_misses: int
    cache_invalidations: int    # hot entries dropped by repair/write-back
    served_bytes: int           # payload bytes returned to clients
    blocks_read: int            # source blocks fetched (all paths)
    bytes_read: int
    latency: dict               # count/bytes/p50_ms/p99_ms/mean_ms/max_ms

    @property
    def coalescing_ratio(self) -> float:
        """Degraded requests per decode launch: how many reads each launch
        amortized over (1.0 = naive per-request decode; cache hits and
        coalesced waiters both push this up)."""
        return self.degraded_reads / max(1, self.decode_launches)

    @property
    def cache_hit_rate(self) -> float:
        lookups = self.cache_hits + self.cache_misses
        return self.cache_hits / lookups if lookups else 0.0

    @property
    def local_decode_fraction(self) -> float:
        """Fraction of serving decodes satisfied without a global decode —
        the paper's low-bandwidth degraded-read claim, counted."""
        total = self.local_decodes + self.global_decodes
        return self.local_decodes / total if total else 1.0

    @property
    def p50_ms(self) -> float:
        return self.latency.get("p50_ms", 0.0)

    @property
    def p99_ms(self) -> float:
        return self.latency.get("p99_ms", 0.0)


def read_report(store, *, reset: bool = False) -> DegradedReadReport:
    """Snapshot the store's degraded-read serving telemetry.

    ``reset=True`` also zeroes the serving counters and the latency window
    (repair/locality telemetry is left untouched), so per-scenario load
    generators can diff cleanly.
    """
    t = store.telemetry
    with store._tele_lock:
        snap = t.copy()
    latency = (store.read_latency.reset() if reset
               else store.read_latency.snapshot())
    if reset:
        with store._tele_lock:
            t.direct_reads = t.degraded_reads = t.coalesced_reads = 0
            t.serve_decode_launches = 0
            t.serve_local_decodes = t.serve_global_decodes = 0
            t.serve_replans = 0
            t.cache_hits = t.cache_misses = t.cache_invalidations = 0
            t.served_bytes = 0
    return DegradedReadReport(
        direct_reads=snap.direct_reads,
        degraded_reads=snap.degraded_reads,
        coalesced_reads=snap.coalesced_reads,
        decode_launches=snap.serve_decode_launches,
        local_decodes=snap.serve_local_decodes,
        global_decodes=snap.serve_global_decodes,
        replans=snap.serve_replans,
        cache_hits=snap.cache_hits,
        cache_misses=snap.cache_misses,
        cache_invalidations=snap.cache_invalidations,
        served_bytes=snap.served_bytes,
        blocks_read=snap.blocks_read,
        bytes_read=snap.bytes_read,
        latency=latency,
    )


def repair_failed_nodes(store, nodes: Iterable[int], *,
                        spare_of: Optional[dict[int, int]] = None,
                        revive: bool = True,
                        options: Optional[RepairOptions] = None
                        ) -> FleetRepairReport:
    """Fail ``nodes`` and rebuild every affected stripe in the store.

    All stripes whose blocks lived on the failed nodes are grouped by
    failure pattern and repaired through the store's batched engine — one
    launch per (pattern, chunk). ``options``
    (:class:`repro.ftx.options.RepairOptions`) carries the execution
    knobs.

    ``options.pipeline`` (default: on when ``cfg.pipeline_window > 0``)
    overlaps each window's disk reads, device launch and write-back
    through the async pipeline; the report's ``read/compute/write_seconds``
    and ``overlap_seconds`` fields make the overlap observable.
    ``options.mesh_rules`` (or an ambient ``with_rules`` context)
    device-shards each launch's stripe axis; the report's
    ``devices``/``device_launches`` fields record the resulting per-device
    launch counts. ``options.placement`` (a
    ``repro.dist.placement.PlacementMap``; defaults to the store's, else
    one derived from the node->shard default for the mesh's stripe-axis
    span) drives the per-shard gather and the local/remote read accounting
    reported via ``local_reads``/``remote_reads``/
    ``gather_bytes_per_shard``. ``options.schedule`` (default
    ``cfg.stripe_schedule``) picks the stripe -> device-shard assignment of
    each batched chunk: ``"locality"`` (``repro.dist.schedule``) permutes
    chunks onto the shards owning most of their surviving blocks,
    bit-identically and never predicted worse than the contiguous
    ``"none"`` default; the report's ``scheduled_local_read_fraction`` vs
    ``contiguous_local_read_fraction`` (and ``schedule_uplift``) make the
    difference observable. ``revive`` marks the nodes UP again after
    the rebuild (blocks were re-materialized in place or onto spares).
    """
    o = options if options is not None else RepairOptions()
    nodes = tuple(nodes)
    for node in nodes:
        store.fail_node(node)
    before = store.codec.planner.stats.snapshot()
    tele = store.repair_all(spare_of=spare_of, options=o)
    after = store.codec.planner.stats.snapshot()
    if revive:
        for node in nodes:
            store.revive_node(node)
    return FleetRepairReport(
        failed_nodes=nodes,
        stripes_repaired=tele["stripes_repaired"],
        patterns=tele["patterns"],
        launches=tele["launches"],
        devices=tele.get("devices", 1),
        device_launches=tele.get("device_launches", tele["launches"]),
        blocks_read=tele["blocks_read"],
        bytes_read=tele["bytes_read"],
        sim_seconds=tele["sim_seconds"],
        wall_seconds=tele["wall_seconds"],
        repairs_local=tele["repairs_local"],
        repairs_global=tele["repairs_global"],
        plan_cache={k: after[k] - before[k] for k in after},
        pipelined=tele.get("pipelined", False),
        windows=tele.get("windows", 0),
        replans=tele.get("replans", 0),
        read_seconds=tele.get("read_seconds", 0.0),
        compute_seconds=tele.get("compute_seconds", 0.0),
        write_seconds=tele.get("write_seconds", 0.0),
        overlap_seconds=tele.get("overlap_seconds", 0.0),
        local_reads=tele.get("local_reads", 0),
        remote_reads=tele.get("remote_reads", 0),
        gather_bytes_per_shard=tele.get("gather_bytes_per_shard", {}),
        schedule=tele.get("schedule", "none"),
        scheduled_local_read_fraction=tele.get(
            "scheduled_local_read_fraction", 1.0),
        contiguous_local_read_fraction=tele.get(
            "contiguous_local_read_fraction", 1.0),
        destinations=tele.get("destinations", "in_place"),
        blocks_relocated=tele.get("blocks_relocated", 0),
        destination_copyset_fraction=tele.get(
            "destination_copyset_fraction", 1.0),
        effective_backend=tele.get("effective_backend",
                                   store.cfg.backend),
    )
