"""Fleet-level durability sizing.

The paper's MTTDL analysis is per-stripe; an operator provisioning an
erasure-coded checkpoint store for an N-node training fleet needs the
fleet-level view: with S independent stripes, MTTDL_fleet ≈ MTTDL_stripe / S
(competing exponentials), and the overhead/durability frontier across
schemes and (k, r, p).

``size_fleet`` sweeps candidate geometries and returns those meeting a
target fleet MTTDL at minimal storage overhead — the decision the paper's
Tables II+VI support, automated.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.reliability import ReliabilityParams, stripe_mttdl_years
from repro.core.schemes import make_scheme


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    nodes: int                 # hosts contributing checkpoint shards
    state_bytes: int           # total protected state (params + moments)
    block_bytes: int = 1 << 28
    target_mttdl_years: float = 1e6
    params: ReliabilityParams = ReliabilityParams()


@dataclasses.dataclass(frozen=True)
class Candidate:
    scheme: str
    k: int
    r: int
    p: int
    overhead: float            # (n/k) - 1
    stripes: int
    stripe_mttdl_years: float
    fleet_mttdl_years: float

    @property
    def meets(self) -> bool:
        return self.fleet_mttdl_years >= 0


def evaluate(spec: FleetSpec, scheme: str, k: int, r: int, p: int,
             samples: int = 400, model: str = "paper") -> Candidate:
    s = make_scheme(scheme, k, r, p)
    stripes = max(1, -(-spec.state_bytes // (k * spec.block_bytes)))
    per = stripe_mttdl_years(s, spec.params, samples=samples, model=model)
    return Candidate(scheme=scheme, k=k, r=r, p=p,
                     overhead=s.n / k - 1.0, stripes=stripes,
                     stripe_mttdl_years=per,
                     fleet_mttdl_years=per / stripes)


def size_fleet(spec: FleetSpec,
               schemes: tuple[str, ...] = ("azure", "cp-azure", "cp-uniform"),
               geometries: Optional[list[tuple[int, int, int]]] = None,
               samples: int = 300, model: str = "paper") -> list[Candidate]:
    """All candidates meeting the target, cheapest overhead first."""
    geometries = geometries or [(12, 2, 2), (24, 2, 2), (24, 3, 3),
                                (48, 4, 3), (48, 4, 4), (96, 5, 4)]
    out = []
    for scheme in schemes:
        for (k, r, p) in geometries:
            if k + r + p > spec.nodes:
                continue
            try:
                c = evaluate(spec, scheme, k, r, p, samples=samples,
                             model=model)
            except Exception:
                continue
            out.append(c)
    ok = [c for c in out if c.fleet_mttdl_years >= spec.target_mttdl_years]
    pool = ok or out
    return sorted(pool, key=lambda c: (c.overhead, -c.fleet_mttdl_years))
