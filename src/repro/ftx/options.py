"""Options objects for the repair and serving paths (PR 8 API collapse).

``StripeStore.repair_all`` grew one keyword per PR — ``batched=``,
``mesh_rules=``, ``pipeline=``, ``window=``, ``pipeline_hook=``,
``placement=``, ``schedule=`` — and every layer above it
(``RepairPipeline``, ``repair_failed_nodes``, ``FailureInjector``, the
benchmarks) re-declared the same sprawl to forward it. This module
collapses the knobs into two frozen dataclasses:

* :class:`RepairOptions` — how to execute a repair. ``None`` fields mean
  "the store's configured default", exactly the semantics the old kwargs
  had, so ``RepairOptions()`` is always safe.
* :class:`ServeOptions` — how to serve a (possibly degraded) read:
  per-request overrides of the store-config coalescing/cache knobs.

All entry points take ``options=`` exclusively. The pre-PR-8 loose kwargs
were accepted (with a ``DeprecationWarning``) for the one promised cycle
and deleted in PR 9; passing them now raises ``TypeError`` like any other
unknown keyword.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class RepairOptions:
    """How to execute a repair (``StripeStore.repair_all`` and friends).

    Every field defaults to "whatever the store is configured to do":
    ``None`` means the store-config default for that knob (``pipeline`` ->
    ``cfg.pipeline_window > 0``, ``window`` -> ``cfg.pipeline_window``,
    ``schedule`` -> ``cfg.stripe_schedule``, ``placement`` -> the store's
    map, ``mesh_rules`` -> the ambient ``with_rules`` context).
    """
    batched: bool = True                 # pattern-batched engine vs seed loop
    mesh_rules: Any = None               # device sharding of the stripe axis
    pipeline: Optional[bool] = None      # async double-buffered windows
    window: Optional[int] = None         # stripes per window/launch chunk
    pipeline_hook: Optional[Callable[[str, int], None]] = None
    placement: Any = None                # PlacementMap for the sharded gather
    schedule: Optional[str] = None       # "none" | "locality" | "global"
    destinations: Optional[str] = None   # rebuild write-back placement:
    #                                      "in_place" | "topology" (None ->
    #                                      cfg.rebuild_destinations)


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """How to serve one read (``StripeStore.read``/``read_range``).

    Per-request overrides of the store-config serving knobs; ``None``
    keeps the configured behavior. ``coalesce=False`` opts this request
    out of in-flight decode sharing (it always leads its own decode);
    ``use_cache=False`` bypasses the hot-block cache both ways — no probe,
    and the reconstruction is not inserted (sibling targets of a multi-
    block plan are still cached: they belong to other requests).
    """
    coalesce: Optional[bool] = None
    use_cache: Optional[bool] = None

    def coalesce_for(self, cfg) -> bool:
        return cfg.coalesce_reads if self.coalesce is None else self.coalesce

    def cache_for(self, cfg) -> bool:
        return (cfg.read_cache_blocks > 0 if self.use_cache is None
                else self.use_cache)
