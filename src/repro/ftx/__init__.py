"""Fault-tolerance layer: CP-LRC erasure-coded state store.

The paper's technique as a first-class framework feature: training state
(checkpoint shards) is striped across hosts with a CP-LRC; node failures are
repaired with the paper's local-first algorithms at local-group bandwidth
instead of k-block global reads.
"""
from .options import RepairOptions, ServeOptions  # noqa: F401
from .stripestore import (NodeState, StripeStore,  # noqa: F401
                          StripeStreamWriter, StoreConfig)
from .checkpoint import (CheckpointConfig, CheckpointFuture,  # noqa: F401
                         CheckpointManager)
from .events import (DataLossEvent, DiskFailEvent, FleetEvent,  # noqa: F401
                     NodeFailEvent, RackFailEvent, RepairDoneEvent,
                     ScrubEvent, SectorErrorEvent)
from .failures import FailureInjector  # noqa: F401
from .fleet import (DegradedReadReport, FleetRepairReport,  # noqa: F401
                    read_report, repair_failed_nodes)
from .pipeline import (EncodePipeline, PipelineResult,  # noqa: F401
                       RepairPipeline, run_double_buffered)
from .rebalance import (Move, RebalanceReport, Rebalancer,  # noqa: F401
                        plan_moves, rebalance)
