"""One fleet-event vocabulary for injection, simulation, and trace replay.

Before PR 8 the repo had two incompatible failure-event shapes: the
closed-form reliability model had none (it never materializes events), and
``ftx/failures.py`` carried an ad-hoc ``FailureEvent`` record that fused a
node failure with its repair into one row. This module defines the single
schema all three consumers now share:

* ``FailureInjector`` (``repro.ftx.failures``) *emits* these events while
  driving a live :class:`~repro.ftx.StripeStore`, and *consumes* them again
  in :meth:`~repro.ftx.failures.FailureInjector.replay` (trace replay
  against a different store/config — the CR-SIM-style workflow).
* The event-driven fleet simulator (``repro.sim``) emits the same types
  from both its batched JAX engine and its pure-Python oracle, which is
  what lets the bit-identity tests compare the two paths event by event.
* Future real-cluster trace ingestion only needs a parser to this schema.

All events are frozen dataclasses with a simulated timestamp ``t`` in
hours. ``to_doc``/``from_doc`` round-trip them through plain dicts (JSON
traces); :func:`event_order` is the canonical sort key — time first, then a
fixed kind rank (failures before repairs at equal times, matching the
simulator's event-selection tie-break), then the unit id.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """Base event: something happened at simulated time ``t`` (hours)."""
    t: float


@dataclasses.dataclass(frozen=True)
class DiskFailEvent(FleetEvent):
    """A disk (the block-holding unit) failed; its blocks are lost until a
    repair rebuilds them. ``node``/``rack`` carry the enclosing units when
    the emitter knows the hierarchy (-1 otherwise)."""
    disk: int = 0
    node: int = -1
    rack: int = -1


@dataclasses.dataclass(frozen=True)
class NodeFailEvent(FleetEvent):
    """A whole node failed — every disk (and block) it holds goes down at
    once. The stripe-store injector emits these (its nodes hold one block
    per stripe); the simulator emits one per correlated node-level burst."""
    node: int = 0
    rack: int = -1


@dataclasses.dataclass(frozen=True)
class RackFailEvent(FleetEvent):
    """A rack-level correlated failure: every node in the rack (a topology
    failure domain) loses its disks simultaneously."""
    rack: int = 0


@dataclasses.dataclass(frozen=True)
class SectorErrorEvent(FleetEvent):
    """A latent sector error surfaced on ``disk``: ``block`` (when known)
    is unreadable until the next scrub or rebuild touches it. These are
    silent — they cost nothing until a repair needs the affected block."""
    disk: int = 0
    block: int = -1


@dataclasses.dataclass(frozen=True)
class ScrubEvent(FleetEvent):
    """A scrub pass completed, clearing latent sector errors on ``disk``
    (``-1`` = a fleet-wide sweep, the simulator's periodic scrub)."""
    disk: int = -1


@dataclasses.dataclass(frozen=True)
class RepairDoneEvent(FleetEvent):
    """A repair finished at ``t``: unit ``unit`` (of ``kind``) is whole
    again. ``started_at`` dates the triggering failure; ``blocks_read`` /
    ``sim_seconds`` carry the real repair pipeline's bandwidth accounting
    when the emitter ran one (the injector does; the simulator carries the
    modelled transfer cost)."""
    unit: int = 0
    kind: str = "node"              # "disk" | "node" | "rack"
    started_at: float = 0.0
    blocks_read: int = 0
    sim_seconds: float = 0.0
    local: bool = True


@dataclasses.dataclass(frozen=True)
class DataLossEvent(FleetEvent):
    """The failure pattern went undecodable: data loss at ``t``. ``blocks``
    is the erased-block pattern that crossed the line (down plus latent)."""
    blocks: tuple[int, ...] = ()


# kind tag <-> class, for serialization and replay dispatch.
EVENT_TYPES: dict[str, type] = {
    "disk_fail": DiskFailEvent,
    "node_fail": NodeFailEvent,
    "rack_fail": RackFailEvent,
    "sector_error": SectorErrorEvent,
    "scrub": ScrubEvent,
    "repair_done": RepairDoneEvent,
    "data_loss": DataLossEvent,
}
_KIND_OF_TYPE = {cls: kind for kind, cls in EVENT_TYPES.items()}
# Sort rank at equal timestamps: failures and sector errors land before the
# repair/scrub that would clear them — the same tie-break the simulator's
# column-ordered argmin applies.
_KIND_RANK = {"disk_fail": 0, "node_fail": 1, "rack_fail": 2,
              "sector_error": 3, "repair_done": 4, "scrub": 5,
              "data_loss": 6}


def kind_of(event: FleetEvent) -> str:
    """The schema tag of ``event`` (``"node_fail"``, ``"repair_done"``...).

    Subclasses report their closest registered ancestor, so the deprecated
    ``FailureInjector`` shim types still classify correctly.
    """
    for cls in type(event).__mro__:
        tag = _KIND_OF_TYPE.get(cls)
        if tag is not None:
            return tag
    raise TypeError(f"not a registered fleet event: {type(event).__name__}")


def event_order(event: FleetEvent) -> tuple:
    """Canonical sort key: ``(t, kind rank, unit id)``."""
    unit = next((getattr(event, f) for f in ("disk", "node", "rack", "unit")
                 if hasattr(event, f)), -1)
    return (event.t, _KIND_RANK[kind_of(event)], unit)


def to_doc(event: FleetEvent) -> dict:
    """Serialize to a plain dict: ``{"event": <schema tag>, **fields}``.

    The discriminator key is ``"event"`` (not ``"kind"``) so it can never
    collide with a field — ``RepairDoneEvent.kind`` names the repaired
    unit's level and must survive the round-trip.
    """
    doc = dataclasses.asdict(event)
    doc["event"] = kind_of(event)
    return doc


def from_doc(doc: dict) -> FleetEvent:
    """Rebuild an event from :func:`to_doc` output (JSON trace rows)."""
    doc = dict(doc)
    kind = doc.pop("event")
    cls = EVENT_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown fleet-event kind {kind!r} "
                         f"(known: {', '.join(EVENT_TYPES)})")
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in doc.items() if k in fields})


def sort_events(events: Iterable[FleetEvent]) -> list[FleetEvent]:
    """Events in canonical order (stable under :func:`event_order`)."""
    return sorted(events, key=event_order)


def dump_trace(events: Iterable[FleetEvent], path) -> None:
    """Write an event trace as a JSON file, canonically ordered.

    The on-disk shape is ``{"events": [to_doc(e), ...]}`` with sorted
    dict keys and a fixed indent — byte-stable for a given event list, so
    committed trace fixtures diff cleanly and a dump->load->dump cycle is
    the identity (the golden-file property the replay tests pin).
    """
    import json
    from pathlib import Path

    docs = [to_doc(e) for e in sort_events(events)]
    Path(path).write_text(
        json.dumps({"events": docs}, indent=2, sort_keys=True) + "\n")


def load_trace(path) -> list[FleetEvent]:
    """Read a :func:`dump_trace` file back into canonically ordered events.

    Accepts the ``{"events": [...]}`` envelope or a bare JSON list of
    event docs (hand-written fixtures)."""
    import json
    from pathlib import Path

    doc = json.loads(Path(path).read_text())
    rows = doc["events"] if isinstance(doc, dict) else doc
    return sort_events(from_doc(r) for r in rows)
