"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].
128 experts shard cleanly over the model axis; attention heads (56) are not
divisible by 16 and replicate (see DESIGN.md §5). Dense-residual FFN runs in
parallel with the MoE on every layer (Arctic's dense+MoE hybrid)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, act="swiglu",
    num_experts=128, experts_per_tok=2, moe_d_ff=4864, dense_residual=True,
    moe_group_size=1024, fsdp_params=True,
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, act="swiglu",
    num_experts=8, experts_per_tok=2, moe_d_ff=128, dense_residual=True,
    moe_group_size=64,
    capacity_factor=8.0,
)
