"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]. Experts (8) are not
divisible by the model axis (16): the sharding layer automatically falls
back to tensor-parallel expert FFNs (32768/16) — see repro.dist.sharding."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, act="swiglu",  # GeGLU-gated experts: 3
    # matrices per expert -> 8*3*(6144*32768)*64 = 309B + attn = 314B total
    num_experts=8, experts_per_tok=2, moe_d_ff=32768,
    moe_group_size=4096, fsdp_params=True,
)

SMOKE = ModelConfig(
    name="grok1-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, act="gelu",
    num_experts=4, experts_per_tok=2, moe_d_ff=256, moe_group_size=64,
    capacity_factor=8.0,
)
