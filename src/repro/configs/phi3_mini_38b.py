"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU [arXiv:2404.14219]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, act="swiglu",
)

SMOKE = ModelConfig(
    name="phi3-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, act="swiglu",
)
