"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060]. d_ff=0: pure
Mamba2 blocks with no separate MLP."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280, act="swiglu",
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=512, act="swiglu",
    ssm_state=32, ssm_expand=2, ssm_head_dim=32, ssm_chunk=64,
)
