"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention, window 1024, 128k context
[hf:google/gemma-3]. head_dim=240 (d_model/heads)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15360, vocab_size=262144, act="swiglu",
    sliding_window=1024, local_global_period=6, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    num_layers=6, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, act="swiglu",
    sliding_window=32, local_global_period=6,
)
