"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT frontend is a STUB (patch embeddings provided by
input_specs); backbone is the Qwen2-style LM [arXiv:2404.16821; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, qkv_bias=True, act="swiglu",
    frontend="patches", frontend_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, qkv_bias=True, act="swiglu",
    frontend="patches", frontend_tokens=16,
)
