"""Assigned architectures (exact public configs) + shape grid + input specs.

Each ``<arch>.py`` module defines ``CONFIG`` (the full published config) and
``SMOKE`` (a reduced same-family config for CPU smoke tests). The shape grid
is the assignment's four cells; ``long_500k`` is only valid for sub-quadratic
archs (see DESIGN.md §4 and ``LONG_OK``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import ModelApi, build

ARCHS = (
    "internlm2_20b",
    "qwen25_3b",
    "phi3_mini_38b",
    "gemma3_12b",
    "seamless_m4t_medium",
    "internvl2_1b",
    "grok1_314b",
    "arctic_480b",
    "jamba_52b",
    "mamba2_27b",
)

# public ids (with dashes/dots) -> module names
ALIASES = {
    "internlm2-20b": "internlm2_20b",
    "qwen2.5-3b": "qwen25_3b",
    "phi3-mini-3.8b": "phi3_mini_38b",
    "gemma3-12b": "gemma3_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-1b": "internvl2_1b",
    "grok-1-314b": "grok1_314b",
    "arctic-480b": "arctic_480b",
    "jamba-v0.1-52b": "jamba_52b",
    "mamba2-2.7b": "mamba2_27b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs allowed to run long_500k (sub-quadratic / bounded-KV attention)
LONG_OK = {"gemma3_12b", "jamba_52b", "mamba2_27b"}


def resolve(arch: str) -> str:
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if mod not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ALIASES)}")
    return mod


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    module = importlib.import_module(f"repro.configs.{resolve(arch)}")
    return module.SMOKE if smoke else module.CONFIG


def get_model(arch: str, smoke: bool = False) -> ModelApi:
    return build(get_config(arch, smoke))


def cell_valid(arch: str, shape: str) -> tuple[bool, str]:
    """Is (arch x shape) a runnable cell? Returns (ok, reason-if-skip)."""
    mod = resolve(arch)
    if shape == "long_500k" and mod not in LONG_OK:
        return False, ("full-attention arch: 512k decode KV is quadratic-cost "
                       "prefill territory; skipped per assignment spec")
    return True, ""


def input_specs(arch: str, shape: str, smoke: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step function.

    train:   {"batch": {tokens, labels, [frames|prefix_embeds]}}
    prefill: {"batch": {tokens, [frames|prefix_embeds]}}
    decode:  {"caches": ..., "tokens": (B,1), "index": scalar}
    """
    cfg = get_config(arch, smoke)
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    if smoke:
        b, s = max(2, b // 128), min(s, 256)
    i32 = jnp.int32
    out: dict = {}
    if spec.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if spec.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        elif cfg.frontend != "none":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        out["batch"] = batch
    else:
        api = get_model(arch, smoke)
        out["caches"] = api.abstract_caches(b, s)
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        out["index"] = jax.ShapeDtypeStruct((), i32)
    return out
