"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA [arXiv:2403.17297; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544, rope_theta=1e6, act="swiglu",
)

SMOKE = ModelConfig(
    name="internlm2-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, act="swiglu",
)
