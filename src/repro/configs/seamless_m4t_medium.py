"""seamless-m4t-medium [audio]: enc-dec, 12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 [arXiv:2308.11596; hf]. The speech frontend is a
STUB per the assignment: input_specs() provides (B, T, d_model) frame
embeddings directly."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, encoder_layers=12, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, vocab_size=256206, act="gelu",
    frontend="frames",
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=512, act="gelu",
    frontend="frames",
)
