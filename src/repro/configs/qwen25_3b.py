"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA with QKV bias [hf:Qwen/Qwen2.5; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    act="swiglu",
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, qkv_bias=True, act="swiglu",
)
