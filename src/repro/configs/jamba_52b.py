"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887; hf]. Attention sits at index 3 of each 8-layer period;
MoE on odd layers. The Mamba mixer uses our Mamba2/SSD block (DESIGN.md
notes the Mamba-1 -> SSD substitution)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, act="swiglu",
    num_experts=16, experts_per_tok=2, moe_d_ff=14336,
    moe_every=2, moe_offset=1, attn_period=8, attn_offset=3,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    moe_group_size=4096, fsdp_params=True,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, act="swiglu",
    num_experts=4, experts_per_tok=2, moe_d_ff=256,
    moe_every=2, moe_offset=1, attn_period=8, attn_offset=3,
    ssm_state=32, ssm_expand=2, ssm_head_dim=32, ssm_chunk=64,
    moe_group_size=64, capacity_factor=8.0,
)
