"""Counter-based randomness for the fleet simulator (DESIGN.md §12).

Both simulator paths — the batched epoch engine (``repro.sim.engine``) and
the pure-Python event-loop oracle (``repro.sim.oracle``) — must consume
*identical* random bits so their event sequences can be compared bit for
bit. Sequential generators (``np.random.Generator``) make that impossible:
the two paths draw in different orders (the engine batches an epoch's draws
across trials; the oracle runs one trial to completion). The fix is
counter-based addressing: every draw is named by a ``(trial, stream, seq)``
triple and hashed independently through a ``jax.random.fold_in`` chain —
order of evaluation cannot matter because there is no shared cursor.

* ``stream`` identifies the renewal process (disk-``d`` lifetime, node-``i``
  burst, per-disk latent-error arrivals, the repair channel —
  :meth:`repro.sim.units.UnitHierarchy` assigns the ids).
* ``seq`` counts that stream's draws within the trial.

:class:`BitSource` evaluates triples through one jitted vmapped kernel —
the engine hands it a whole epoch's triples at once (padded to power-of-two
buckets so JAX compiles a handful of shapes, not one per epoch); the oracle
asks for one at a time. Identical triple -> identical uint32, so the paths
agree by construction.

The uint32 -> duration transforms run in *numpy float64* and round once to
float32 (the simulator's time grid). Keeping the transform out of JAX makes
it exactly reproducible on any backend/donation configuration; keeping the
grid float32 gives both paths one canonical rounding of every timestamp.
"""
from __future__ import annotations

import functools

import numpy as np

_TRIPLE = np.dtype(np.uint32)


@functools.lru_cache(maxsize=None)
def _bits_kernel():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def kernel(base, trial, stream, seq):
        def one(tr, st, sq):
            k = jax.random.fold_in(base, tr)
            k = jax.random.fold_in(k, st)
            k = jax.random.fold_in(k, sq)
            return jax.random.bits(k, (), jnp.uint32)

        return jax.vmap(one)(trial, stream, seq)

    return kernel


class BitSource:
    """uint32 bits addressed by ``(trial, stream, seq)``, seeded once.

    ``bits(triples)`` evaluates a ``(n, 3)`` uint32 array of triples in one
    device call (padded up to a power of two — the pad lanes are computed
    and discarded, never observed). ``bit1`` is the oracle's scalar
    convenience.
    """

    def __init__(self, seed: int):
        import jax

        self.seed = int(seed)
        self._base = jax.random.PRNGKey(self.seed)
        self._kernel = _bits_kernel()

    def bits(self, triples: np.ndarray) -> np.ndarray:
        triples = np.asarray(triples, dtype=_TRIPLE).reshape(-1, 3)
        n = len(triples)
        if n == 0:
            return np.zeros(0, dtype=np.uint32)
        padded = 1 << (n - 1).bit_length()
        if padded != n:
            triples = np.concatenate(
                [triples, np.zeros((padded - n, 3), dtype=_TRIPLE)])
        out = self._kernel(self._base, triples[:, 0], triples[:, 1],
                           triples[:, 2])
        return np.asarray(out, dtype=np.uint32)[:n]

    def bit1(self, trial: int, stream: int, seq: int) -> np.uint32:
        return self.bits(np.array([[trial, stream, seq]], dtype=_TRIPLE))[0]


def uniform01(bits) -> np.ndarray:
    """uint32 -> open (0, 1) float64: ``(bits + 0.5) * 2^-32``. Strictly
    inside the interval, so ``log1p(-u)`` below is always finite."""
    return (np.asarray(bits, dtype=np.float64) + 0.5) * 2.0 ** -32


def exp_hours(bits, mean_hours: float) -> np.ndarray:
    """Exponential durations with the given mean, rounded once to the
    float32 time grid."""
    u = uniform01(bits)
    return np.float32(np.float64(mean_hours) * -np.log1p(-u))


def weibull_hours(bits, scale_hours: float, shape: float) -> np.ndarray:
    """Weibull durations (inverse-CDF), rounded once to float32.
    ``shape=1`` degenerates to the exponential — the calibration mode the
    closed-form Markov chain assumes."""
    u = uniform01(bits)
    dur = np.float64(scale_hours) * (-np.log1p(-u)) ** (1.0 / np.float64(shape))
    return np.float32(dur)


def weibull_scale(mean_hours: float, shape: float) -> float:
    """The Weibull scale whose mean is ``mean_hours`` at ``shape``:
    ``scale = mean / Gamma(1 + 1/shape)``."""
    from math import gamma

    return float(mean_hours) / gamma(1.0 + 1.0 / float(shape))


def later(t, dur) -> np.float32:
    """``t + dur`` on the float32 time grid (single canonical rounding —
    both simulator paths schedule every event through this)."""
    return np.float32(np.float32(t) + np.float32(dur))
