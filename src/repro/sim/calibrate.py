"""Close the loop: measured repair-pipeline throughput -> simulator rates.

The closed-form chain and the simulator both turn a repair plan's
block-read cost into a vulnerability window through
:func:`repro.core.reliability.repair_hours`, whose ``bandwidth_gbps`` is a
*assumed* constant. This module replaces the assumption with a
measurement: run the real repair pipeline (reads -> batched decode ->
write-back, with whatever pipelining/scheduling the store is configured
for) on real data, take the store's byte/latency telemetry, and hand the
*effective* repair bandwidth back to :class:`ReliabilityParams`. Faster
pipelines then shrink every simulated vulnerability window — the
repair-bandwidth feedback the paper's reliability argument rests on.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional, Union

import numpy as np

from repro.core.reliability import ReliabilityParams
from repro.ftx.options import RepairOptions
from repro.ftx.stripestore import StoreConfig, StripeStore

Telemetry = Union[dict, object]


def _field(tele: Telemetry, name: str):
    return tele[name] if isinstance(tele, dict) else getattr(tele, name)


def measured_bandwidth(tele: Telemetry) -> float:
    """Effective repair throughput (Gbps) from repair telemetry — the
    ``bytes_read``/``sim_seconds`` pair every repair path reports
    (``StripeStore.repair_all``'s diff dict, a ``FleetRepairReport``, or a
    ``RepairDoneEvent``)."""
    bytes_read = float(_field(tele, "bytes_read"))
    sim_seconds = float(_field(tele, "sim_seconds"))
    if sim_seconds <= 0:
        raise ValueError("telemetry has no simulated transfer time "
                         "(sim_seconds <= 0); run a repair first")
    return bytes_read * 8.0 / 1e9 / sim_seconds


def calibrated(params: Optional[ReliabilityParams],
               tele_or_gbps: Union[Telemetry, float]) -> ReliabilityParams:
    """``ReliabilityParams`` with ``bandwidth_gbps`` replaced by a measured
    value (a float) or by :func:`measured_bandwidth` of repair telemetry."""
    base = params or ReliabilityParams()
    gbps = (float(tele_or_gbps) if isinstance(tele_or_gbps, (int, float))
            else measured_bandwidth(tele_or_gbps))
    return dataclasses.replace(base, bandwidth_gbps=gbps)


def measure_repair_bandwidth(root: Path, cfg: StoreConfig, *,
                             objects: int = 4, object_bytes: int = 1 << 14,
                             seed: int = 0,
                             options: Optional[RepairOptions] = None
                             ) -> dict:
    """Run one real single-node repair and report its effective bandwidth.

    Builds a store under ``root``, fills it with ``objects`` random
    objects, fails the node holding stripe 0's first data block, repairs
    through the store's batched engine (``options`` selects pipelining /
    scheduling), and returns the repair telemetry diff augmented with
    ``gbps`` — ready for :func:`calibrated`.
    """
    store = StripeStore(Path(root) / "calib", cfg)
    rng = np.random.default_rng(seed)
    for i in range(objects):
        store.put(f"calib{i}", rng.integers(0, 256, object_bytes,
                                            dtype=np.uint8).tobytes())
    store.seal()
    store.fail_node(store.stripes[0].node_of_block[0])
    tele = store.repair_all(options=options or RepairOptions())
    tele = dict(tele)
    tele["gbps"] = measured_bandwidth(tele)
    return tele
