"""Batched event-driven fleet reliability simulator (DESIGN.md §12).

Simulates many independent trials of one stripe's disk fleet — Weibull disk
lifetimes, correlated node/rack bursts, latent sector errors with periodic
scrubbing, and one-at-a-time repairs whose mean duration comes from the
*real* repair cost model (``StripeModel.tau_hours`` — planner plan costs or
the Markov chain's average profile, through the shared
``reliability.repair_hours``) — and estimates MTTDL from observed losses.

The vectorization strategy is **trials in lockstep**: every trial owns an
independent simulated clock, so there is no global event ordering to
respect — each *epoch* processes exactly one event per still-active trial:

1. **select** (JAX, jitted): stack each trial's per-process next-event
   times into a ``(T, 6)`` candidate matrix — disk-fail, node-burst,
   rack-burst, latent-error, repair-done, scrub — and take a masked
   min/argmin. Ties break by fixed column priority then lowest unit id
   (``argmin``'s first-index rule), mirroring
   ``repro.ftx.events.event_order``.
2. **decide** (host): outcome logic — accept/reject/loss, decodability via
   the memoized ``StripeModel`` — touches dict caches and frozensets, so
   it stays in Python; crucially no outcome depends on a random value, so
   every draw the epoch needs is known *before* drawing.
3. **draw** (JAX, jitted): the epoch's draws across all trials evaluate as
   one vmapped counter-based batch (``repro.sim.rng``), padded to
   power-of-two buckets.
4. **apply** (host, numpy): fill the drawn durations back into the
   per-trial schedule on the float32 time grid.

Because every random value is addressed by ``(trial, stream, seq)`` and
every timestamp is rounded once on the shared float32 grid, this engine is
**bit-identical** to the pure-Python per-trial oracle
(``repro.sim.oracle``) — same events, same times, same losses — which is
what the property tests pin.

Model semantics (shared with the oracle):

* ``model="paper"``: a *single-disk* failure that would make the erased
  pattern undecodable at ``f <= p + r`` is **rejected** — the disk draws a
  fresh lifetime and stays up. This is thinning: the accepted failure rate
  from state ``f`` is ``(n-f) * lambda * (1 - q_{f+1})``, exactly the
  paper-model Markov chain's slowed descent. Loss happens when failures
  exceed ``p + r``. Correlated bursts and latent errors (not part of the
  chain) are always strict.
* ``model="strict"``: the failure stands; the first undecodable pattern is
  data loss — the rank-faithful semantics.
* Repairs fix one disk at a time (lowest id first), exponential duration
  with mean ``tau(down)``; any change of the down-set *redraws* the
  completion (memoryless, so the closed-form chain's repair rates are
  reproduced exactly when ``cost_model="average"``).
* A latent sector error marks a live block unreadable (silent until
  counted against decodability); a scrub clears all of them; rebuilding a
  disk clears its latent error.

MTTDL is the censoring-correct exponential MLE: total observed fleet-hours
over observed losses.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import numpy as np

from repro.core.reliability import HOURS_PER_YEAR
from repro.core.schemes import LRCScheme
from repro.dist.topology import Topology
from repro.ftx.events import (DataLossEvent, DiskFailEvent, FleetEvent,
                              NodeFailEvent, RackFailEvent, RepairDoneEvent,
                              ScrubEvent, SectorErrorEvent)

from .rng import BitSource, exp_hours, later, weibull_hours
from .units import SimParams, StripeModel, UnitHierarchy

_INF = np.float32(np.inf)

# Candidate-column priority (ties break left to right, matching the kind
# ranks in repro.ftx.events): disk fail, node burst, rack burst, latent
# error, repair done, scrub.
COL_DISK, COL_NODE, COL_RACK, COL_LSE, COL_REPAIR, COL_SCRUB = range(6)


@functools.lru_cache(maxsize=None)
def _select_kernel():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def select(nf, nn, nr, nl, rt, ns):
        cols = jnp.stack([nf.min(1), nn.min(1), nr.min(1), nl.min(1),
                          rt, ns], axis=1)
        col = jnp.argmin(cols, axis=1)
        units = jnp.stack([jnp.argmin(nf, 1), jnp.argmin(nn, 1),
                           jnp.argmin(nr, 1), jnp.argmin(nl, 1),
                           jnp.zeros_like(col), jnp.zeros_like(col)], axis=1)
        unit = jnp.take_along_axis(units, col[:, None], axis=1)[:, 0]
        return jnp.min(cols, axis=1), col, unit

    return select


@dataclasses.dataclass
class SimResult:
    """One simulation run's accounting."""
    scheme: str
    trials: int
    horizon_hours: float
    seed: int
    losses: int
    observed_hours: float          # summed exposure, censoring-aware
    loss_times: list[float]
    events: int                    # events processed (one per active trial
    #                                per epoch, no-ops included)
    epochs: int                    # batched selection rounds executed
    rejected: int                  # paper-model thinned disk failures
    counts: dict[str, int]         # processed events by kind
    wall_seconds: float
    event_log: Optional[list[list[FleetEvent]]] = None  # per trial

    @property
    def mttdl_hours(self) -> float:
        """Censoring-correct exponential MLE: exposure over losses."""
        return (self.observed_hours / self.losses if self.losses
                else float("inf"))

    @property
    def mttdl_years(self) -> float:
        return self.mttdl_hours / HOURS_PER_YEAR

    @property
    def event_parallelism(self) -> float:
        """Mean events retired per batched epoch — how much lockstep
        batching amortizes each selection/draw launch over (1.0 = a pure
        sequential event loop). Deterministic given (config, seed)."""
        return self.events / max(1, self.epochs)


class _Draw:
    """One pending draw order: filled after the epoch's batched RNG call."""
    __slots__ = ("trial", "stream", "seq", "kind", "mean", "tt", "slot")

    def __init__(self, trial, stream, seq, kind, mean, tt, slot):
        self.trial = trial
        self.stream = stream
        self.seq = seq
        self.kind = kind          # "weibull" | "exp"
        self.mean = mean          # exp mean hours (weibull uses params)
        self.tt = tt              # event time the duration adds onto
        self.slot = slot          # ("fail", d) | ("node", i) | ("rack", j)
        #                           | ("lse", d) | ("repair",)


def simulate(scheme: LRCScheme, params: SimParams, *, trials: int,
             horizon_hours: float, seed: int = 0,
             hierarchy: Optional[UnitHierarchy] = None,
             topology: Optional[Topology] = None,
             policy: str = "contiguous",
             record_events: bool = False) -> SimResult:
    """Run ``trials`` lockstep trials to ``horizon_hours`` (or loss)."""
    hier = hierarchy or UnitHierarchy.from_topology(scheme.n, topology,
                                                   policy)
    if hier.num_disks != scheme.n:
        raise ValueError(f"hierarchy has {hier.num_disks} disks, "
                         f"scheme needs n={scheme.n}")
    model = StripeModel(scheme, params)
    src = BitSource(seed)
    select = _select_kernel()
    t_wall = time.perf_counter()

    T, D = int(trials), hier.num_disks
    N, R = max(1, hier.num_nodes), max(1, hier.num_racks)
    horizon = np.float32(horizon_hours)
    p = params

    # -------------------------------------------------------------- state
    next_fail = np.full((T, D), _INF, np.float32)
    next_node = np.full((T, N), _INF, np.float32)
    next_rack = np.full((T, R), _INF, np.float32)
    next_lse = np.full((T, D), _INF, np.float32)
    repair_t = np.full(T, _INF, np.float32)
    repair_sched = np.zeros(T, np.float32)
    repair_cost = np.zeros(T, np.float64)
    next_scrub = np.full(T, np.float32(p.scrub_hours) if p.scrub_hours > 0
                         else _INF, np.float32)
    down = [set() for _ in range(T)]
    lse = [set() for _ in range(T)]
    seq = [dict() for _ in range(T)]        # stream id -> draws consumed
    active = np.ones(T, bool)
    observed = np.zeros(T, np.float64)
    loss_times: list[float] = []
    log: Optional[list[list[FleetEvent]]] = \
        [[] for _ in range(T)] if record_events else None
    counts = {"disk_fail": 0, "disk_fail_rejected": 0, "node_fail": 0,
              "rack_fail": 0, "sector_error": 0, "scrub": 0,
              "repair_done": 0, "data_loss": 0, "noop": 0}

    def take(trial: int, stream: int) -> int:
        s = seq[trial]
        got = s.get(stream, 0)
        s[stream] = got + 1
        return got

    # Initial lifetimes (all disks) and burst/error arrivals, one batch.
    init: list[_Draw] = []
    for trial in range(T):
        for d in range(D):
            st = hier.stream_disk_fail(d)
            init.append(_Draw(trial, st, take(trial, st), "weibull", 0.0,
                              np.float32(0.0), ("fail", d)))
        if p.node_burst_hours > 0:
            for i in range(hier.num_nodes):
                st = hier.stream_node_fail(i)
                init.append(_Draw(trial, st, take(trial, st), "exp",
                                  p.node_burst_hours, np.float32(0.0),
                                  ("node", i)))
        if p.rack_burst_hours > 0:
            for j in range(hier.num_racks):
                st = hier.stream_rack_fail(j)
                init.append(_Draw(trial, st, take(trial, st), "exp",
                                  p.rack_burst_hours, np.float32(0.0),
                                  ("rack", j)))
        if p.lse_hours > 0:
            for d in range(D):
                st = hier.stream_lse(d)
                init.append(_Draw(trial, st, take(trial, st), "exp",
                                  p.lse_hours, np.float32(0.0), ("lse", d)))

    def settle(orders: list[_Draw]) -> None:
        """Batched RNG for the epoch's orders, then fill the schedule."""
        if not orders:
            return
        triples = np.array([[o.trial, o.stream, o.seq] for o in orders],
                           np.uint32)
        bits = src.bits(triples)
        for o, b in zip(orders, bits):
            dur = (weibull_hours(b, p.weibull_scale_hours, p.weibull_shape)
                   if o.kind == "weibull" else exp_hours(b, o.mean))
            at = later(o.tt, dur)
            kind, tr = o.slot[0], o.trial
            if kind == "fail":
                next_fail[tr, o.slot[1]] = at
            elif kind == "node":
                next_node[tr, o.slot[1]] = at
            elif kind == "rack":
                next_rack[tr, o.slot[1]] = at
            elif kind == "lse":
                next_lse[tr, o.slot[1]] = at
            else:
                repair_t[tr] = at
                repair_sched[tr] = o.tt

    settle(init)

    def emit(trial: int, ev: FleetEvent) -> None:
        if log is not None:
            log[trial].append(ev)

    def retire(trial: int, hours: float) -> None:
        active[trial] = False
        observed[trial] = hours
        next_fail[trial] = next_node[trial] = _INF
        next_rack[trial] = next_lse[trial] = _INF
        repair_t[trial] = next_scrub[trial] = _INF

    def lose(trial: int, tt: np.float32, mask: frozenset[int]) -> None:
        counts["data_loss"] += 1
        loss_times.append(float(tt))
        emit(trial, DataLossEvent(t=float(tt), blocks=tuple(sorted(mask))))
        retire(trial, float(tt))

    def order_repair(trial: int, tt: np.float32,
                     orders: list[_Draw]) -> None:
        """(Re)draw the in-flight repair for the current down-set."""
        pattern = frozenset(down[trial])
        tau = model.tau_hours(pattern)
        repair_cost[trial] = model.cost_blocks(pattern)
        orders.append(_Draw(trial, hier.stream_repair,
                            take(trial, hier.stream_repair), "exp", tau, tt,
                            ("repair",)))

    # --------------------------------------------------------------- loop
    events = epochs = 0
    while active.any():
        tmin, col, unit = (np.asarray(a) for a in select(
            next_fail, next_node, next_rack, next_lse, repair_t, next_scrub))
        epochs += 1
        orders: list[_Draw] = []
        for trial in np.flatnonzero(active):
            trial = int(trial)
            tt = np.float32(tmin[trial])
            if not tt < horizon:          # censored (inf-only schedules too)
                retire(trial, float(horizon))
                continue
            events += 1
            c, u = int(col[trial]), int(unit[trial])
            dn, er = down[trial], lse[trial]
            if c == COL_DISK:
                mask = frozenset(dn | er | {u})
                f_after = len(dn) + 1
                if f_after > model.fmax:
                    counts["disk_fail"] += 1
                    emit(trial, DiskFailEvent(
                        t=float(tt), disk=u, node=hier.node_of_disk[u],
                        rack=hier.rack_of_node[hier.node_of_disk[u]]))
                    lose(trial, tt, mask)
                    continue
                if not model.decodable(mask) and p.model == "paper":
                    # Thinning: the failure is rejected; fresh lifetime.
                    counts["disk_fail_rejected"] += 1
                    st = hier.stream_disk_fail(u)
                    orders.append(_Draw(trial, st, take(trial, st),
                                        "weibull", 0.0, tt, ("fail", u)))
                    continue
                counts["disk_fail"] += 1
                emit(trial, DiskFailEvent(
                    t=float(tt), disk=u, node=hier.node_of_disk[u],
                    rack=hier.rack_of_node[hier.node_of_disk[u]]))
                if not model.decodable(mask):      # strict: loss stands
                    lose(trial, tt, mask)
                    continue
                dn.add(u)
                next_fail[trial, u] = _INF
                order_repair(trial, tt, orders)
            elif c in (COL_NODE, COL_RACK):
                if c == COL_NODE:
                    st = hier.stream_node_fail(u)
                    mean, slot = p.node_burst_hours, ("node", u)
                    burst = hier.disks_of_node(u)
                else:
                    st = hier.stream_rack_fail(u)
                    mean, slot = p.rack_burst_hours, ("rack", u)
                    burst = hier.disks_of_rack(u)
                orders.append(_Draw(trial, st, take(trial, st), "exp", mean,
                                    tt, slot))
                newly = [d for d in burst if d not in dn]
                if not newly:
                    counts["noop"] += 1
                    continue
                counts["node_fail" if c == COL_NODE else "rack_fail"] += 1
                emit(trial, NodeFailEvent(
                    t=float(tt), node=u,
                    rack=hier.rack_of_node[u]) if c == COL_NODE
                    else RackFailEvent(t=float(tt), rack=u))
                dn.update(newly)
                next_fail[trial, newly] = _INF
                mask = frozenset(dn | er)
                if not model.decodable(frozenset(dn)) or \
                        not model.decodable(mask):
                    lose(trial, tt, mask)
                    continue
                order_repair(trial, tt, orders)
            elif c == COL_LSE:
                st = hier.stream_lse(u)
                orders.append(_Draw(trial, st, take(trial, st), "exp",
                                    p.lse_hours, tt, ("lse", u)))
                if u in dn or u in er:
                    counts["noop"] += 1
                    continue
                counts["sector_error"] += 1
                er.add(u)
                emit(trial, SectorErrorEvent(t=float(tt), disk=u))
                mask = frozenset(dn | er)
                if not model.decodable(mask):
                    lose(trial, tt, mask)
            elif c == COL_REPAIR:
                target = min(dn)
                counts["repair_done"] += 1
                emit(trial, RepairDoneEvent(
                    t=float(tt), unit=target, kind="disk",
                    started_at=float(repair_sched[trial]),
                    blocks_read=int(round(repair_cost[trial])),
                    sim_seconds=float((tt - repair_sched[trial]) * 3600.0),
                    local=repair_cost[trial] < scheme.k))
                dn.discard(target)
                er.discard(target)
                st = hier.stream_disk_fail(target)
                orders.append(_Draw(trial, st, take(trial, st), "weibull",
                                    0.0, tt, ("fail", target)))
                if dn:
                    order_repair(trial, tt, orders)
                else:
                    repair_t[trial] = _INF
            else:                          # COL_SCRUB
                counts["scrub"] += 1
                er.clear()
                emit(trial, ScrubEvent(t=float(tt), disk=-1))
                next_scrub[trial] = later(tt, np.float32(p.scrub_hours))
        settle(orders)

    return SimResult(
        scheme=getattr(scheme, "name", scheme.__class__.__name__),
        trials=T, horizon_hours=float(horizon_hours), seed=seed,
        losses=counts["data_loss"], observed_hours=float(observed.sum()),
        loss_times=loss_times, events=events, epochs=epochs,
        rejected=counts["disk_fail_rejected"], counts=counts,
        wall_seconds=time.perf_counter() - t_wall, event_log=log)
