"""Pure-Python event-loop oracle for the batched simulator.

Runs each trial to completion with an ordinary one-event-at-a-time loop —
no batching, no lockstep — consuming the *same* counter-addressed random
bits (``repro.sim.rng``) and the same float32 time grid as
``repro.sim.engine``. Because a draw's identity is its
``(trial, stream, seq)`` triple and every timestamp rounds through
``later``, the two paths must produce bit-identical event sequences;
``tests/test_sim.py`` pins that on small horizons with every failure
process switched on. Keep any semantic change mirrored in both files.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.schemes import LRCScheme
from repro.dist.topology import Topology
from repro.ftx.events import (DataLossEvent, DiskFailEvent, FleetEvent,
                              NodeFailEvent, RackFailEvent, RepairDoneEvent,
                              ScrubEvent, SectorErrorEvent)

from .engine import (COL_DISK, COL_LSE, COL_NODE, COL_RACK, COL_REPAIR,
                     COL_SCRUB, SimResult)
from .rng import BitSource, exp_hours, later, weibull_hours
from .units import SimParams, StripeModel, UnitHierarchy

_INF = np.float32(np.inf)


def simulate_oracle(scheme: LRCScheme, params: SimParams, *, trials: int,
                    horizon_hours: float, seed: int = 0,
                    hierarchy: Optional[UnitHierarchy] = None,
                    topology: Optional[Topology] = None,
                    policy: str = "contiguous",
                    record_events: bool = False) -> SimResult:
    """Sequential reference run; same signature and result as
    :func:`repro.sim.engine.simulate`."""
    hier = hierarchy or UnitHierarchy.from_topology(scheme.n, topology,
                                                    policy)
    if hier.num_disks != scheme.n:
        raise ValueError(f"hierarchy has {hier.num_disks} disks, "
                         f"scheme needs n={scheme.n}")
    model = StripeModel(scheme, params)
    src = BitSource(seed)
    t_wall = time.perf_counter()
    horizon = np.float32(horizon_hours)
    p = params
    D, N, R = hier.num_disks, hier.num_nodes, hier.num_racks

    counts = {"disk_fail": 0, "disk_fail_rejected": 0, "node_fail": 0,
              "rack_fail": 0, "sector_error": 0, "scrub": 0,
              "repair_done": 0, "data_loss": 0, "noop": 0}
    observed = 0.0
    loss_times: list[float] = []
    log: Optional[list[list[FleetEvent]]] = \
        [[] for _ in range(trials)] if record_events else None
    events = 0

    for trial in range(trials):
        seq: dict[int, int] = {}

        def take(stream: int) -> int:
            got = seq.get(stream, 0)
            seq[stream] = got + 1
            return got

        def lifetime(disk: int, tt) -> np.float32:
            st = hier.stream_disk_fail(disk)
            b = src.bit1(trial, st, take(st))
            return later(tt, weibull_hours(b, p.weibull_scale_hours,
                                           p.weibull_shape))

        def exp_at(stream: int, mean: float, tt) -> np.float32:
            b = src.bit1(trial, stream, take(stream))
            return later(tt, exp_hours(b, mean))

        next_fail = [lifetime(d, np.float32(0.0)) for d in range(D)]
        next_node = [exp_at(hier.stream_node_fail(i), p.node_burst_hours,
                            np.float32(0.0)) if p.node_burst_hours > 0
                     else _INF for i in range(N)]
        next_rack = [exp_at(hier.stream_rack_fail(j), p.rack_burst_hours,
                            np.float32(0.0)) if p.rack_burst_hours > 0
                     else _INF for j in range(R)]
        next_lse = [exp_at(hier.stream_lse(d), p.lse_hours, np.float32(0.0))
                    if p.lse_hours > 0 else _INF for d in range(D)]
        repair_t = _INF
        repair_sched = np.float32(0.0)
        repair_cost = 0.0
        next_scrub = (np.float32(p.scrub_hours) if p.scrub_hours > 0
                      else _INF)
        down: set[int] = set()
        er: set[int] = set()

        def emit(ev: FleetEvent) -> None:
            if log is not None:
                log[trial].append(ev)

        def order_repair(tt) -> None:
            nonlocal repair_t, repair_sched, repair_cost
            pattern = frozenset(down)
            repair_cost = model.cost_blocks(pattern)
            repair_t = exp_at(hier.stream_repair,
                              model.tau_hours(pattern), tt)
            repair_sched = tt

        while True:
            # Same tie-breaks as the engine's argmin: column priority,
            # then lowest unit id (min() returns the first minimum).
            picks = []
            for arr in (next_fail, next_node, next_rack, next_lse):
                u = min(range(len(arr)), key=arr.__getitem__)
                picks.append((arr[u], u))
            picks.append((repair_t, 0))
            picks.append((next_scrub, 0))
            tt = min(t for t, _ in picks)
            if not tt < horizon:                       # censored
                observed += float(horizon)
                break
            c = next(i for i, (t, _) in enumerate(picks) if t == tt)
            u = picks[c][1]
            events += 1
            lost = False
            if c == COL_DISK:
                mask = frozenset(down | er | {u})
                if len(down) + 1 > model.fmax:
                    counts["disk_fail"] += 1
                    emit(DiskFailEvent(
                        t=float(tt), disk=u, node=hier.node_of_disk[u],
                        rack=hier.rack_of_node[hier.node_of_disk[u]]))
                    lost = True
                elif not model.decodable(mask) and p.model == "paper":
                    counts["disk_fail_rejected"] += 1
                    next_fail[u] = lifetime(u, tt)
                else:
                    counts["disk_fail"] += 1
                    emit(DiskFailEvent(
                        t=float(tt), disk=u, node=hier.node_of_disk[u],
                        rack=hier.rack_of_node[hier.node_of_disk[u]]))
                    if not model.decodable(mask):      # strict: stands
                        lost = True
                    else:
                        down.add(u)
                        next_fail[u] = _INF
                        order_repair(tt)
            elif c in (COL_NODE, COL_RACK):
                if c == COL_NODE:
                    next_node[u] = exp_at(hier.stream_node_fail(u),
                                          p.node_burst_hours, tt)
                    burst = hier.disks_of_node(u)
                else:
                    next_rack[u] = exp_at(hier.stream_rack_fail(u),
                                          p.rack_burst_hours, tt)
                    burst = hier.disks_of_rack(u)
                newly = [d for d in burst if d not in down]
                if not newly:
                    counts["noop"] += 1
                else:
                    counts["node_fail" if c == COL_NODE
                           else "rack_fail"] += 1
                    emit(NodeFailEvent(t=float(tt), node=u,
                                       rack=hier.rack_of_node[u])
                         if c == COL_NODE
                         else RackFailEvent(t=float(tt), rack=u))
                    down.update(newly)
                    for d in newly:
                        next_fail[d] = _INF
                    mask = frozenset(down | er)
                    if not model.decodable(frozenset(down)) or \
                            not model.decodable(mask):
                        lost = True
                    else:
                        order_repair(tt)
            elif c == COL_LSE:
                next_lse[u] = exp_at(hier.stream_lse(u), p.lse_hours, tt)
                if u in down or u in er:
                    counts["noop"] += 1
                else:
                    counts["sector_error"] += 1
                    er.add(u)
                    emit(SectorErrorEvent(t=float(tt), disk=u))
                    mask = frozenset(down | er)
                    if not model.decodable(mask):
                        lost = True
            elif c == COL_REPAIR:
                target = min(down)
                counts["repair_done"] += 1
                emit(RepairDoneEvent(
                    t=float(tt), unit=target, kind="disk",
                    started_at=float(repair_sched),
                    blocks_read=int(round(repair_cost)),
                    sim_seconds=float((tt - repair_sched) * 3600.0),
                    local=repair_cost < scheme.k))
                down.discard(target)
                er.discard(target)
                next_fail[target] = lifetime(target, tt)
                if down:
                    order_repair(tt)
                else:
                    repair_t = _INF
            else:                                      # COL_SCRUB
                counts["scrub"] += 1
                er.clear()
                emit(ScrubEvent(t=float(tt), disk=-1))
                next_scrub = later(tt, np.float32(p.scrub_hours))
            if lost:
                counts["data_loss"] += 1
                loss_times.append(float(tt))
                mask = frozenset(down | er | ({u} if c == COL_DISK else
                                              set()))
                emit(DataLossEvent(t=float(tt),
                                   blocks=tuple(sorted(mask))))
                observed += float(tt)
                break

    return SimResult(
        scheme=getattr(scheme, "name", scheme.__class__.__name__),
        trials=trials, horizon_hours=float(horizon_hours), seed=seed,
        losses=counts["data_loss"], observed_hours=observed,
        loss_times=loss_times, events=events, epochs=events,
        rejected=counts["disk_fail_rejected"], counts=counts,
        wall_seconds=time.perf_counter() - t_wall, event_log=log)
