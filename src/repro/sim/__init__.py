"""Event-driven fleet reliability simulator (DESIGN.md §12).

``simulate`` is the batched trials-in-lockstep engine (JAX selection +
counter-based RNG); ``simulate_oracle`` is the bit-identical pure-Python
reference loop; ``units``/``rng`` hold the shared geometry and randomness;
``calibrate`` feeds measured repair-pipeline throughput back into the
failure model.
"""
from .calibrate import calibrated, measure_repair_bandwidth, \
    measured_bandwidth
from .engine import SimResult, simulate
from .oracle import simulate_oracle
from .rng import BitSource, later, weibull_scale
from .units import SimParams, StripeModel, UnitHierarchy

__all__ = [
    "BitSource", "SimParams", "SimResult", "StripeModel", "UnitHierarchy",
    "calibrated", "later", "measure_repair_bandwidth", "measured_bandwidth",
    "simulate", "simulate_oracle", "weibull_scale",
]
