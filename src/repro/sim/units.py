"""Unit hierarchy + failure/repair model shared by both simulator paths.

The simulator models one stripe's ``n`` blocks as ``n`` *disks* — the
stateful failure unit — placed onto storage nodes by the same
block-placement machinery the stripe store uses
(:func:`repro.dist.topology.place_stripe`), with racks given by the
topology's failure domains. Node and rack failures are *correlated bursts*:
every disk the unit holds goes down at once, which is exactly the
correlated-failure effect placement policies exist to bound (XORing
Elephants' copyset argument) and closed-form per-disk chains cannot see.

:class:`StripeModel` packages what both the batched engine and the oracle
need to agree on, bit for bit:

* ``decodable(mask)`` — memoized rank check over the erased-block pattern
  (down disks plus latent-error blocks), through the same
  ``LRCScheme.decodable`` the repair planner trusts;
* ``cost_blocks(mask)`` — blocks read to repair the pattern, either the
  closed-form chain's per-count average profile
  (:func:`repro.core.reliability.repair_cost_profile`, making the simulator
  comparable to the chain *by construction*) or the actual
  ``RepairPlanner``/``multi_repair_plan`` cost of the concrete pattern
  (the real repair pipeline in the loop: cheaper CP-LRC plans directly
  shrink the vulnerability window);
* ``tau_hours(mask)`` — mean repair duration via the *shared*
  :func:`repro.core.reliability.repair_hours` model, with the
  ``ReliabilityParams`` bandwidth optionally replaced by the measured
  pipeline throughput (:func:`repro.sim.calibrate.measured_bandwidth`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.reliability import (ReliabilityParams, repair_cost_profile,
                                    repair_hours)
from repro.core.repair import multi_repair_plan
from repro.core.schemes import LRCScheme
from repro.dist.topology import Topology, place_stripe

from .rng import weibull_scale

COST_MODELS = ("average", "planner")
MODELS = ("paper", "strict")


@dataclasses.dataclass(frozen=True)
class UnitHierarchy:
    """disk -> node -> rack geometry, plus the RNG stream-id layout.

    Streams (see ``repro.sim.rng``): disk-``d`` lifetimes are stream ``d``,
    node bursts ``D + i``, rack bursts ``D + N + j``, per-disk latent-error
    arrivals ``D + N + R + d``, and the repair channel is the single last
    stream ``2D + N + R``. Both simulator paths draw from these ids, so a
    draw's identity never depends on event order.
    """
    node_of_disk: tuple[int, ...]
    rack_of_node: tuple[int, ...]

    @classmethod
    def from_topology(cls, n: int, topo: Optional[Topology] = None,
                      policy: str = "contiguous", sid: int = 0
                      ) -> "UnitHierarchy":
        """Place ``n`` disks (stripe blocks) onto ``topo``'s nodes under a
        block-placement policy; racks are the topology's failure domains.
        Default: one node per disk, one rack (no correlated bursts)."""
        topo = topo or Topology(num_nodes=n)
        placed = place_stripe(policy, topo, sid, n)
        # Renumber to the nodes actually used, keeping topology order, so
        # burst streams stay dense no matter how wide the fleet is.
        used = sorted(set(placed))
        node_id = {node: i for i, node in enumerate(used)}
        return cls(node_of_disk=tuple(node_id[node] for node in placed),
                   rack_of_node=tuple(topo.rack_of(node) for node in used))

    @property
    def num_disks(self) -> int:
        return len(self.node_of_disk)

    @property
    def num_nodes(self) -> int:
        return len(self.rack_of_node)

    @property
    def num_racks(self) -> int:
        return max(self.rack_of_node) + 1 if self.rack_of_node else 0

    def disks_of_node(self, node: int) -> tuple[int, ...]:
        return tuple(d for d, nd in enumerate(self.node_of_disk)
                     if nd == node)

    def disks_of_rack(self, rack: int) -> tuple[int, ...]:
        return tuple(d for d, nd in enumerate(self.node_of_disk)
                     if self.rack_of_node[nd] == rack)

    # ------------------------------------------------------ stream layout
    def stream_disk_fail(self, disk: int) -> int:
        return disk

    def stream_node_fail(self, node: int) -> int:
        return self.num_disks + node

    def stream_rack_fail(self, rack: int) -> int:
        return self.num_disks + self.num_nodes + rack

    def stream_lse(self, disk: int) -> int:
        return self.num_disks + self.num_nodes + self.num_racks + disk

    @property
    def stream_repair(self) -> int:
        return 2 * self.num_disks + self.num_nodes + self.num_racks


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Failure/repair processes of one simulated fleet.

    All rates are per the *simulated* clock (hours). ``0`` disables a
    process. With ``weibull_shape=1``, bursts/LSE off, and
    ``cost_model="average"``, the simulator is distribution-identical to
    ``core/reliability.py``'s Markov chain — the cross-validation
    configuration the property tests pin.
    """
    disk_mttf_hours: float = 4.0 * 24 * 365.25   # mean life per disk
    weibull_shape: float = 1.0                   # 1 = exponential (CTMC)
    node_burst_hours: float = 0.0                # mean between node bursts
    rack_burst_hours: float = 0.0                # mean between rack bursts
    lse_hours: float = 0.0                       # mean between latent
    #                                              sector errors, per disk
    scrub_hours: float = 0.0                     # fleet scrub period
    model: str = "paper"                         # "paper" | "strict"
    cost_model: str = "average"                  # "average" | "planner"
    reliability: ReliabilityParams = ReliabilityParams()

    def __post_init__(self):
        if self.model not in MODELS:
            raise ValueError(f"unknown model {self.model!r} "
                             f"(choose from {', '.join(MODELS)})")
        if self.cost_model not in COST_MODELS:
            raise ValueError(f"unknown cost_model {self.cost_model!r} "
                             f"(choose from {', '.join(COST_MODELS)})")
        if self.disk_mttf_hours <= 0:
            raise ValueError("disk_mttf_hours must be positive")
        if self.weibull_shape <= 0:
            raise ValueError("weibull_shape must be positive")

    @property
    def weibull_scale_hours(self) -> float:
        return weibull_scale(self.disk_mttf_hours, self.weibull_shape)


class StripeModel:
    """Decodability + repair-cost oracle over erased-block masks.

    Masks are frozensets of block indices (down disks plus latent-error
    blocks); every query is memoized, so each distinct pattern pays for one
    rank check / one planner solve no matter how many trials hit it.
    """

    def __init__(self, scheme: LRCScheme, params: SimParams):
        self.scheme = scheme
        self.params = params
        self.fmax = scheme.p + scheme.r    # beyond this, loss is certain
        self._decodable: dict[frozenset[int], bool] = {frozenset(): True}
        self._cost: dict[frozenset[int], float] = {}
        self._profile = (repair_cost_profile(scheme, self.fmax)
                         if params.cost_model == "average" else None)

    def decodable(self, mask: frozenset[int]) -> bool:
        got = self._decodable.get(mask)
        if got is None:
            got = self._decodable[mask] = (len(mask) <= self.fmax
                                           and self.scheme.decodable(mask))
        return got

    def cost_blocks(self, down: frozenset[int]) -> float:
        """Blocks read to repair the ``down`` pattern (the repair channel's
        bandwidth demand). ``"average"`` reproduces the Markov chain's
        per-count profile; ``"planner"`` prices the concrete pattern
        through the real multi-failure planner."""
        got = self._cost.get(down)
        if got is None:
            if self._profile is not None:
                got = float(self._profile[len(down)])
            else:
                plan = multi_repair_plan(self.scheme, down)
                if not plan.feasible:
                    raise ValueError(f"cost of unrecoverable {sorted(down)}")
                got = float(plan.cost)
            self._cost[down] = got
        return got

    def tau_hours(self, down: frozenset[int]) -> float:
        """Mean repair duration of the ``down`` pattern — the *same*
        detection + transfer model the closed-form chain uses."""
        return repair_hours(self.cost_blocks(down), len(down),
                            self.params.reliability)
