"""Repair-cost and locality metrics (paper Section II-B / VI-A).

* ADRC   — average degraded read cost over data blocks.
* ARC_1  — average single-node repair cost over all blocks.
* ARC_2  — average two-node repair cost (exhaustive pair enumeration).
* ARC_f  — sampled average f-node repair cost (feeds the MTTDL model).
* local-repair portion / effective local-repair portion (Tables IV, V).
* unrecoverable_fraction — q_f = P(random f-failure pattern undecodable)
  (exact for small C(n, f), Monte Carlo otherwise).
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from .repair import multi_repair_plan, single_repair_plan
from .schemes import LRCScheme


def adrc(scheme: LRCScheme, policy: str = "paper") -> float:
    costs = [single_repair_plan(scheme, b, policy).cost for b in scheme.data_ids]
    return sum(costs) / scheme.k


def arc1(scheme: LRCScheme, policy: str = "paper") -> float:
    costs = [single_repair_plan(scheme, b, policy).cost for b in range(scheme.n)]
    return sum(costs) / scheme.n


def arc2(scheme: LRCScheme) -> float:
    n = scheme.n
    total = 0
    for pair in itertools.combinations(range(n), 2):
        plan = multi_repair_plan(scheme, pair)
        if not plan.feasible:
            # Two failures are always decodable for d >= 3 codes; treat an
            # (impossible here) undecodable pair as a full-stripe read.
            total += n - 2
            continue
        total += plan.cost
    return total / math.comb(n, 2)


def local_portion(scheme: LRCScheme) -> float:
    """Table IV: fraction of two-node patterns repairable fully locally."""
    n = scheme.n
    hits = 0
    for pair in itertools.combinations(range(n), 2):
        plan = multi_repair_plan(scheme, pair)
        if plan.feasible and plan.local_possible:
            hits += 1
    return hits / math.comb(n, 2)


def effective_local_portion(scheme: LRCScheme) -> float:
    """Table V: all-local AND strictly cheaper than the k-read global decode."""
    n = scheme.n
    hits = 0
    for pair in itertools.combinations(range(n), 2):
        plan = multi_repair_plan(scheme, pair)
        if (plan.feasible and plan.local_possible
                and plan.best_local_cost is not None
                and plan.best_local_cost < scheme.k):
            hits += 1
    return hits / math.comb(n, 2)


def _patterns(n: int, f: int, samples: int, seed: int, exact_cap: int):
    if math.comb(n, f) <= exact_cap:
        yield from itertools.combinations(range(n), f)
        return
    rng = np.random.default_rng(seed)
    for _ in range(samples):
        yield tuple(sorted(rng.choice(n, size=f, replace=False).tolist()))


def arc_f(scheme: LRCScheme, f: int, samples: int = 400, seed: int = 0,
          exact_cap: int = 2000) -> float:
    """Sampled mean repair cost for f simultaneous failures (recoverable
    patterns only; unrecoverable ones are data loss, not repair)."""
    n = scheme.n
    total, count = 0, 0
    for pat in _patterns(n, f, samples, seed, exact_cap):
        plan = multi_repair_plan(scheme, pat, max_exact=3 if f > 3 else 4)
        if plan.feasible:
            total += plan.cost
            count += 1
    return total / max(count, 1)


def unrecoverable_fraction(scheme: LRCScheme, f: int, samples: int = 3000,
                           seed: int = 1, exact_cap: int = 20000) -> float:
    """q_f: probability a uniformly random f-failure pattern is undecodable."""
    n = scheme.n
    if f <= 0:
        return 0.0
    if f > scheme.p + scheme.r:
        return 1.0  # more failures than parity blocks: some data must be lost
    bad, count = 0, 0
    for pat in _patterns(n, f, samples, seed, exact_cap):
        count += 1
        if not scheme.decodable(frozenset(pat)):
            bad += 1
    return bad / max(count, 1)


def summarize(scheme: LRCScheme) -> dict[str, float]:
    return {
        "ADRC": adrc(scheme),
        "ARC1": arc1(scheme),
        "ARC2": arc2(scheme),
        "local_portion": local_portion(scheme),
        "effective_local_portion": effective_local_portion(scheme),
    }
