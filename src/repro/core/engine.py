"""Batched multi-stripe codec engine.

The planner/executor split (DESIGN.md §4): :class:`~repro.core.planner.
RepairPlanner` compiles and caches the host-side GF algebra; this module's
:class:`BatchedCodecEngine` executes a compiled plan over a whole *batch* of
stripes at once — ``(S, k, B)`` in, ``(S, n, B)`` out — as a single Pallas
launch with a stripe grid axis, instead of the seed codec's one solve + one
launch per stripe per block.

Batches are homogeneous in the failure pattern, not in S: callers group
stripes by pattern (``ftx.stripestore`` does this per fleet repair) and may
pass ragged last batches of any size, including S=1.

Availability can be given either as a dense ``(S, n, B)`` array or as a
mapping ``block-id -> (S, B)`` holding only surviving blocks; both gather to
the plan's read order before the launch.

Passing :class:`~repro.dist.sharding.MeshRules` (at construction or per
call) shards the stripe axis over the mesh's data axes — one device-parallel
launch per call via ``repro.dist.stripes`` — with bit-identical results;
``last_span`` reports how many devices the most recent launch spread over.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Mapping, Optional, Union

import jax
import numpy as np

from repro.dist.sharding import MeshRules
from repro.dist.stripes import stripe_span
from repro.kernels.ops import (default_backend, effective_backend,
                               encode_batch_op, gf_matmul_batch_op,
                               require_backend)

from .planner import CompiledPlan, RepairPlanner
from .schemes import LRCScheme

Blocks = Union[jax.Array, np.ndarray, Mapping[int, "jax.Array | np.ndarray"]]


@dataclasses.dataclass
class BatchedCodecEngine:
    scheme: LRCScheme
    # REPRO_BACKEND > mxu-on-TPU > gf (kernels.ops.default_backend),
    # resolved once at construction.
    backend: str = dataclasses.field(default_factory=default_backend)
    planner: RepairPlanner | None = None
    mesh_rules: MeshRules | None = None
    last_span: int = dataclasses.field(default=1, init=False)
    # Wall-clock of the most recent execute() launch, device-synchronized
    # (block_until_ready) so span accounting upstream sees real compute time
    # rather than async-dispatch time.
    last_exec_seconds: float = dataclasses.field(default=0.0, init=False)
    # Formulation the most recent launch actually ran (kernels.ops.
    # effective_backend): equals ``backend`` except for the one documented
    # substitution — an interpreted "gf" batch executes the fused table
    # path and reports "ref". Nothing downgrades silently; this field is
    # the telemetry record of what ran, per launch.
    effective_backend: str = dataclasses.field(default="", init=False)

    def __post_init__(self):
        require_backend(self.backend)
        if self.planner is None:
            self.planner = RepairPlanner(self.scheme)
        elif self.planner.scheme is not self.scheme:
            raise ValueError("planner is bound to a different scheme")

    def _rules(self, mesh_rules: Optional[MeshRules]) -> Optional[MeshRules]:
        return self.mesh_rules if mesh_rules is None else mesh_rules

    # --------------------------------------------------------------- helpers
    def _gather(self, available: Blocks, reads: tuple[int, ...]) -> jax.Array:
        """Stack the read blocks into (S, |reads|, B) in plan column order."""
        import jax.numpy as jnp

        if isinstance(available, Mapping):
            cols = []
            for b in reads:
                try:
                    cols.append(jnp.asarray(available[b], jnp.uint8))
                except KeyError:
                    raise KeyError(f"plan reads block {b} but it was not "
                                   f"provided") from None
            return jnp.stack(cols, axis=1)
        arr = jnp.asarray(available, jnp.uint8)
        if arr.ndim != 3:
            raise ValueError(f"expected (S, n, B) availability, got {arr.shape}")
        return arr[:, list(reads), :]

    def execute(self, plan: CompiledPlan, stacked: jax.Array | np.ndarray,
                mesh_rules: Optional[MeshRules] = None) -> jax.Array:
        """Run a compiled plan on an already-gathered (S, |reads|, B) stack.

        The zero-copy entry point for callers that materialize the read
        stack themselves — skips the per-block gather/stack. ``stacked``
        may be a host numpy array (the stripe store's single-shard gather;
        scattered straight onto the stripe sharding by the launch layer) or
        a pre-sharded global ``jax.Array`` built per device shard
        (``repro.dist.placement.assemble_shards``), which is consumed with
        zero re-transfer — never bounced through one device.
        """
        import jax.numpy as jnp

        if isinstance(stacked, np.ndarray):
            stacked = np.ascontiguousarray(stacked, np.uint8)
        else:
            stacked = jnp.asarray(stacked, jnp.uint8)
        if stacked.ndim != 3 or stacked.shape[1] != len(plan.reads):
            raise ValueError(f"expected (S, {len(plan.reads)}, B) stack for "
                             f"plan reads {plan.reads}, got {stacked.shape}")
        mr = self._rules(mesh_rules)
        self.last_span = stripe_span(stacked.shape, mr)
        self.effective_backend = effective_backend(self.backend)
        bitmatrix = (plan.bit_coeffs()
                     if self.backend in ("crs", "mxu") else None)
        t0 = time.perf_counter()
        out = gf_matmul_batch_op(plan.coeffs, stacked,
                                 backend=self.backend, bitmatrix=bitmatrix,
                                 mesh_rules=mr)
        jax.block_until_ready(out)
        self.last_exec_seconds = time.perf_counter() - t0
        return out

    def _execute(self, plan: CompiledPlan, available: Blocks,
                 mesh_rules: Optional[MeshRules] = None) -> jax.Array:
        return self.execute(plan, self._gather(available, plan.reads),
                            mesh_rules)

    # ------------------------------------------------------------- encoding
    def encode(self, data: jax.Array | np.ndarray,
               mesh_rules: Optional[MeshRules] = None) -> jax.Array:
        """(S, k, B) data -> (S, n, B) systematic stripes, one launch."""
        import jax.numpy as jnp

        data = jnp.asarray(data, jnp.uint8)
        if data.ndim != 3 or data.shape[1] != self.scheme.k:
            raise ValueError(
                f"expected (S, {self.scheme.k}, B) data, got {data.shape}")
        mr = self._rules(mesh_rules)
        self.last_span = stripe_span(data.shape, mr)
        self.effective_backend = effective_backend(self.backend)
        plan = self.planner.encode_plan()
        bitmatrix = (plan.bit_coeffs()
                     if self.backend in ("crs", "mxu") else None)
        parity = encode_batch_op(plan.coeffs, data, backend=self.backend,
                                 mesh_rules=mr, bitmatrix=bitmatrix)
        return jnp.concatenate([data, parity], axis=1)

    # ------------------------------------------------------------- repair
    def repair_single(self, failed: int, available: Blocks,
                      policy: str = "paper",
                      mesh_rules: Optional[MeshRules] = None
                      ) -> tuple[jax.Array, CompiledPlan]:
        """Rebuild one block across S stripes: (S, B) plus the cached plan."""
        plan = self.planner.single_plan(failed, policy)
        return self._execute(plan, available, mesh_rules)[:, 0, :], plan

    def repair_multi(self, failed: Iterable[int], available: Blocks,
                     mesh_rules: Optional[MeshRules] = None
                     ) -> tuple[dict[int, jax.Array], CompiledPlan]:
        """Rebuild a failure pattern across S stripes in one launch.

        Returns ``{block -> (S, B)}``; the cascade is pre-flattened by the
        planner so there is exactly one kernel launch regardless of how many
        blocks the pattern repairs — one per device when sharded.
        """
        plan = self.planner.multi_plan(failed)
        out = self._execute(plan, available, mesh_rules)
        return {b: out[:, i, :] for i, b in enumerate(plan.targets)}, plan

    # ------------------------------------------------------------- decode
    def decode(self, available: Blocks, ids: Iterable[int] | None = None,
               mesh_rules: Optional[MeshRules] = None) -> jax.Array:
        """(S, k, B) data blocks from any rank-k subset of surviving blocks.

        ``ids`` names the surviving blocks; it may be omitted for a Mapping
        availability (its keys are used).
        """
        if ids is None:
            if not isinstance(available, Mapping):
                raise ValueError("ids is required for dense availability")
            ids = available.keys()
        plan = self.planner.decode_plan(ids)
        return self._execute(plan, available, mesh_rules)
