"""Repair planning for LRC stripes.

Implements the paper's repair algorithms (Sections IV-C / IV-D):

* **single-node**: typed repair — data / grouped blocks within their local
  repair group; cascaded-group members (local parities and G_r in CP-LRCs)
  within the cascaded group; non-grouped global parities by recomputation.
* **multi-node**: "local-first, global-as-fallback". A failed block can be
  repaired locally by any *unit* (local repair group or the cascaded group)
  that contains it, provided the unit's other members are alive or already
  repaired. Repairs cascade: repairing L_1 from its group can unlock the
  cascaded-group repair of G_r, etc. If any failure cannot be covered this
  way, a global decode happens; per the paper, the k-block decode set is
  chosen to include blocks already read by local repairs, so a pattern that
  needs global repair costs exactly k reads (never more).

Costs are counted in *distinct surviving blocks read* (node accesses), the
paper's metric. Blocks reconstructed earlier in the plan are reusable for
free (they are at the proxy already).

The multi-node planner searches over repair-unit assignments and orders for
the minimum-read schedule (exact for small failure counts — this is what
reproduces Table III's ARC2 wide-stripe cells to the cent — and greedy for
larger patterns, which only arise in MTTDL sampling).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from .gf import GF_INV_TABLE, GF_MUL_TABLE
from .schemes import DATA, GLOBAL, LOCAL, LRCScheme


@dataclasses.dataclass(frozen=True)
class RepairPlan:
    """A single-block repair: read ``reads``, combine with ``method``."""
    target: int
    reads: frozenset[int]
    method: str  # "group" | "cascade" | "recompute" | "global"

    @property
    def cost(self) -> int:
        return len(self.reads)


@dataclasses.dataclass(frozen=True)
class MultiRepairPlan:
    failed: frozenset[int]
    reads: frozenset[int]
    all_local: bool
    feasible: bool
    steps: tuple[tuple[int, str], ...]  # (block, method) in execution order
    local_possible: bool = False        # does ANY all-local schedule exist?
    best_local_cost: Optional[int] = None

    @property
    def cost(self) -> int:
        return len(self.reads)


# --------------------------------------------------------------------------
# repair units
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _Unit:
    uid: int
    kind: str  # "group" | "cascade"
    members: frozenset[int]

    def sources_for(self, b: int) -> frozenset[int]:
        return self.members - {b}


def repair_units(scheme: LRCScheme) -> list[_Unit]:
    units = [
        _Unit(uid=g.gid, kind="group", members=frozenset(g.members()))
        for g in scheme.groups
    ]
    if scheme.cascade is not None:
        units.append(_Unit(uid=len(units), kind="cascade",
                           members=frozenset(scheme.cascade.members)))
    return units


# --------------------------------------------------------------------------
# single-node repair
# --------------------------------------------------------------------------
def single_repair_candidates(scheme: LRCScheme, b: int) -> list[RepairPlan]:
    """All structural repair options for block b (everything else alive)."""
    plans = []
    for g in scheme.groups_of_item(b):
        reads = frozenset(g.items) - {b} | {g.parity}
        plans.append(RepairPlan(b, reads, "group"))
    g = scheme.group_of_parity(b)
    if g is not None:
        plans.append(RepairPlan(b, frozenset(g.items), "recompute"))
    if scheme.in_cascade(b):
        reads = frozenset(scheme.cascade.members) - {b}
        plans.append(RepairPlan(b, reads, "cascade"))
    if scheme.kind(b) == GLOBAL:
        plans.append(RepairPlan(b, frozenset(scheme.data_ids), "global"))
    if not plans:  # ungrouped data block cannot happen by construction
        raise AssertionError(f"no repair candidate for block {b}")
    return plans


def single_repair_plan(scheme: LRCScheme, b: int,
                       policy: str = "paper") -> RepairPlan:
    """Pick the plan the paper's repair algorithm would pick.

    policy="paper": cascaded-group members always repair within the cascaded
    group (this is what the paper's evaluation tables use — see
    EXPERIMENTS.md on the min{g,p} text/table discrepancy at P4); everything
    else takes its cheapest local option, with global recomputation only for
    non-grouped global parities.
    policy="min": strictly cheapest candidate (the paper text's min{g,p}).
    """
    plans = single_repair_candidates(scheme, b)
    if policy == "paper" and scheme.in_cascade(b):
        cas = [pl for pl in plans if pl.method == "cascade"]
        if cas:
            return cas[0]
    non_global = [pl for pl in plans if pl.method != "global"]
    pool = non_global if non_global else plans
    return min(pool, key=lambda pl: (pl.cost, pl.method != "group"))


# --------------------------------------------------------------------------
# rank utilities for global decode-set selection
# --------------------------------------------------------------------------
def _greedy_rank_k_set(scheme: LRCScheme, ordered_pool: list[int]) -> Optional[list[int]]:
    """Greedy: walk the pool, keep rows that grow the GF(2^8) rank, stop at k."""
    k = scheme.k
    basis: list[np.ndarray] = []  # rows in echelon form (leading-one normalized)
    lead: list[int] = []
    chosen: list[int] = []
    for b in ordered_pool:
        row = scheme.gen[b].copy()
        for lrow, lc in zip(basis, lead):
            c = row[lc]
            if c:
                row ^= GF_MUL_TABLE[np.uint8(c), lrow]
        nz = np.nonzero(row)[0]
        if nz.size == 0:
            continue
        lc = int(nz[0])
        inv = GF_INV_TABLE[row[lc]]
        row = GF_MUL_TABLE[np.uint8(inv), row]
        basis.append(row)
        lead.append(lc)
        chosen.append(b)
        if len(chosen) == k:
            return chosen
    return None


def global_decode_set(scheme: LRCScheme, alive: frozenset[int],
                      prefer: frozenset[int] = frozenset()) -> Optional[list[int]]:
    """A rank-k set of alive blocks, preferring already-read blocks, then data
    blocks, then parities (mirrors the paper's read-reuse rule)."""
    pool = sorted(alive, key=lambda b: (b not in prefer, scheme.kind(b) != DATA, b))
    return _greedy_rank_k_set(scheme, pool)


# --------------------------------------------------------------------------
# multi-node repair
# --------------------------------------------------------------------------
def _local_closure(units: list[_Unit], failed: frozenset[int], alive: frozenset[int],
                   assignment: dict[int, _Unit]) -> Optional[tuple[frozenset[int], tuple[tuple[int, str], ...]]]:
    """Execute an assignment failure->unit to a fixed point.

    Returns (reads, steps) if every assigned failure gets repaired (dependency
    order respected), else None. Failures not in the assignment are treated as
    unrepairable locally (they go to the global phase by the caller).
    """
    pending = set(assignment)
    repaired: set[int] = set()
    reads: set[int] = set()
    steps: list[tuple[int, str]] = []
    progress = True
    while pending and progress:
        progress = False
        for b in sorted(pending):
            unit = assignment[b]
            sources = unit.sources_for(b)
            if sources & failed <= repaired:  # failed sources must be repaired already
                reads |= {s for s in sources if s in alive}
                repaired.add(b)
                pending.discard(b)
                steps.append((b, unit.kind))
                progress = True
    if pending:
        return None
    return frozenset(reads), tuple(steps)


def _units_for(scheme: LRCScheme, units: list[_Unit], b: int) -> list[_Unit]:
    out = []
    for u in units:
        if b not in u.members:
            continue
        if u.kind == "group":
            out.append(u)
        else:  # cascade
            out.append(u)
    return out


def multi_repair_plan(scheme: LRCScheme, failed, *, max_exact: int = 4,
                      allow_global_shortcut: bool = True) -> MultiRepairPlan:
    """Min-read repair schedule for a failure pattern.

    Exact search over unit assignments for ``len(failed) <= max_exact``
    (every failure independently picks one of its covering units, or the
    global phase; at most one failure per unit); greedy fixed-point beyond.
    Patterns that need the global phase cost exactly k reads (the decode set
    subsumes local reads — verified via explicit rank-k set construction).
    """
    failed = frozenset(failed)
    n = scheme.n
    alive = frozenset(range(n)) - failed
    if not scheme.decodable(failed):
        return MultiRepairPlan(failed, frozenset(), False, False, ())
    units = repair_units(scheme)

    best: Optional[tuple[frozenset[int], tuple, bool]] = None  # (reads, steps, all_local)
    best_local: Optional[int] = None

    def consider(reads, steps, all_local):
        nonlocal best, best_local
        if all_local and (best_local is None or len(reads) < best_local):
            best_local = len(reads)
        # Local-first on ties: prefer the all-local schedule at equal cost.
        key = (len(reads), not all_local)
        if best is None or key < (len(best[0]), not best[2]):
            best = (reads, steps, all_local)

    cand_units = {b: _units_for(scheme, units, b) for b in failed}

    if len(failed) <= max_exact:
        # Exact: each failure picks a covering unit or None (=> global phase).
        # Units may serve at most one failure each.
        choices = [cand_units[b] + [None] for b in sorted(failed)]
        ordered = sorted(failed)
        for combo in itertools.product(*choices):
            used = [u.uid for u in combo if u is not None]
            if len(used) != len(set(used)):
                continue
            assignment = {b: u for b, u in zip(ordered, combo) if u is not None}
            local_part = _local_closure(units, failed, alive, assignment)
            if local_part is None:
                continue
            reads, steps = local_part
            leftovers = [b for b in ordered if b not in assignment]
            if leftovers:
                decode = global_decode_set(scheme, alive, prefer=reads)
                if decode is None:
                    continue
                reads = reads | frozenset(decode)
                steps = steps + tuple((b, "global") for b in leftovers)
                consider(reads, steps, all_local=False)
            else:
                consider(reads, steps, all_local=True)
    else:
        # Greedy fixed point: repeatedly apply the cheapest currently-feasible
        # unit repair; remaining failures go global.
        pending = set(failed)
        repaired: set[int] = set()
        reads: set[int] = set()
        steps: list[tuple[int, str]] = []
        used_units: set[int] = set()
        while pending:
            candidates = []
            for b in pending:
                for u in cand_units[b]:
                    if u.uid in used_units:
                        continue
                    sources = u.sources_for(b)
                    if sources & failed <= repaired:
                        new = {s for s in sources if s in alive} - reads
                        candidates.append((len(new), b, u))
            if not candidates:
                break
            _, b, u = min(candidates, key=lambda t: (t[0], t[1]))
            reads |= {s for s in u.sources_for(b) if s in alive}
            repaired.add(b)
            pending.discard(b)
            used_units.add(u.uid)
            steps.append((b, u.kind))
        if pending:
            decode = global_decode_set(scheme, alive, prefer=frozenset(reads))
            if decode is None:
                return MultiRepairPlan(failed, frozenset(), False, False, ())
            reads |= set(decode)
            steps.extend((b, "global") for b in sorted(pending))
            consider(frozenset(reads), tuple(steps), all_local=False)
        else:
            consider(frozenset(reads), tuple(steps), all_local=True)

    # Pure-global option (always considered; this is the k-read fallback).
    decode = global_decode_set(scheme, alive, prefer=frozenset())
    if decode is not None:
        consider(frozenset(decode), tuple((b, "global") for b in sorted(failed)), False)

    if best is None:
        return MultiRepairPlan(failed, frozenset(), False, False, ())

    reads, steps, all_local = best
    return MultiRepairPlan(failed, reads, all_local, True, steps,
                           local_possible=best_local is not None,
                           best_local_cost=best_local)
