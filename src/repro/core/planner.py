"""Repair planner: compiled, cached GF plans for the batched codec engine.

The GF solves behind every codec operation — reconstruction coefficients,
multi-node cascades, full decode — are pure functions of ``(scheme,
failure-pattern, policy)``; nothing about the payload bytes enters them.
The seed codec recomputed them on every call (one Gaussian elimination per
repaired block per stripe), which is pure waste once a fleet repairs
thousands of stripes sharing a handful of failure patterns.

``RepairPlanner`` computes each plan once and LRU-caches it as a
:class:`CompiledPlan`: a dense ``(targets, reads)`` coefficient matrix ready
to feed the (batched) GF matmul kernels, plus the structural plan metadata.
Multi-node cascades are *flattened* at compile time — since every repaired
block is ultimately a linear combination of the surviving read set, the whole
cascade collapses into one coefficient matrix and therefore one kernel
launch, instead of one launch per repaired block (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from .gf import gf_solve_any, matrix_to_bitmatrix
from .repair import (MultiRepairPlan, RepairPlan, multi_repair_plan,
                     single_repair_candidates, single_repair_plan)
from .schemes import LRCScheme

# Serving-path preference order over single-block repair methods: the
# paper's degraded-read argument is local group first (g reads), the
# cascaded group only when the local group is insufficient, and the k-read
# global decode strictly last. "recompute" (a parity from its own group's
# items) is a local-group operation too.
_SERVE_METHOD_RANK = {"group": 0, "recompute": 0, "cascade": 1, "global": 2}

# Bit-matrix expansion accounting. The GF(2) expansion of a plan's byte
# coefficient matrix (DESIGN.md §11) is cached on the CompiledPlan itself,
# so it is computed at most once per plan — i.e. once per failure-pattern
# chunk, amortized over every stripe batch that reuses the plan. The
# counter makes that amortization observable: tests and the benchmark
# regression gate assert expansions == distinct plans, not launches.
_BIT_LOCK = threading.Lock()
_BIT_EXPANSIONS = 0


def bitmatrix_expansions() -> int:
    """Process-wide count of byte->bit coefficient-matrix expansions."""
    with _BIT_LOCK:
        return _BIT_EXPANSIONS


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """A fully-solved codec operation: ``out = coeffs @ stack(reads)``.

    ``coeffs[i]`` rebuilds block ``targets[i]`` from the blocks listed in
    ``reads`` (column order). ``meta`` carries the structural plan the
    coefficients were derived from (None for encode/decode plans).
    """
    op: str                              # "encode" | "single" | "multi" | "decode"
    targets: tuple[int, ...]
    reads: tuple[int, ...]
    coeffs: np.ndarray                   # (len(targets), len(reads)) uint8
    meta: RepairPlan | MultiRepairPlan | None = None
    # Lazily-cached GF(2) expansion of ``coeffs`` for the bit-plane backends
    # (crs/mxu). Excluded from init/repr/compare: it is derived state, and
    # ``dataclasses.replace`` (used when re-attaching meta) resets it to
    # None, which only costs one re-expansion on the replaced plan.
    _bit_coeffs: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def cost(self) -> int:
        return len(self.reads)

    def bit_coeffs(self) -> np.ndarray:
        """The packed ``(8*targets, 8*reads)`` GF(2) expansion of ``coeffs``.

        Computed on first use and cached on the plan (plans are LRU-cached
        by the planner, so a whole pattern chunk — every batch launch that
        reuses this plan — pays for exactly one expansion; see
        :func:`bitmatrix_expansions`). Thread-safe: concurrent first calls
        may race to build, but publication through ``object.__setattr__``
        is atomic and the expansion is deterministic, so every caller sees
        the same matrix and the counter counts at most one expansion per
        plan under the lock.
        """
        cached = self._bit_coeffs
        if cached is not None:
            return cached
        global _BIT_EXPANSIONS
        with _BIT_LOCK:
            cached = self._bit_coeffs
            if cached is not None:
                return cached
            bm = matrix_to_bitmatrix(self.coeffs)
            bm.setflags(write=False)
            object.__setattr__(self, "_bit_coeffs", bm)
            _BIT_EXPANSIONS += 1
            return bm


@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


class RepairPlanner:
    """Per-scheme plan compiler with an LRU cache and hit/miss telemetry.

    Thread-safe: stripe stores may plan from concurrent repair workers. The
    cache key never includes payload data, so a planner can be shared by any
    number of codecs/engines over the same scheme.
    """

    def __init__(self, scheme: LRCScheme, maxsize: int = 512):
        self.scheme = scheme
        self.maxsize = maxsize
        self.stats = PlanCacheStats()
        self._cache: OrderedDict[tuple, CompiledPlan] = OrderedDict()
        self._lock = threading.Lock()

    # ----------------------------------------------------------- cache core
    def _get(self, key: tuple, build) -> CompiledPlan:
        with self._lock:
            plan = self._cache.get(key)
            if plan is not None:
                self.stats.hits += 1
                self._cache.move_to_end(key)
                return plan
            self.stats.misses += 1
        plan = build()  # solve outside the lock; duplicate work is harmless
        with self._lock:
            self._cache[key] = plan
            self._cache.move_to_end(key)
            if len(self._cache) > self.maxsize:
                self._cache.popitem(last=False)
                self.stats.evictions += 1
        return plan

    def cache_clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self.stats = PlanCacheStats()

    def __len__(self) -> int:
        return len(self._cache)

    # ------------------------------------------------------------ raw solves
    def coeffs_for(self, target: int, reads: Sequence[int]
                   ) -> Optional[np.ndarray]:
        """Cached reconstruction coefficients: gen[reads].T @ x = gen[target]."""
        reads = tuple(reads)
        key = ("coeffs", target, reads)
        try:
            return self._get(
                key, lambda: self._solve_many("single", (target,), reads)
            ).coeffs[0]
        except _Unsolvable:
            return None

    def _solve_many(self, op: str, targets: Sequence[int],
                    reads: Sequence[int], meta=None) -> CompiledPlan:
        gen = self.scheme.gen
        reads = tuple(reads)
        a = gen[list(reads)].T.astype(np.uint8)
        rows = []
        for t in targets:
            x = gf_solve_any(a, gen[t])
            if x is None:
                raise _Unsolvable(t, reads)
            rows.append(x)
        return CompiledPlan(op, tuple(targets), reads,
                            np.stack(rows, axis=0).astype(np.uint8), meta)

    # -------------------------------------------------------- compiled plans
    def encode_plan(self) -> CompiledPlan:
        """Parity rows over the data blocks (the generator's parity slice)."""
        s = self.scheme
        return self._get(("encode",), lambda: CompiledPlan(
            "encode", tuple(range(s.k, s.n)), tuple(range(s.k)),
            s.parity_matrix().astype(np.uint8)))

    def single_plan(self, failed: int, policy: str = "paper") -> CompiledPlan:
        """Compiled single-block repair (the paper's typed repair rule)."""
        def build() -> CompiledPlan:
            plan = single_repair_plan(self.scheme, failed, policy)
            reads = tuple(sorted(plan.reads))
            try:
                return dataclasses.replace(
                    self._solve_many("single", (failed,), reads), meta=plan)
            except _Unsolvable:
                raise RuntimeError(
                    f"inconsistent repair plan for block {failed}") from None
        return self._get(("single", failed, policy), build)

    def multi_plan(self, failed) -> CompiledPlan:
        """Compiled multi-node repair, cascade flattened to one matrix.

        Every block the structural planner repairs — including cascade steps
        that nominally read earlier repairs — is a linear combination of the
        plan's surviving read set, so the whole schedule compiles to a single
        ``(|failed|, |reads|)`` matrix and executes as one kernel launch.
        """
        failed = frozenset(failed)
        def build() -> CompiledPlan:
            plan = multi_repair_plan(self.scheme, failed)
            if not plan.feasible:
                raise RuntimeError(f"pattern {sorted(failed)} is not decodable")
            targets = tuple(b for b, _ in plan.steps)
            reads = tuple(sorted(plan.reads))
            try:
                return self._solve_many("multi", targets, reads, meta=plan)
            except _Unsolvable as e:
                raise RuntimeError(
                    f"cannot reconstruct block {e.target} from {sorted(reads)}"
                ) from None
        return self._get(("multi", failed), build)

    def serving_plan(self, block: int, down) -> CompiledPlan:
        """Cheapest feasible plan to serve one lost block under a down-set.

        The degraded-read planner: among the structural single-block repair
        candidates whose sources are all alive, pick the local-group option
        first, the cascaded-group option next, a global recompute last
        (``_SERVE_METHOD_RANK``), cheapest within each tier. When no
        single-block candidate survives the down-set, fall back to the
        flattened multi-node plan for the whole pattern — its targets
        include ``block`` (and every other lost block, which serving caches
        for free). Cached under ``("serve", block, down)`` so a fleet of
        concurrent readers of one hot lost block compiles the GF solve
        exactly once.

        Raises ``RuntimeError`` when the pattern is not decodable.
        """
        down = frozenset(down)
        if block not in down:
            raise ValueError(f"block {block} is not in the down-set "
                             f"{sorted(down)}")

        def build() -> CompiledPlan:
            cands = [c for c in single_repair_candidates(self.scheme, block)
                     if not (c.reads & down)]
            for cand in sorted(cands, key=lambda c: (
                    _SERVE_METHOD_RANK[c.method], c.cost)):
                reads = tuple(sorted(cand.reads))
                try:
                    return dataclasses.replace(
                        self._solve_many("single", (block,), reads),
                        meta=cand)
                except _Unsolvable:
                    continue
            # No single-block candidate survives this down-set: the whole
            # pattern decodes (or fails) through the multi-node plan, which
            # has its own cache entry — the serve key just aliases it.
            return self.multi_plan(down)

        return self._get(("serve", block, down), build)

    def decode_plan(self, available) -> CompiledPlan:
        """Compiled full decode: the k data blocks from any rank-k read set."""
        ids = tuple(sorted(available))
        def build() -> CompiledPlan:
            try:
                return self._solve_many("decode", tuple(range(self.scheme.k)), ids)
            except _Unsolvable:
                raise RuntimeError(
                    "available blocks do not span the data") from None
        return self._get(("decode", ids), build)


class _Unsolvable(Exception):
    def __init__(self, target: int, reads: tuple[int, ...]):
        super().__init__(f"block {target} not in span of {reads}")
        self.target = target
        self.reads = reads
