"""Base MDS stripes: Cauchy and Vandermonde Reed-Solomon generator matrices,
plus the paper's Appendix Theorem 1 coefficient construction.

Everything here is planning-tier numpy over GF(2^8) (see ``repro.core.gf``).
"""
from __future__ import annotations

import numpy as np

from .gf import (
    FIELD,
    GF_INV_TABLE,
    gf_inv,
    gf_matmul,
    gf_mul,
    gf_pow,
    gf_rank,
)


def cauchy_points(k: int, r: int) -> tuple[np.ndarray, np.ndarray]:
    """Canonical k+r distinct evaluation points a_1..a_k, b_1..b_r in GF(2^8).

    Jerasure convention: a_i = i + r - 1? We keep it simple and auditable:
    a_i = r + i - 1 for i in 1..k and b_j = j - 1 for j in 1..r, i.e.
    b = {0..r-1}, a = {r..r+k-1}. Requires k + r <= 256.
    """
    if k + r > FIELD:
        raise ValueError(f"k+r={k + r} exceeds GF(2^8) field size")
    b = np.arange(r, dtype=np.uint8)
    a = np.arange(r, r + k, dtype=np.uint8)
    return a, b


def cauchy_matrix(k: int, r: int) -> np.ndarray:
    """(r, k) Cauchy coding matrix: alpha[j, i] = 1 / (b_j - a_i) = 1/(b_j ^ a_i)."""
    a, b = cauchy_points(k, r)
    diff = (b[:, None] ^ a[None, :]).astype(np.uint8)  # subtraction == XOR
    return gf_inv(diff)


def vandermonde_matrix(k: int, r: int) -> np.ndarray:
    """(r, k) coding matrix derived from a systematic Vandermonde construction.

    Classic Azure-LRC-style generator: start from the (k+r, k) Vandermonde
    V[i, j] = x_i^j, row-reduce to systematic form [I; M]; M is guaranteed to
    make [I; M] MDS for distinct x_i (standard RS systematic construction).
    """
    if k + r > FIELD:
        raise ValueError(f"k+r={k + r} exceeds GF(2^8) field size")
    n = k + r
    v = np.zeros((n, k), dtype=np.uint8)
    for i in range(n):
        for j in range(k):
            v[i, j] = gf_pow(i + 1, j)
    # Systematize: column operations to turn the top kxk block into I.
    # Equivalent to V @ inv(V_top).
    from .gf import gf_mat_inv

    top_inv = gf_mat_inv(v[:k])
    sys = gf_matmul(v, top_inv)
    m = sys[k:]
    if np.any(m == 0):
        # Zero coefficients would break LRC coefficient decomposition; Cauchy
        # matrices never have zeros, Vandermonde-systematic rarely does. Patch
        # by falling back to Cauchy (still MDS, same role).
        return cauchy_matrix(k, r)
    return m


def theorem1_coefficients(k: int, r: int) -> tuple[np.ndarray, np.ndarray]:
    """Appendix Theorem 1: nonzero (gamma_bar, eta_bar) with
    gamma_bar_i + sum_j eta_bar_j * alpha[j, i] = 0 for the Cauchy code.

    gamma_bar_i = prod_z (a_i - b_z)^-1;  eta_bar_j = prod_{z != j} (b_j - b_z)^-1.
    Returns (gamma_bar (k,), eta_bar (r,)).
    """
    a, b = cauchy_points(k, r)
    gamma = np.ones(k, dtype=np.uint8)
    for i in range(k):
        for z in range(r):
            gamma[i] = gf_mul(gamma[i], gf_inv(a[i] ^ b[z]))
    eta = np.ones(r, dtype=np.uint8)
    for j in range(r):
        for z in range(r):
            if z != j:
                eta[j] = gf_mul(eta[j], gf_inv(b[j] ^ b[z]))
    return gamma, eta


def uniform_combination_coefficients(k: int, r: int) -> tuple[np.ndarray, np.ndarray]:
    """Eq. (10) coefficients: G_r = sum_i gamma_i D_i + sum_{j<r} eta_j G_j.

    Normalize Theorem 1's identity by eta_bar_r (Corollary 1):
    gamma_i = gamma_bar_i / eta_bar_r, eta_j = eta_bar_j / eta_bar_r.
    All coefficients are nonzero by construction.
    """
    gamma_bar, eta_bar = theorem1_coefficients(k, r)
    inv_last = gf_inv(eta_bar[r - 1])
    gamma = gf_mul(gamma_bar, inv_last)
    eta = gf_mul(eta_bar[: r - 1], inv_last)
    return gamma, eta


def verify_mds(coding: np.ndarray, trials: int = 64, seed: int = 0) -> bool:
    """Spot-check the MDS property of a systematic code [I; coding]:
    every kxk submatrix of the (k+r, k) generator is invertible. Exhaustive for
    small n, randomized for wide stripes.
    """
    r, k = coding.shape
    n = k + r
    gen = np.concatenate([np.eye(k, dtype=np.uint8), coding], axis=0)
    rng = np.random.default_rng(seed)
    import itertools

    ncomb = 1
    for i in range(r):
        ncomb *= (n - i)
    exhaustive = ncomb <= 200_000  # C(n, r) small enough
    if exhaustive:
        combos = itertools.combinations(range(n), k)
    else:
        combos = (sorted(rng.choice(n, size=k, replace=False)) for _ in range(trials))
    for idx, rows in enumerate(combos):
        if not exhaustive and idx >= trials:
            break
        if gf_rank(gen[list(rows)]) < k:
            return False
    return True
