"""repro.core — the paper's contribution: CP-LRC codes, repair, reliability.

Layers:
  gf          GF(2^8) arithmetic (numpy planning tier + jnp data tier)
  cauchy      base MDS stripes + Appendix Theorem 1 coefficients
  schemes     the six LRC constructions (4 baselines + CP-Azure/CP-Uniform)
  repair      single-/multi-node repair planning (local-first, cascading)
  metrics     ADRC / ARC1 / ARC2 / locality portions
  reliability Markov-chain MTTDL
  codec       JAX/Pallas stripe encode-decode data path (per stripe)
  planner     compiled + LRU-cached GF plans per (scheme, pattern, policy)
  engine      batched multi-stripe executor (one launch per failure pattern)
"""
from .schemes import (  # noqa: F401
    LRCScheme,
    PAPER_PARAMS,
    SCHEMES,
    SCHEME_DISPLAY,
    azure_lrc,
    azure_lrc_plus1,
    cp_azure_lrc,
    cp_uniform_lrc,
    make_scheme,
    optimal_cauchy_lrc,
    uniform_cauchy_lrc,
)
from .repair import (  # noqa: F401
    MultiRepairPlan,
    RepairPlan,
    multi_repair_plan,
    single_repair_plan,
)
from . import metrics, reliability  # noqa: F401
