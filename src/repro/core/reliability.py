"""MTTDL reliability model (paper Section II-B, Figure 2; Table VI).

Continuous-time Markov chain over the number of failed blocks in a stripe:

* state f -> f+1: failure rate (n - f) * lambda, split by the hazard that the
  (f+1)-th failure makes the pattern undecodable (-> absorbing data loss).
  The hazard is derived from q_f = P(random f-pattern undecodable):
  h_f = (q_{f+1} - q_f) / (1 - q_f) (exchangeable-pattern approximation;
  exact enumeration of q_f where C(n, f) is small).
* state f -> f-1: repair at rate 1 / tau_f where
  tau_f = T_detect(f) + cost_f * block_bytes / repair_bandwidth
  and cost_f is the scheme's average f-failure repair cost in blocks
  (ARC_1, ARC_2, sampled ARC_f) — this is exactly where CP-LRCs' lower
  repair bandwidth turns into higher MTTDL.

MTTDL = expected absorption time from state 0, via the standard linear solve
on the embedded generator.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import metrics as metrics_lib
from .schemes import LRCScheme

HOURS_PER_YEAR = 24.0 * 365.25


@dataclasses.dataclass(frozen=True)
class ReliabilityParams:
    """Defaults follow the evaluation's cloud setup (64 MB blocks, 1 Gbps)
    with a 4-year mean life per node and 30-minute multi-failure detection."""
    node_mttf_years: float = 4.0
    block_mb: float = 64.0
    bandwidth_gbps: float = 1.0
    detect_hours_single: float = 0.05
    detect_hours_multi: float = 0.5
    # Global time scale knob used once to line our absolute numbers up with
    # the paper's Table VI (their lambda/bandwidth constants are not given);
    # relative scheme-to-scheme ratios are insensitive to it.
    repair_time_scale: float = 1.0


def repair_hours(cost_blocks: float, f: int, p: ReliabilityParams) -> float:
    """Mean hours to repair an ``f``-failure state that reads
    ``cost_blocks`` blocks: detection plus transfer at the repair
    bandwidth, times the global calibration scale.

    This is the single repair-time model shared by the closed-form Markov
    chain below and the event-driven simulator (``repro.sim``): both turn a
    plan's block-read cost into a vulnerability-window duration through
    exactly this function, so their MTTDLs are comparable by construction.
    """
    transfer_hours = (cost_blocks * p.block_mb * 8.0 / 1000.0
                      / p.bandwidth_gbps / 3600.0)
    detect = p.detect_hours_single if f == 1 else p.detect_hours_multi
    return (detect + transfer_hours) * p.repair_time_scale


_repair_hours = repair_hours  # pre-PR-8 private name


def repair_cost_profile(scheme: LRCScheme, fmax: Optional[int] = None,
                        samples: int = 200, seed: int = 7) -> np.ndarray:
    """Mean repair cost in blocks per failure count: ``cost[f]`` for
    ``f = 0..fmax`` (``cost[0] = 0``).

    Exactly the per-state costs the Markov chain uses (ARC_1, ARC_2,
    sampled ARC_f with the chain's sampling seeds), exported so the
    event-driven simulator's ``cost_model="average"`` mode reproduces the
    closed form's repair rates bit-for-bit.
    """
    fmax = scheme.p + scheme.r if fmax is None else fmax
    cost = np.zeros(fmax + 1)
    for f in range(1, fmax + 1):
        if f == 1:
            cost[f] = metrics_lib.arc1(scheme)
        elif f == 2:
            cost[f] = metrics_lib.arc2(scheme)
        else:
            cost[f] = metrics_lib.arc_f(scheme, f, samples=samples,
                                        seed=seed + 31 * f)
    return cost


def unrecoverable_profile(scheme: LRCScheme, fmax: Optional[int] = None,
                          samples: int = 1500, seed: int = 7) -> np.ndarray:
    """Undecodable-pattern fractions ``q[f]`` for ``f = 0..fmax+1``,
    monotone-guarded exactly as the Markov chain consumes them."""
    fmax = scheme.p + scheme.r if fmax is None else fmax
    q = np.zeros(fmax + 2)
    for f in range(1, fmax + 2):
        q[f] = metrics_lib.unrecoverable_fraction(scheme, f, samples=samples,
                                                  seed=seed + f)
    return np.maximum.accumulate(q)


def stripe_mttdl_years(scheme: LRCScheme,
                       params: Optional[ReliabilityParams] = None,
                       samples: int = 1500, seed: int = 7,
                       model: str = "paper") -> float:
    """MTTDL (years) of one stripe under the Markov model above.

    model="paper": the paper's Figure-2 semantics, read literally — when
    failed > r the *downward* transition rate becomes (n-f)*lambda*(1-p_f)
    (an undecodable-pattern probability only slows the descent; data loss
    happens solely at p+r+1 failures). This reproduces Table VI's ordering:
    CP-LRCs win because their faster repairs (higher mu) dominate.

    model="strict": rank-faithful — the first transition into an undecodable
    pattern is absorbed as data loss (hazard (q_{f+1}-q_f)/(1-q_f)). Under
    this stricter model CP-LRCs pay for their minimum distance of r+1 (vs
    r+2 for Azure LRC): see EXPERIMENTS.md for the side-by-side.
    """
    p = params or ReliabilityParams()
    n = scheme.n
    fmax = scheme.p + scheme.r  # beyond this some data is necessarily lost
    lam = 1.0 / (p.node_mttf_years * HOURS_PER_YEAR)

    # Undecodable-pattern fractions q_0..q_{fmax+1} and mean repair cost per
    # state (blocks read) — the shared profiles the simulator also consumes.
    q = unrecoverable_profile(scheme, fmax, samples=samples, seed=seed)
    cost = repair_cost_profile(scheme, fmax, seed=seed)

    # Transient states 0..fmax; absorbing DL.
    nstates = fmax + 1
    rate_fail = np.array([(n - f) * lam for f in range(nstates)])
    hazard = np.zeros(nstates)  # P(next failure is fatal | state f)
    slow = np.ones(nstates)     # paper model: descent slow-down factor
    if model == "strict":
        for f in range(nstates):
            denom = 1.0 - q[f]
            hazard[f] = 0.0 if denom <= 0 else min(1.0, max(0.0, (q[f + 1] - q[f]) / denom))
    elif model == "paper":
        for f in range(nstates - 1):
            slow[f] = 1.0 - q[f + 1]
        hazard[nstates - 1] = 1.0  # p+r+1 failures: data loss
    else:
        raise ValueError(f"unknown reliability model {model!r}")
    mu = np.zeros(nstates)
    for f in range(1, nstates):
        mu[f] = 1.0 / repair_hours(cost[f], f, p)

    # Expected absorption time T_f: (sum of outflow rates) * T_f =
    # 1 + rate_up_ok * T_{f+1} + mu * T_{f-1}; from the top state every new
    # failure is fatal (f = fmax + 1 always exceeds parity count).
    #
    # Rates span ~12 orders of magnitude (per-hour failure rates vs 1e17-year
    # horizons), which destroys float64 Gaussian elimination — solve exactly
    # over rationals instead (the system is tiny: <= r + p + 1 states).
    from fractions import Fraction

    a = [[Fraction(0) for _ in range(nstates)] for _ in range(nstates)]
    b = [Fraction(1) for _ in range(nstates)]
    for f in range(nstates):
        eff_fail = Fraction(rate_fail[f]) * Fraction(slow[f])
        out = eff_fail + (Fraction(mu[f]) if f > 0 else Fraction(0))
        a[f][f] = out
        up_ok = eff_fail * (Fraction(1) - Fraction(hazard[f]))
        if f + 1 < nstates:
            a[f][f + 1] -= up_ok
        # from fmax, any new failure is data loss (hazard[fmax] == 1).
        if f > 0:
            a[f][f - 1] -= Fraction(mu[f])
    t = _solve_fractions(a, b)
    return float(t[0] / HOURS_PER_YEAR)


def _solve_fractions(a: list[list], b: list) -> list:
    """Exact Gaussian elimination over Fractions (tiny systems only)."""
    n = len(b)
    m = [row[:] + [b[i]] for i, row in enumerate(a)]
    for c in range(n):
        piv = next(rr for rr in range(c, n) if m[rr][c] != 0)
        m[c], m[piv] = m[piv], m[c]
        inv = 1 / m[c][c]
        m[c] = [v * inv for v in m[c]]
        for rr in range(n):
            if rr != c and m[rr][c] != 0:
                fac = m[rr][c]
                m[rr] = [v - fac * w for v, w in zip(m[rr], m[c])]
    return [m[i][n] for i in range(n)]


def calibrate_scale(scheme: LRCScheme, target_years: float,
                    params: Optional[ReliabilityParams] = None,
                    **kw) -> ReliabilityParams:
    """1-D search on repair_time_scale so that stripe_mttdl_years(scheme)
    matches a target (used once to anchor absolute numbers to Table VI)."""
    base = params or ReliabilityParams()
    lo, hi = 1e-4, 1e4
    for _ in range(60):
        mid = (lo * hi) ** 0.5
        cand = dataclasses.replace(base, repair_time_scale=mid)
        got = stripe_mttdl_years(scheme, cand, **kw)
        # Longer repairs => lower MTTDL (monotone decreasing in scale).
        if got > target_years:
            lo = mid
        else:
            hi = mid
    return dataclasses.replace(base, repair_time_scale=(lo * hi) ** 0.5)
