"""Stripe codec: the JAX/Pallas data path for encode, repair and decode.

Planning (which blocks to read, with which GF coefficients) happens on the
host in numpy — mirroring the paper's coordinator — and the byte crunching
runs through the Pallas kernels in ``repro.kernels``.

The reconstruction rule is fully general: to rebuild block ``b`` from a
read-set ``R`` we solve ``gen[R].T @ x = gen[b]`` over GF(2^8) and combine
``x @ stack(R-blocks)`` on device. This covers local-group repair, cascaded
repair and global decode with one code path, and works for every scheme.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Mapping, Optional, Sequence

import jax
import numpy as np

from repro.kernels.ops import encode_op, gf_matmul_op

from .gf import gf_solve_any
from .repair import MultiRepairPlan, RepairPlan, multi_repair_plan, single_repair_plan
from .schemes import LRCScheme


@dataclasses.dataclass
class StripeCodec:
    scheme: LRCScheme
    backend: str = "gf"  # see repro.kernels.ops.BACKENDS

    # ------------------------------------------------------------- encoding
    def encode(self, data: jax.Array | np.ndarray) -> jax.Array:
        """(k, B) data blocks -> (n, B) full stripe (systematic layout)."""
        import jax.numpy as jnp

        data = jnp.asarray(data, jnp.uint8)
        if data.shape[0] != self.scheme.k:
            raise ValueError(f"expected {self.scheme.k} data blocks, got {data.shape}")
        parity = encode_op(self.scheme.parity_matrix(), data, backend=self.backend)
        return jnp.concatenate([data, parity], axis=0)

    # ----------------------------------------------------- reconstruction
    def reconstruction_coeffs(self, target: int, reads: Sequence[int],
                              free: Mapping[int, np.ndarray] | None = None
                              ) -> Optional[np.ndarray]:
        """GF coefficients x with block[target] = sum_i x_i * block[reads[i]]."""
        gen = self.scheme.gen
        a = gen[list(reads)].T.astype(np.uint8)  # (k, |R|)
        return gf_solve_any(a, gen[target])

    def combine(self, coeffs: np.ndarray, blocks: Sequence[jax.Array]) -> jax.Array:
        """x (|R|,) . blocks (|R|, B) -> (B,) on device via the GF kernel."""
        import jax.numpy as jnp

        stacked = jnp.stack([jnp.asarray(b, jnp.uint8) for b in blocks], axis=0)
        backend = "ref" if self.backend not in ("gf", "ref") else self.backend
        out = gf_matmul_op(coeffs.reshape(1, -1), stacked, backend=backend)
        return out[0]

    def repair_single(self, failed: int, available: Mapping[int, jax.Array],
                      policy: str = "paper") -> tuple[jax.Array, RepairPlan]:
        plan = single_repair_plan(self.scheme, failed, policy)
        reads = sorted(plan.reads)
        coeffs = self.reconstruction_coeffs(failed, reads)
        if coeffs is None:
            raise RuntimeError(f"inconsistent repair plan for block {failed}")
        block = self.combine(coeffs, [available[b] for b in reads])
        return block, plan

    def repair_multi(self, failed: Iterable[int],
                     available: Mapping[int, jax.Array]
                     ) -> tuple[dict[int, jax.Array], MultiRepairPlan]:
        """Execute the min-read multi-node plan; returns rebuilt blocks.

        ``available`` must contain every surviving block the plan reads.
        Repaired blocks become sources for later steps (the cascading
        effect), matching the planner's free-reuse accounting.
        """
        plan = multi_repair_plan(self.scheme, failed)
        if not plan.feasible:
            raise RuntimeError(f"pattern {sorted(failed)} is not decodable")
        have: dict[int, jax.Array] = dict(available)
        rebuilt: dict[int, jax.Array] = {}
        pending = [b for b, _ in plan.steps]
        for b in pending:
            # Sources: anything readable or already repaired. Use the plan's
            # read set plus repaired blocks; solve for b against that basis.
            basis = sorted(set(plan.reads) | set(rebuilt))
            coeffs = self.reconstruction_coeffs(b, basis)
            if coeffs is None:
                raise RuntimeError(f"cannot reconstruct block {b} from {basis}")
            nz = [i for i, c in enumerate(coeffs) if c]
            use = [basis[i] for i in nz]
            block = self.combine(coeffs[nz], [have[s] for s in use])
            have[b] = block
            rebuilt[b] = block
        return rebuilt, plan

    def decode_all(self, available: Mapping[int, jax.Array]) -> jax.Array:
        """Rebuild the k data blocks from any rank-k subset of blocks."""
        import jax.numpy as jnp

        ids = sorted(available)
        gen = self.scheme.gen
        a = gen[ids].T.astype(np.uint8)  # (k, |ids|)
        rows = []
        for tgt in range(self.scheme.k):
            x = gf_solve_any(a, gen[tgt])
            if x is None:
                raise RuntimeError("available blocks do not span the data")
            rows.append(x)
        coeffs = np.stack(rows, axis=0)  # (k, |ids|)
        stacked = jnp.stack([jnp.asarray(available[b], jnp.uint8) for b in ids])
        return gf_matmul_op(coeffs, stacked, backend=self.backend
                            if self.backend in ("gf", "ref") else "ref")


@functools.lru_cache(maxsize=64)
def cached_codec(scheme_key: tuple, backend: str = "gf") -> StripeCodec:
    """Codec cache keyed by (name, k, r, p)."""
    from .schemes import make_scheme

    name, k, r, p = scheme_key
    return StripeCodec(make_scheme(name, k, r, p), backend=backend)
