"""Stripe codec: the JAX/Pallas data path for encode, repair and decode.

Planning (which blocks to read, with which GF coefficients) happens on the
host in numpy — mirroring the paper's coordinator — and the byte crunching
runs through the Pallas kernels in ``repro.kernels``.

The reconstruction rule is fully general: to rebuild block ``b`` from a
read-set ``R`` we solve ``gen[R].T @ x = gen[b]`` over GF(2^8) and combine
``x @ stack(R-blocks)`` on device. This covers local-group repair, cascaded
repair and global decode with one code path, and works for every scheme.

Since the planner/executor split (DESIGN.md §4) every GF solve goes through
a :class:`~repro.core.planner.RepairPlanner`, so repeated repairs of the
same ``(scheme, pattern, policy)`` reuse the compiled coefficient matrix
instead of re-running Gaussian elimination; multi-node cascades execute as a
single flattened kernel launch. For many stripes sharing a failure pattern,
prefer :class:`~repro.core.engine.BatchedCodecEngine`, which runs the whole
batch in one launch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Mapping, Optional, Sequence

import jax
import numpy as np

from repro.kernels.ops import (default_backend, encode_op, gf_matmul_op,
                               require_backend)

from .planner import RepairPlanner
from .repair import MultiRepairPlan, RepairPlan
from .schemes import LRCScheme


@dataclasses.dataclass
class StripeCodec:
    scheme: LRCScheme
    # see repro.kernels.ops.BACKENDS; default honours REPRO_BACKEND
    backend: str = dataclasses.field(default_factory=default_backend)
    planner: Optional[RepairPlanner] = None

    def __post_init__(self):
        require_backend(self.backend)
        if self.planner is None:
            self.planner = RepairPlanner(self.scheme)

    def _bits(self, compiled) -> Optional[np.ndarray]:
        """The plan's cached GF(2) expansion when the backend needs one."""
        return compiled.bit_coeffs() if self.backend in ("crs", "mxu") else None

    # ------------------------------------------------------------- encoding
    def encode(self, data: jax.Array | np.ndarray) -> jax.Array:
        """(k, B) data blocks -> (n, B) full stripe (systematic layout)."""
        import jax.numpy as jnp

        data = jnp.asarray(data, jnp.uint8)
        if data.shape[0] != self.scheme.k:
            raise ValueError(f"expected {self.scheme.k} data blocks, got {data.shape}")
        parity = encode_op(self.scheme.parity_matrix(), data, backend=self.backend)
        return jnp.concatenate([data, parity], axis=0)

    # ----------------------------------------------------- reconstruction
    def reconstruction_coeffs(self, target: int, reads: Sequence[int],
                              free: Mapping[int, np.ndarray] | None = None
                              ) -> Optional[np.ndarray]:
        """GF coefficients x with block[target] = sum_i x_i * block[reads[i]]."""
        return self.planner.coeffs_for(target, tuple(reads))

    def combine(self, coeffs: np.ndarray, blocks: Sequence[jax.Array]) -> jax.Array:
        """x (|R|,) . blocks (|R|, B) -> (B,) on device via the GF kernel."""
        import jax.numpy as jnp

        stacked = jnp.stack([jnp.asarray(b, jnp.uint8) for b in blocks], axis=0)
        out = gf_matmul_op(coeffs.reshape(1, -1), stacked,
                           backend=self.backend)
        return out[0]

    def repair_single(self, failed: int, available: Mapping[int, jax.Array],
                      policy: str = "paper") -> tuple[jax.Array, RepairPlan]:
        compiled = self.planner.single_plan(failed, policy)
        block = self.combine(compiled.coeffs[0],
                             [available[b] for b in compiled.reads])
        return block, compiled.meta

    def repair_multi(self, failed: Iterable[int],
                     available: Mapping[int, jax.Array]
                     ) -> tuple[dict[int, jax.Array], MultiRepairPlan]:
        """Execute the min-read multi-node plan; returns rebuilt blocks.

        ``available`` must contain every surviving block the plan reads.
        The planner pre-flattens the cascade — every failed block is a linear
        combination of the surviving read set — so the whole pattern repairs
        in one kernel launch.
        """
        import jax.numpy as jnp

        compiled = self.planner.multi_plan(failed)
        stacked = jnp.stack([jnp.asarray(available[b], jnp.uint8)
                             for b in compiled.reads], axis=0)
        out = gf_matmul_op(compiled.coeffs, stacked, backend=self.backend,
                           bitmatrix=self._bits(compiled))
        rebuilt = {b: out[i] for i, b in enumerate(compiled.targets)}
        return rebuilt, compiled.meta

    def decode_all(self, available: Mapping[int, jax.Array]) -> jax.Array:
        """Rebuild the k data blocks from any rank-k subset of blocks."""
        import jax.numpy as jnp

        compiled = self.planner.decode_plan(available.keys())
        stacked = jnp.stack([jnp.asarray(available[b], jnp.uint8)
                             for b in compiled.reads])
        return gf_matmul_op(compiled.coeffs, stacked, backend=self.backend,
                            bitmatrix=self._bits(compiled))


def cached_codec(scheme_key: tuple, backend: str | None = None) -> StripeCodec:
    """Codec cache keyed by (name, k, r, p, resolved backend)."""
    return _cached_codec(scheme_key, backend or default_backend())


@functools.lru_cache(maxsize=64)
def _cached_codec(scheme_key: tuple, backend: str) -> StripeCodec:
    from .schemes import make_scheme

    name, k, r, p = scheme_key
    return StripeCodec(make_scheme(name, k, r, p), backend=backend)
