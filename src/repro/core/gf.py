"""GF(2^8) arithmetic — the algebraic substrate for every LRC in this repo.

Two tiers:

* **numpy tier** (planning path): coefficient generation, Gaussian
  elimination / rank / inverse for repair planning and fault-tolerance
  enumeration. Mirrors what the paper's coordinator does in C++/Jerasure.
* **jnp tier** (data path): vectorized encode/decode used by ``repro.codec``
  and as the oracle for the Pallas kernels in ``repro.kernels``.

Field: GF(2^8) with the AES/Jerasure-standard primitive polynomial
``x^8 + x^4 + x^3 + x^2 + 1`` (0x11D). Addition is XOR; w=8 supports stripes
with k + r + p up to 255 blocks — ample for the paper's widest (96, 5, 4).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

try:  # The planning tier must import without JAX (e.g. docs tooling).
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

PRIM_POLY = 0x11D
FIELD = 256
ORDER = FIELD - 1  # multiplicative group order


# --------------------------------------------------------------------------
# Table construction (module import time; ~microseconds).
# --------------------------------------------------------------------------
def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(2 * ORDER, dtype=np.uint8)
    log = np.zeros(FIELD, dtype=np.int32)
    x = 1
    for i in range(ORDER):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= PRIM_POLY
    exp[ORDER:] = exp[:ORDER]
    return exp, log


GF_EXP, GF_LOG = _build_tables()


def _build_mul_table() -> np.ndarray:
    a = np.arange(FIELD, dtype=np.int32)
    t = GF_EXP[(GF_LOG[a][:, None] + GF_LOG[a][None, :]) % ORDER].astype(np.uint8)
    t[0, :] = 0
    t[:, 0] = 0
    return t


GF_MUL_TABLE = _build_mul_table()  # (256, 256) uint8
GF_INV_TABLE = np.zeros(FIELD, dtype=np.uint8)
GF_INV_TABLE[1:] = GF_EXP[(ORDER - GF_LOG[np.arange(1, FIELD)]) % ORDER]


# --------------------------------------------------------------------------
# numpy tier
# --------------------------------------------------------------------------
def gf_mul(a, b):
    """Elementwise GF(2^8) product (numpy, broadcasting)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    return GF_MUL_TABLE[a, b]


def gf_inv(a):
    a = np.asarray(a, dtype=np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("GF(2^8) inverse of 0")
    return GF_INV_TABLE[a]


def gf_div(a, b):
    return gf_mul(a, gf_inv(b))


def gf_pow(a: int, e: int) -> int:
    if e == 0:
        return 1
    if a == 0:
        return 0
    return int(GF_EXP[(int(GF_LOG[a]) * e) % ORDER])


def gf_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Matrix product over GF(2^8): (m,k) @ (k,n) -> (m,n). numpy tier.

    XOR-reduction of table-looked-up partial products. Memory O(m*k*n) —
    fine for planning-sized matrices (k <= 128); the data path uses the
    jnp/Pallas tier instead.
    """
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"gf_matmul shape mismatch {a.shape} @ {b.shape}")
    prods = GF_MUL_TABLE[a[:, :, None], b[None, :, :]]  # (m,k,n)
    return np.bitwise_xor.reduce(prods, axis=1)


def gf_matvec(a: np.ndarray, v: np.ndarray) -> np.ndarray:
    return gf_matmul(a, v.reshape(-1, 1)).reshape(-1)


def gf_eliminate(m: np.ndarray) -> tuple[np.ndarray, int, list[int]]:
    """Row-reduce over GF(2^8). Returns (rref, rank, pivot_cols)."""
    m = np.array(m, dtype=np.uint8, copy=True)
    rows, cols = m.shape
    rank = 0
    pivots: list[int] = []
    for c in range(cols):
        if rank >= rows:
            break
        pivot = None
        for rr in range(rank, rows):
            if m[rr, c]:
                pivot = rr
                break
        if pivot is None:
            continue
        if pivot != rank:
            m[[rank, pivot]] = m[[pivot, rank]]
        inv = GF_INV_TABLE[m[rank, c]]
        m[rank] = GF_MUL_TABLE[np.uint8(inv), m[rank]]
        mask = m[:, c].copy()
        mask[rank] = 0
        nz = np.nonzero(mask)[0]
        if nz.size:
            m[nz] ^= GF_MUL_TABLE[mask[nz][:, None], m[rank][None, :]]
        pivots.append(c)
        rank += 1
    return m, rank, pivots


def gf_rank(m: np.ndarray) -> int:
    return gf_eliminate(m)[1]


def gf_mat_inv(m: np.ndarray) -> np.ndarray:
    """Invert a square matrix over GF(2^8) (Gauss-Jordan)."""
    m = np.asarray(m, dtype=np.uint8)
    n = m.shape[0]
    if m.shape != (n, n):
        raise ValueError(f"not square: {m.shape}")
    aug = np.concatenate([m, np.eye(n, dtype=np.uint8)], axis=1)
    rref, rank, _ = gf_eliminate(aug)
    if rank < n:
        raise np.linalg.LinAlgError("singular over GF(2^8)")
    return rref[:, n:]


def gf_solve(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Solve a @ x = b over GF(2^8) for square invertible a."""
    return gf_matmul(gf_mat_inv(a), b.reshape(a.shape[0], -1)).reshape(b.shape)


def gf_solve_any(a: np.ndarray, y: np.ndarray) -> Optional[np.ndarray]:
    """Any solution x of a @ x = y over GF(2^8) (a may be non-square /
    rank-deficient); returns None if inconsistent. Free variables are 0.

    Used to derive reconstruction coefficients: to rebuild block b from a
    read-set R, solve gen[R].T @ x = gen[b]."""
    a = np.asarray(a, dtype=np.uint8)
    y = np.asarray(y, dtype=np.uint8).reshape(-1)
    rows, cols = a.shape
    aug = np.concatenate([a, y[:, None]], axis=1)
    rref, rank, pivots = gf_eliminate(aug)
    x = np.zeros(cols, dtype=np.uint8)
    for rr, c in enumerate(pivots):
        if c == cols:  # pivot in the y column -> inconsistent system
            return None
        x[c] = rref[rr, cols]
    # Verify (guards against pivots beyond rank rows).
    if not np.array_equal(gf_matvec(a, x), y):
        return None
    return x


# --------------------------------------------------------------------------
# Bitmatrix (CRS) representation: GF(2^8) coefficient -> 8x8 binary matrix.
# Column j of M_c holds the bits of c * x^j; then for byte vectors seen as
# bit-packets, multiplication by c is a GF(2) matrix product — pure XOR.
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _coeff_bitmatrix_cached(c: int) -> bytes:
    m = np.zeros((8, 8), dtype=np.uint8)
    for j in range(8):
        v = gf_mul(c, 1 << j)
        for i in range(8):
            m[i, j] = (int(v) >> i) & 1
    return m.tobytes()


def coeff_bitmatrix(c: int) -> np.ndarray:
    """8x8 GF(2) matrix of multiplication by c (row i = output bit i)."""
    return np.frombuffer(_coeff_bitmatrix_cached(int(c)), dtype=np.uint8).reshape(8, 8).copy()


def matrix_to_bitmatrix(m: np.ndarray) -> np.ndarray:
    """(rows, cols) GF(2^8) matrix -> (rows*8, cols*8) GF(2) bitmatrix."""
    m = np.asarray(m, dtype=np.uint8)
    rows, cols = m.shape
    out = np.zeros((rows * 8, cols * 8), dtype=np.uint8)
    for i in range(rows):
        for j in range(cols):
            out[i * 8:(i + 1) * 8, j * 8:(j + 1) * 8] = coeff_bitmatrix(m[i, j])
    return out


# --------------------------------------------------------------------------
# jnp tier — data-path reference implementations (oracles for Pallas kernels)
# --------------------------------------------------------------------------
if jnp is not None:
    _JNP_MUL_TABLE = None

    def _jnp_mul_table():
        global _JNP_MUL_TABLE
        if _JNP_MUL_TABLE is None:
            _JNP_MUL_TABLE = jnp.asarray(GF_MUL_TABLE)
        return _JNP_MUL_TABLE

    def gf_mul_jnp(a, b):
        """Elementwise GF(2^8) product via the 64KB table (jnp, broadcasting)."""
        table = _jnp_mul_table()
        a = a.astype(jnp.uint8)
        b = b.astype(jnp.uint8)
        flat = table.reshape(-1)
        idx = a.astype(jnp.int32) * FIELD + b.astype(jnp.int32)
        return jnp.take(flat, idx, axis=0)

    def gf_mul_shift_jnp(a, b):
        """Elementwise GF(2^8) product, table-free ("Russian peasant").

        8 rounds of conditional-XOR + xtime. This is the exact algorithm the
        Pallas kernel uses on TPU (no gathers), kept here as a jnp oracle.
        """
        a = a.astype(jnp.int32)
        b = b.astype(jnp.int32)
        acc = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), jnp.int32)
        cur = jnp.broadcast_to(b, acc.shape)
        coef = jnp.broadcast_to(a, acc.shape)
        for _ in range(8):
            acc = acc ^ jnp.where((coef & 1) != 0, cur, 0)
            hi = (cur & 0x80) != 0
            cur = ((cur << 1) & 0xFF) ^ jnp.where(hi, PRIM_POLY & 0xFF, 0)
            coef = coef >> 1
        return acc.astype(jnp.uint8)

    def gf_matmul_jnp(coef, data):
        """(m,k) @ (k,B) over GF(2^8), jnp reference (table path)."""
        prods = gf_mul_jnp(coef[:, :, None], data[None, :, :])
        # XOR-reduce over k.
        return jax.lax.reduce(
            prods.astype(jnp.uint8),
            np.uint8(0),
            lambda x, y: jax.lax.bitwise_xor(x, y),
            dimensions=(1,),
        )
