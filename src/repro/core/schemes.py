"""LRC scheme constructions.

Implements the paper's two contributions (CP-Azure, CP-Uniform) and the four
baselines it compares against (Azure LRC, Azure LRC+1, Optimal Cauchy LRC,
Uniform Cauchy LRC), for arbitrary (k, r, p).

Block index layout (fixed across schemes):
    data     D_1..D_k   -> indices 0 .. k-1
    locals   L_1..L_p   -> indices k .. k+p-1
    globals  G_1..G_r   -> indices k+p .. k+p+r-1

Every scheme carries:
  * ``gen``: the (n, k) generator over GF(2^8) — row b gives block b as a
    linear combination of the data blocks. Data rows are identity. This is the
    single source of truth for encoding, decodability (rank checks) and MDS /
    distance analysis.
  * ``groups``: local repair groups. ``items`` are the blocks protected by the
    group (data and possibly global parities); ``parity`` is the local parity
    block; ``coeffs[i]`` is the GF coefficient of ``items[i]`` so that
    ``parity = XOR_i gf_mul(coeffs[i], items[i])``.
  * ``cascade``: for CP-LRCs, the cascaded parity group
    ``[L_1, .., L_p, G_r]`` — any member equals the XOR of the others.

Grouping conventions (reverse-engineered from the paper's Tables I/III; see
EXPERIMENTS.md for the handful of table cells where the paper is internally
inconsistent):
  * item lists are chopped **sequentially**; when sizes differ, the
    floor-sized groups come first and ceil-sized groups last (the paper's
    (6,2,2) CP-Uniform example: (D1,D2,D3), (D4,D5,D6,G1)).
  * Uniform Cauchy groups all of [D_1..D_k, G_1..G_r]; CP-Uniform groups
    [D_1..D_k, G_1..G_{r-1}] (G_r lives in the cascaded group).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import cauchy as cauchy_lib
from .gf import gf_mul, gf_matmul

DATA, LOCAL, GLOBAL = "data", "local", "global"


@dataclasses.dataclass(frozen=True)
class Group:
    gid: int
    items: tuple[int, ...]
    parity: int
    coeffs: tuple[int, ...]  # parity = XOR_i coeffs[i] * items[i]

    def members(self) -> tuple[int, ...]:
        return self.items + (self.parity,)


@dataclasses.dataclass(frozen=True)
class Cascade:
    members: tuple[int, ...]  # [L_1..L_p, G_r]; each = XOR of the others


@dataclasses.dataclass(frozen=True)
class LRCScheme:
    name: str
    k: int
    r: int
    p: int
    gen: np.ndarray  # (n, k) uint8
    groups: tuple[Group, ...]
    cascade: Optional[Cascade]
    tolerance: int = 0  # any <= tolerance failures are guaranteed decodable

    # ---------------------------------------------------------------- layout
    @property
    def n(self) -> int:
        return self.k + self.p + self.r

    @property
    def data_ids(self) -> range:
        return range(0, self.k)

    @property
    def local_ids(self) -> range:
        return range(self.k, self.k + self.p)

    @property
    def global_ids(self) -> range:
        return range(self.k + self.p, self.n)

    def kind(self, b: int) -> str:
        if b < self.k:
            return DATA
        if b < self.k + self.p:
            return LOCAL
        return GLOBAL

    def label(self, b: int) -> str:
        if b < self.k:
            return f"D{b + 1}"
        if b < self.k + self.p:
            return f"L{b - self.k + 1}"
        return f"G{b - self.k - self.p + 1}"

    # ------------------------------------------------------------- structure
    def groups_of_item(self, b: int) -> list[Group]:
        return [g for g in self.groups if b in g.items]

    def group_of_parity(self, b: int) -> Optional[Group]:
        for g in self.groups:
            if g.parity == b:
                return g
        return None

    def in_cascade(self, b: int) -> bool:
        return self.cascade is not None and b in self.cascade.members

    # --------------------------------------------------------------- algebra
    def parity_matrix(self) -> np.ndarray:
        """(p + r, k): rows for L_1..L_p then G_1..G_r."""
        return self.gen[self.k:]

    def decodable(self, failed: frozenset[int] | set[int]) -> bool:
        if len(failed) <= self.tolerance:
            return True  # guaranteed by the scheme's minimum distance
        alive = [b for b in range(self.n) if b not in failed]
        from .gf import gf_rank

        return gf_rank(self.gen[alive]) == self.k

    def encode(self, data: np.ndarray) -> np.ndarray:
        """numpy-tier stripe encode: data (k, B) uint8 -> (n, B)."""
        data = np.asarray(data, dtype=np.uint8)
        parity = gf_matmul(self.parity_matrix(), data)
        return np.concatenate([data, parity], axis=0)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def split_sizes(total: int, parts: int) -> list[int]:
    """Near-even sizes, floor-sized groups first, ceil-sized last."""
    if parts <= 0 or total < parts:
        raise ValueError(f"cannot split {total} items into {parts} groups")
    base, extra = divmod(total, parts)
    return [base] * (parts - extra) + [base + 1] * extra


def chop(seq: list[int], sizes: list[int]) -> list[list[int]]:
    out, pos = [], 0
    for s in sizes:
        out.append(seq[pos:pos + s])
        pos += s
    assert pos == len(seq)
    return out


def _compose_row(scheme_gen_rows: dict[int, np.ndarray], items, coeffs, k: int) -> np.ndarray:
    """Express a parity (sum of coeff*block over items) in terms of data."""
    row = np.zeros(k, dtype=np.uint8)
    for b, c in zip(items, coeffs):
        row ^= gf_mul(np.uint8(c), scheme_gen_rows[b])
    return row


def _assemble(name, k, r, p, coding, group_specs, cascade_members,
              tolerance) -> LRCScheme:
    """Build the (n,k) generator from a coding matrix plus group specs.

    ``coding``: (r, k) global-parity coefficients.
    ``group_specs``: list of (items, coeffs) for L_1..L_p, where items may
    reference global blocks (their rows get substituted).
    ``tolerance``: failure count guaranteed decodable (min distance - 1),
    used as a fast path to skip rank checks in hot enumeration loops.
    """
    n = k + p + r
    rows: dict[int, np.ndarray] = {}
    for i in range(k):
        e = np.zeros(k, dtype=np.uint8)
        e[i] = 1
        rows[i] = e
    for j in range(r):
        rows[k + p + j] = coding[j].astype(np.uint8)
    groups = []
    for gid, (items, coeffs) in enumerate(group_specs):
        parity_id = k + gid
        rows[parity_id] = _compose_row(rows, items, coeffs, k)
        groups.append(Group(gid=gid, items=tuple(items), parity=parity_id,
                            coeffs=tuple(int(c) for c in coeffs)))
    gen = np.stack([rows[b] for b in range(n)], axis=0)
    cascade = Cascade(members=tuple(cascade_members)) if cascade_members else None
    scheme = LRCScheme(name=name, k=k, r=r, p=p, gen=gen,
                       groups=tuple(groups), cascade=cascade,
                       tolerance=tolerance)
    _check_scheme(scheme)
    return scheme


def _check_scheme(s: LRCScheme) -> None:
    # Local parity identity: parity row equals composed row (by construction),
    # and the cascade identity XOR(L_1..L_p) == G_r where present.
    if s.cascade is not None:
        acc = np.zeros(s.k, dtype=np.uint8)
        for b in s.cascade.members[:-1]:
            acc ^= s.gen[b]
        if not np.array_equal(acc, s.gen[s.cascade.members[-1]]):
            raise AssertionError(f"{s.name}: cascade identity violated")
    # All parity coefficients of the coding matrix must be nonzero for the
    # CP decomposition to make sense (guaranteed by Cauchy construction).


# --------------------------------------------------------------------------
# Baseline constructions
# --------------------------------------------------------------------------
def azure_lrc(k: int, r: int, p: int) -> LRCScheme:
    """Azure LRC: Vandermonde globals, p XOR local groups over data."""
    coding = cauchy_lib.vandermonde_matrix(k, r)
    data = list(range(k))
    groups = [(grp, [1] * len(grp)) for grp in chop(data, split_sizes(k, p))]
    # The r+1 tolerance the paper quotes holds for Azure's maximally-
    # recoverable coefficient choice; generic systematic-Vandermonde
    # coefficients only guarantee r (we found a counterexample at (9,3,3) —
    # see tests/test_schemes.py). Beyond r, decodability is rank-checked.
    return _assemble("azure", k, r, p, coding, groups, None, tolerance=r)


def azure_lrc_plus1(k: int, r: int, p: int) -> LRCScheme:
    """Azure LRC+1: (k, r, p-1) Azure + one XOR local parity over the globals."""
    if p < 2:
        raise ValueError("azure+1 requires p >= 2 (one group is the parity group)")
    coding = cauchy_lib.vandermonde_matrix(k, r)
    data = list(range(k))
    groups = [(grp, [1] * len(grp)) for grp in chop(data, split_sizes(k, p - 1))]
    global_ids = list(range(k + p, k + p + r))
    groups.append((global_ids, [1] * r))
    return _assemble("azure+1", k, r, p, coding, groups, None, tolerance=r)


def optimal_cauchy_lrc(k: int, r: int, p: int) -> LRCScheme:
    """Optimal Cauchy LRC: Cauchy globals; L_j = XOR(group data) + XOR(all globals)."""
    coding = cauchy_lib.cauchy_matrix(k, r)
    data = list(range(k))
    global_ids = list(range(k + p, k + p + r))
    groups = []
    for grp in chop(data, split_sizes(k, p)):
        items = grp + global_ids
        groups.append((items, [1] * len(items)))
    return _assemble("optimal", k, r, p, coding, groups, None, tolerance=r)


def uniform_cauchy_lrc(k: int, r: int, p: int) -> LRCScheme:
    """Uniform Cauchy LRC: Cauchy globals; [D_1..D_k, G_1..G_r] chopped into p
    XOR groups (floor-sized first => globals land in the tail groups)."""
    coding = cauchy_lib.cauchy_matrix(k, r)
    items = list(range(k)) + list(range(k + p, k + p + r))
    groups = [(grp, [1] * len(grp)) for grp in chop(items, split_sizes(k + r, p))]
    return _assemble("uniform", k, r, p, coding, groups, None, tolerance=r)


# --------------------------------------------------------------------------
# CP-LRC constructions (the paper's contribution)
# --------------------------------------------------------------------------
def cp_azure_lrc(k: int, r: int, p: int, coding: Optional[np.ndarray] = None) -> LRCScheme:
    """CP-Azure: decompose G_r's data coefficients across p local parities.

    L_j = sum over group-j data of beta_i * D_i with beta = coding[r-1],
    hence XOR(L_1..L_p) = G_r (cascaded parity group).
    """
    if coding is None:
        coding = cauchy_lib.cauchy_matrix(k, r)
    beta = coding[r - 1]
    if np.any(beta == 0):
        raise ValueError("G_r coefficients must be nonzero for CP decomposition")
    data = list(range(k))
    groups = [(grp, [int(beta[i]) for i in grp])
              for grp in chop(data, split_sizes(k, p))]
    cascade = list(range(k, k + p)) + [k + p + r - 1]
    return _assemble("cp-azure", k, r, p, coding, groups, cascade, tolerance=r)


def cp_uniform_lrc(k: int, r: int, p: int) -> LRCScheme:
    """CP-Uniform: group [D_1..D_k, G_1..G_{r-1}] into p groups; coefficients
    from the Appendix Theorem 1 identity G_r = sum gamma_i D_i + sum eta_j G_j.
    """
    coding = cauchy_lib.cauchy_matrix(k, r)
    if r >= 2:
        gamma, eta = cauchy_lib.uniform_combination_coefficients(k, r)
    else:
        # r == 1: G_r = G_1 = its own data coefficients; no eta terms.
        gamma, eta = coding[0].copy(), np.zeros(0, dtype=np.uint8)
    items = list(range(k)) + list(range(k + p, k + p + r - 1))
    coeff_of = {i: int(gamma[i]) for i in range(k)}
    for j in range(r - 1):
        coeff_of[k + p + j] = int(eta[j])
    groups = []
    for grp in chop(items, split_sizes(k + r - 1, p)):
        groups.append((grp, [coeff_of[b] for b in grp]))
    cascade = list(range(k, k + p)) + [k + p + r - 1]
    return _assemble("cp-uniform", k, r, p, coding, groups, cascade, tolerance=r)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
SCHEMES = {
    "azure": azure_lrc,
    "azure+1": azure_lrc_plus1,
    "optimal": optimal_cauchy_lrc,
    "uniform": uniform_cauchy_lrc,
    "cp-azure": cp_azure_lrc,
    "cp-uniform": cp_uniform_lrc,
}

SCHEME_DISPLAY = {
    "azure": "Azure LRC",
    "azure+1": "Azure LRC+1",
    "optimal": "Optimal Cauchy LRC",
    "uniform": "Uniform Cauchy LRC",
    "cp-azure": "CP-Azure",
    "cp-uniform": "CP-Uniform",
}

# The paper's Table II parameter sets.
PAPER_PARAMS = {
    "P1": (6, 2, 2),
    "P2": (12, 2, 2),
    "P3": (16, 3, 2),
    "P4": (20, 3, 5),
    "P5": (24, 2, 2),
    "P6": (48, 4, 3),
    "P7": (72, 4, 4),
    "P8": (96, 5, 4),
}


def make_scheme(name: str, k: int, r: int, p: int) -> LRCScheme:
    try:
        fn = SCHEMES[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; have {sorted(SCHEMES)}") from None
    return fn(k, r, p)
