"""Model API: uniform facade over the decoder-only and encoder-decoder
assemblies, used by the trainer, server, dry-run and smoke tests."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, lm
from .common import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], PyTree]
    param_logical: Callable[[], PyTree]
    train_loss: Callable[[PyTree, dict], jax.Array]
    prefill: Callable[[PyTree, dict], tuple]
    decode_step: Callable[[PyTree, PyTree, jax.Array, jax.Array], tuple]
    init_caches: Callable[..., PyTree]
    sample_batch: Callable[..., dict]

    def abstract_params(self, seed: int = 0) -> PyTree:
        """ShapeDtypeStruct pytree of the parameters — no allocation."""
        return jax.eval_shape(self.init_params, jax.random.key(seed))

    def abstract_caches(self, batch: int, max_len: int) -> PyTree:
        if self.cfg.family == "encdec":
            return jax.eval_shape(
                lambda: self.init_caches(self.cfg, batch, max_len, max_len))
        return jax.eval_shape(lambda: self.init_caches(self.cfg, batch, max_len))

    def param_count(self) -> int:
        total = 0
        for x in jax.tree.leaves(self.abstract_params()):
            n = 1
            for s in x.shape:  # python ints: no int32 overflow on 300B+ models
                n *= int(s)
            total += n
        return total

    def active_param_count(self) -> int:
        """MoE: expert weights count as top-k / E of their size (active set)."""
        cfg = self.cfg
        if not cfg.num_experts:
            return self.param_count()
        total = 0
        for leaf in jax.tree.leaves(self.abstract_params()):
            n = 1
            for s in leaf.shape:
                n *= int(s)
            # Expert tensors: (E, d, ff) or layer-stacked (R, E, d, ff).
            if (leaf.ndim >= 3 and cfg.num_experts > 1
                    and (leaf.shape[0] == cfg.num_experts
                         or (leaf.ndim >= 4 and leaf.shape[1] == cfg.num_experts))):
                n = n * cfg.experts_per_tok // cfg.num_experts
            total += n
        return total


def build(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "encdec":
        mod = encdec
    else:
        mod = lm
    return ModelApi(
        cfg=cfg,
        init_params=lambda key: mod.init_params(key, cfg),
        param_logical=lambda: mod.param_logical(cfg),
        train_loss=lambda params, batch: mod.train_loss(params, batch, cfg),
        prefill=lambda params, batch: mod.prefill(params, batch, cfg),
        decode_step=lambda params, caches, tokens, index: mod.decode_step(
            params, caches, tokens, index, cfg),
        init_caches=mod.init_caches,
        sample_batch=lambda batch, seq, key, **kw: mod.sample_batch(
            cfg, batch, seq, key, **kw),
    )
