"""Decoder-only language model assembly (covers dense / MoE / hybrid / SSM /
VLM-backbone / frontend-stub families).

Params pytree:
  {"embed": (V, d), "stack": [per-position stacked LayerParams],
   "final_norm": (d,), "frontend_proj": optional (d_front, d)}

Batch dict (see ``repro.configs`` input_specs):
  tokens  (B, S) int32
  labels  (B, S) int32          (train only)
  prefix_embeds (B, F, d) bf16  (vlm/audio stubs only)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_activation

from . import blocks
from .common import ModelConfig, cross_entropy, dense_init, embed_tokens, lm_logits, rms_norm

PyTree = Any


def _logical_leaf(v):
    return (isinstance(v, tuple) and not hasattr(v, "_fields")
            and all(x is None or isinstance(x, str) for x in v))


def init_params(key, cfg: ModelConfig) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "embed": dense_init(k1, (cfg.vocab_size, cfg.d_model), cfg.param_dtype,
                            scale=0.02),
        "stack": blocks.init_stack(k2, cfg),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(k3, (cfg.d_model, cfg.d_model),
                                             cfg.param_dtype)
    return params


def param_logical(cfg: ModelConfig) -> PyTree:
    """Logical axis names, mirroring init_params structure. Stacked layer
    leaves get a leading None (the repeat axis)."""
    specs = blocks.build_period(cfg)
    stack_logical = []
    for spec in specs:
        lg = blocks.layer_param_logical(spec, cfg)
        lg = jax.tree.map(lambda names: (None,) + tuple(names), lg,
                          is_leaf=_logical_leaf)
        stack_logical.append(lg)
    out = {
        "embed": ("vocab", None),
        "stack": stack_logical,
        "final_norm": (None,),
    }
    if cfg.frontend != "none":
        out["frontend_proj"] = (None, None)
    return out


def _embed_inputs(params, batch, cfg: ModelConfig):
    x = embed_tokens(params["embed"], batch["tokens"])
    mask = None
    if cfg.frontend != "none":
        prefix = batch["prefix_embeds"].astype(cfg.param_dtype)
        prefix = jnp.einsum("bfd,de->bfe", prefix, params["frontend_proj"])
        x = jnp.concatenate([prefix, x], axis=1)
        # loss only on token positions
        b, s = batch["tokens"].shape
        f = prefix.shape[1]
        mask = jnp.concatenate([jnp.zeros((b, f), bool), jnp.ones((b, s), bool)],
                               axis=1)
    return shard_activation(x, "batch", "seq", None), mask


def forward(params, batch, cfg: ModelConfig, remat: bool = True) -> jax.Array:
    """Token-level logits (B, S_total, V)."""
    x, _ = _embed_inputs(params, batch, cfg)
    x = blocks.forward_stack(params["stack"], x, cfg, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(x, params["embed"], None)


def train_loss(params, batch, cfg: ModelConfig) -> jax.Array:
    x, mask = _embed_inputs(params, batch, cfg)
    x = blocks.forward_stack(params["stack"], x, cfg, remat=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if mask is not None:
        f = x.shape[1] - batch["labels"].shape[1]
        x = x[:, f:, :]
    logits = lm_logits(x, params["embed"], None)
    return cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig):
    """Prefill: returns (last-position logits, decode caches)."""
    x, _ = _embed_inputs(params, batch, cfg)
    x, caches = blocks.prefill_stack(params["stack"], x, cfg)
    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x, params["embed"], None)
    return logits, caches


def decode_step(params, caches, tokens, index, cfg: ModelConfig):
    """One decode step: tokens (B, 1), index = current absolute position."""
    x = embed_tokens(params["embed"], tokens)
    x, caches = blocks.decode_stack(params["stack"], caches, x, index, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x, params["embed"], None)
    return logits, caches


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    return blocks.init_caches(cfg, batch, max_len)


def sample_batch(cfg: ModelConfig, batch: int, seq: int, key,
                 with_labels: bool = True) -> dict:
    """Concrete random batch for smoke tests / examples."""
    kt, kl, kp = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)}
    if with_labels:
        out["labels"] = jax.random.randint(kl, (batch, seq), 0, cfg.vocab_size)
    if cfg.frontend != "none":
        out["prefix_embeds"] = jax.random.normal(
            kp, (batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return out
