"""Composable model library: GQA transformers, MoE, Mamba2/SSD, hybrids,
encoder-decoder — pure-pytree JAX, layer-stacked under lax.scan."""
from .common import ModelConfig  # noqa: F401
from .registry import ModelApi, build  # noqa: F401
