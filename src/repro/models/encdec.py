"""Encoder-decoder assembly (seamless-m4t backbone).

Encoder: bidirectional attention over stub frame embeddings (the audio
frontend provides (B, T, d) directly per the assignment spec). Decoder:
causal self-attention + cross-attention + MLP, layer-stacked via scan.

Params:
  {"embed": (V, d), "enc_stack": stacked enc layers, "dec_stack": stacked
   dec layers, "enc_norm": (d,), "final_norm": (d,), "frontend_proj": (d, d)}
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_activation

from . import attention as attn_lib
from . import mlp as mlp_lib
from .common import ModelConfig, cross_entropy, dense_init, embed_tokens, lm_logits, rms_norm

PyTree = Any


def _logical_leaf(v):
    return (isinstance(v, tuple) and not hasattr(v, "_fields")
            and all(x is None or isinstance(x, str) for x in v))


class EncLayer(NamedTuple):
    norm1: jax.Array
    attn: attn_lib.AttnParams
    norm2: jax.Array
    ffn: mlp_lib.MLPParams


class DecLayer(NamedTuple):
    norm1: jax.Array
    self_attn: attn_lib.AttnParams
    norm_x: jax.Array
    cross_attn: attn_lib.AttnParams
    norm2: jax.Array
    ffn: mlp_lib.MLPParams


def _init_enc_layer(key, cfg) -> EncLayer:
    k1, k2 = jax.random.split(key)
    g = jnp.ones((cfg.d_model,), cfg.param_dtype)
    return EncLayer(norm1=g, attn=attn_lib.init_attn(k1, cfg), norm2=g,
                    ffn=mlp_lib.init_mlp(k2, cfg))


def _init_dec_layer(key, cfg) -> DecLayer:
    k1, k2, k3 = jax.random.split(key, 3)
    g = jnp.ones((cfg.d_model,), cfg.param_dtype)
    return DecLayer(norm1=g, self_attn=attn_lib.init_attn(k1, cfg), norm_x=g,
                    cross_attn=attn_lib.init_attn(k2, cfg), norm2=g,
                    ffn=mlp_lib.init_mlp(k3, cfg))


def init_params(key, cfg: ModelConfig) -> PyTree:
    from .common import stack_layer_init

    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc_layers = cfg.encoder_layers or cfg.num_layers
    return {
        "embed": dense_init(k1, (cfg.vocab_size, cfg.d_model), cfg.param_dtype,
                            scale=0.02),
        "frontend_proj": dense_init(k4, (cfg.d_model, cfg.d_model),
                                    cfg.param_dtype),
        "enc_stack": stack_layer_init(lambda kk: _init_enc_layer(kk, cfg),
                                      enc_layers, k2),
        "dec_stack": stack_layer_init(lambda kk: _init_dec_layer(kk, cfg),
                                      cfg.num_layers, k3),
        "enc_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }


def param_logical(cfg: ModelConfig) -> PyTree:
    a = attn_lib.attn_param_logical(cfg)
    m = mlp_lib.mlp_param_logical()
    stackify = lambda tree: jax.tree.map(
        lambda names: (None,) + tuple(names), tree,
        is_leaf=_logical_leaf)
    enc = stackify(EncLayer(norm1=(None,), attn=a, norm2=(None,), ffn=m))
    dec = stackify(DecLayer(norm1=(None,), self_attn=a, norm_x=(None,),
                            cross_attn=a, norm2=(None,), ffn=m))
    return {"embed": ("vocab", None), "frontend_proj": (None, None),
            "enc_stack": enc, "dec_stack": dec,
            "enc_norm": (None,), "final_norm": (None,)}


def _encode(params, frames, cfg: ModelConfig) -> jax.Array:
    x = jnp.einsum("btd,de->bte", frames.astype(cfg.param_dtype),
                   params["frontend_proj"])
    x = shard_activation(x, "batch", None, None)

    def body(h, p: EncLayer):
        hn = rms_norm(h, p.norm1, cfg.norm_eps)
        h = h + _bidir_attention(p.attn, hn, cfg)
        hn = rms_norm(h, p.norm2, cfg.norm_eps)
        h = h + mlp_lib.mlp(p.ffn, hn, cfg)
        return h, None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_stack"], unroll=cfg.scan_unroll)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _bidir_attention(p, x, cfg):
    """Encoder self-attention: full (non-causal) mask."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = attn_lib._project_qkv(p, x, positions, cfg)
    scores = attn_lib._gqa_scores(q, k, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    return attn_lib._gqa_out(probs, v, p.wo)


def _decode_stack(params, x, memory, cfg: ModelConfig):
    def body(h, p: DecLayer):
        hn = rms_norm(h, p.norm1, cfg.norm_eps)
        h = h + attn_lib.attention(p.self_attn, hn, cfg)
        hn = rms_norm(h, p.norm_x, cfg.norm_eps)
        mk, mv = attn_lib.project_memory_kv(p.cross_attn, memory)
        h = h + attn_lib.cross_attention(p.cross_attn, hn, mk, mv, cfg)
        hn = rms_norm(h, p.norm2, cfg.norm_eps)
        h = h + mlp_lib.mlp(p.ffn, hn, cfg)
        return h, None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_stack"], unroll=cfg.scan_unroll)
    return x


def train_loss(params, batch, cfg: ModelConfig) -> jax.Array:
    memory = _encode(params, batch["frames"], cfg)
    x = embed_tokens(params["embed"], batch["tokens"])
    x = _decode_stack(params, x, memory, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x, params["embed"], None)
    return cross_entropy(logits, batch["labels"])


def prefill(params, batch, cfg: ModelConfig):
    """Encode + decoder prefill. Caches: (self KV per layer, memory KV per
    layer, encoder memory) — decode_step reuses all three."""
    memory = _encode(params, batch["frames"], cfg)
    x = embed_tokens(params["embed"], batch["tokens"])

    def body(h, p: DecLayer):
        hn = rms_norm(h, p.norm1, cfg.norm_eps)
        out, kv = attn_lib.prefill_attention(p.self_attn, hn, cfg)
        h = h + out
        hn = rms_norm(h, p.norm_x, cfg.norm_eps)
        mk, mv = attn_lib.project_memory_kv(p.cross_attn, memory)
        h = h + attn_lib.cross_attention(p.cross_attn, hn, mk, mv, cfg)
        hn = rms_norm(h, p.norm2, cfg.norm_eps)
        h = h + mlp_lib.mlp(p.ffn, hn, cfg)
        return h, (kv, (mk.astype(jnp.bfloat16), mv.astype(jnp.bfloat16)))

    x, caches = jax.lax.scan(body, x, params["dec_stack"],
                             unroll=cfg.scan_unroll)
    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return lm_logits(x, params["embed"], None), caches


def decode_step(params, caches, tokens, index, cfg: ModelConfig):
    x = embed_tokens(params["embed"], tokens)
    self_kv, mem_kv = caches

    def body(h, scanned):
        p, kv, mem = scanned
        hn = rms_norm(h, p.norm1, cfg.norm_eps)
        out, kv = attn_lib.decode_attention(p.self_attn, hn, kv, index, cfg)
        h = h + out
        hn = rms_norm(h, p.norm_x, cfg.norm_eps)
        h = h + attn_lib.cross_attention(p.cross_attn, hn, mem[0], mem[1], cfg)
        hn = rms_norm(h, p.norm2, cfg.norm_eps)
        h = h + mlp_lib.mlp(p.ffn, hn, cfg)
        return h, kv

    x, self_kv = jax.lax.scan(body, x, (params["dec_stack"], self_kv, mem_kv),
                              unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(x, params["embed"], None), (self_kv, mem_kv)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, mem_len: int):
    enc_l = cfg.num_layers
    hd = cfg.resolved_head_dim
    kv = attn_lib.KVCache(
        k=jnp.zeros((enc_l, batch, max_len, cfg.num_kv_heads, hd), jnp.bfloat16),
        v=jnp.zeros((enc_l, batch, max_len, cfg.num_kv_heads, hd), jnp.bfloat16))
    mem = (jnp.zeros((enc_l, batch, mem_len, cfg.num_kv_heads, hd), jnp.bfloat16),
           jnp.zeros((enc_l, batch, mem_len, cfg.num_kv_heads, hd), jnp.bfloat16))
    return (kv, mem)


def sample_batch(cfg: ModelConfig, batch: int, seq: int, key,
                 with_labels: bool = True) -> dict:
    kt, kl, kf = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        "frames": jax.random.normal(kf, (batch, seq, cfg.d_model), jnp.bfloat16),
    }
    if with_labels:
        out["labels"] = jax.random.randint(kl, (batch, seq), 0, cfg.vocab_size)
    return out
