"""Layer assembly: period-structured stacks under ``lax.scan``.

A model is a *period* of layer positions repeated R times
(num_layers = R * period). Uniform models have period 1; gemma3 uses a
6-layer period (5 sliding-window + 1 global attention); jamba an 8-layer
period (7 Mamba + 1 attention, MoE on odd positions). Parameters and KV/SSM
caches stack along a leading R axis per position, and the whole depth runs
as one ``lax.scan`` over periods with ``jax.checkpoint`` on the body —
compile time and HLO size stay O(period), not O(depth).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import mlp as mlp_lib
from . import ssm as ssm_lib
from .common import ModelConfig, rms_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # "attn" | "attn_local" | "ssm"
    ffn: str    # "mlp" | "moe" | "none"


def build_period(cfg: ModelConfig) -> list[LayerSpec]:
    """Derive the layer period from the config's structural knobs."""
    period_len = 1
    if cfg.local_global_period:
        period_len = cfg.local_global_period
    if cfg.attn_period:
        period_len = max(period_len, cfg.attn_period)
    if cfg.num_experts and cfg.moe_every > 1:
        period_len = max(period_len, cfg.moe_every)
    if cfg.num_layers % period_len:
        raise ValueError(f"{cfg.name}: {cfg.num_layers} layers not divisible "
                         f"by period {period_len}")
    specs = []
    for i in range(period_len):
        if not cfg.is_attn_layer(i):
            mixer = "ssm"
        elif cfg.is_global_attn_layer(i) or not cfg.sliding_window:
            mixer = "attn"
        else:
            mixer = "attn_local"
        if cfg.d_ff == 0 and not cfg.num_experts:
            ffn = "none"
        elif cfg.is_moe_layer(i):
            ffn = "moe"
        else:
            ffn = "mlp"
        specs.append(LayerSpec(mixer=mixer, ffn=ffn))
    return specs


class LayerParams(NamedTuple):
    norm1: jax.Array
    mixer: PyTree            # AttnParams | SSMParams
    norm2: Optional[jax.Array]
    ffn: Optional[PyTree]    # MLPParams | MoEParams | None


def init_layer(key, spec: LayerSpec, cfg: ModelConfig) -> LayerParams:
    k1, k2 = jax.random.split(key)
    if spec.mixer == "ssm":
        mixer = ssm_lib.init_ssm(k1, cfg)
    else:
        mixer = attn_lib.init_attn(k1, cfg)
    if spec.ffn == "moe":
        ffn = mlp_lib.init_moe(k2, cfg)
    elif spec.ffn == "mlp":
        ffn = mlp_lib.init_mlp(k2, cfg)
    else:
        ffn = None
    g = jnp.ones((cfg.d_model,), cfg.param_dtype)
    return LayerParams(norm1=g, mixer=mixer,
                       norm2=g if ffn is not None else None, ffn=ffn)


def layer_param_logical(spec: LayerSpec, cfg: ModelConfig) -> LayerParams:
    mixer = (ssm_lib.ssm_param_logical() if spec.mixer == "ssm"
             else attn_lib.attn_param_logical(cfg))
    if spec.ffn == "moe":
        ffn = mlp_lib.moe_param_logical(cfg)
    elif spec.ffn == "mlp":
        ffn = mlp_lib.mlp_param_logical()
    else:
        ffn = None
    return LayerParams(norm1=(None,), mixer=mixer,
                       norm2=(None,) if ffn is not None else None, ffn=ffn)


def apply_layer(spec: LayerSpec, p: LayerParams, x: jax.Array,
                cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, p.norm1, cfg.norm_eps)
    if spec.mixer == "ssm":
        x = x + ssm_lib.ssm_forward(p.mixer, h, cfg)
    elif spec.mixer == "attn_local":
        x = x + attn_lib.attention(p.mixer, h, cfg, window=cfg.sliding_window)
    else:
        x = x + attn_lib.attention(p.mixer, h, cfg)
    if p.ffn is not None:
        h = rms_norm(x, p.norm2, cfg.norm_eps)
        if spec.ffn == "moe":
            x = x + mlp_lib.moe(p.ffn, h, cfg)
        else:
            x = x + mlp_lib.mlp(p.ffn, h, cfg)
    return x


# --------------------------------------------------------------------------
# stacked periods
# --------------------------------------------------------------------------
def init_stack(key, cfg: ModelConfig) -> list[PyTree]:
    """Per-position stacked params: list over period positions; each element
    has leaves with leading axis R = num_layers / period."""
    from .common import stack_layer_init

    specs = build_period(cfg)
    repeats = cfg.num_layers // len(specs)
    out = []
    for pos, spec in enumerate(specs):
        kpos = jax.random.fold_in(key, pos)
        out.append(stack_layer_init(
            lambda kk, spec=spec: init_layer(kk, spec, cfg), repeats, kpos))
    return out


def forward_stack(stack: list[PyTree], x: jax.Array, cfg: ModelConfig,
                  remat: bool = True) -> jax.Array:
    specs = build_period(cfg)

    def body(carry, period_params):
        h = carry
        for pos, spec in enumerate(specs):
            h = apply_layer(spec, period_params[pos], h, cfg)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, stack, unroll=cfg.scan_unroll)
    return x


def prefill_stack(stack: list[PyTree], x: jax.Array, cfg: ModelConfig,
                  remat: bool = True) -> tuple[jax.Array, list[PyTree]]:
    """Forward pass that also emits decode caches for every layer."""
    specs = build_period(cfg)

    def body(carry, period_params):
        h = carry
        caches = []
        for pos, spec in enumerate(specs):
            p = period_params[pos]
            hn = rms_norm(h, p.norm1, cfg.norm_eps)
            if spec.mixer == "ssm":
                out, c = ssm_lib.ssm_forward_with_cache(p.mixer, hn, cfg)
            else:
                window = cfg.sliding_window if spec.mixer == "attn_local" else 0
                out, c = attn_lib.prefill_attention(p.mixer, hn, cfg,
                                                    window=window)
            h = h + out
            if p.ffn is not None:
                hn = rms_norm(h, p.norm2, cfg.norm_eps)
                if spec.ffn == "moe":
                    h = h + mlp_lib.moe(p.ffn, hn, cfg)
                else:
                    h = h + mlp_lib.mlp(p.ffn, hn, cfg)
            caches.append(c)
        return h, caches

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, stack, unroll=cfg.scan_unroll)
    return x, caches


# --------------------------------------------------------------------------
# decode with caches
# --------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> list[PyTree]:
    """Per-position stacked caches (leading R axis), matching init_stack."""
    specs = build_period(cfg)
    repeats = cfg.num_layers // len(specs)
    caches = []
    for spec in specs:
        if spec.mixer == "ssm":
            c = ssm_lib.init_ssm_cache(cfg, batch)
        else:
            length = (min(cfg.sliding_window, max_len)
                      if spec.mixer == "attn_local" else max_len)
            c = attn_lib.init_cache(cfg, batch, length)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape), c))
    return caches


def pad_caches(caches: list[PyTree], cfg: ModelConfig,
               new_len: int) -> list[PyTree]:
    """Grow global KV caches (axis: length) to ``new_len`` so decode can
    append. Ring (sliding-window) and SSM caches are length-invariant."""
    specs = build_period(cfg)
    out = []
    for spec, c in zip(specs, caches):
        if spec.mixer == "attn" and isinstance(c, attn_lib.KVCache):
            cur = c.k.shape[2]  # (R, B, L, KV, hd)
            if cur < new_len:
                widths = [(0, 0), (0, 0), (0, new_len - cur), (0, 0), (0, 0)]
                c = attn_lib.KVCache(k=jnp.pad(c.k, widths),
                                     v=jnp.pad(c.v, widths))
        out.append(c)
    return out


def decode_stack(stack: list[PyTree], caches: list[PyTree], x: jax.Array,
                 index: jax.Array, cfg: ModelConfig
                 ) -> tuple[jax.Array, list[PyTree]]:
    """One-token step through the whole depth; returns (x, new caches)."""
    specs = build_period(cfg)

    def body(carry, scanned):
        h = carry
        period_params, period_caches = scanned
        new_caches = []
        for pos, spec in enumerate(specs):
            p = period_params[pos]
            c = period_caches[pos]
            hn = rms_norm(h, p.norm1, cfg.norm_eps)
            if spec.mixer == "ssm":
                out, c = ssm_lib.ssm_decode_step(p.mixer, hn, c, cfg)
            else:
                window = cfg.sliding_window if spec.mixer == "attn_local" else 0
                out, c = attn_lib.decode_attention(p.mixer, hn, c, index, cfg,
                                                   window=window)
            h = h + out
            if p.ffn is not None:
                hn = rms_norm(h, p.norm2, cfg.norm_eps)
                if spec.ffn == "moe":
                    h = h + mlp_lib.moe(p.ffn, hn, cfg)
                else:
                    h = h + mlp_lib.mlp(p.ffn, hn, cfg)
            new_caches.append(c)
        return h, new_caches

    x, new_caches = jax.lax.scan(body, x, (stack, caches),
                                 unroll=cfg.scan_unroll)
    return x, new_caches
