"""Grouped-query attention: training/prefill (full + sliding window) and
cached single-token decode. GQA never materializes repeated KV heads — score
einsums keep a (kv_heads, q_per_kv) split so memory matches the cache.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard_activation

from .common import ModelConfig, dense_init, rope

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array            # (d, H, hd)
    wk: jax.Array            # (d, KV, hd)
    wv: jax.Array            # (d, KV, hd)
    wo: jax.Array            # (H, hd, d)
    bq: Optional[jax.Array]  # (H, hd) or None
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KV, hd)
    v: jax.Array


def init_attn(key, cfg: ModelConfig) -> AttnParams:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    mk = lambda kk, shape: dense_init(kk, shape, cfg.param_dtype)
    bias = (lambda shape: jnp.zeros(shape, cfg.param_dtype)) if cfg.qkv_bias else (lambda shape: None)
    return AttnParams(
        wq=mk(ks[0], (d, cfg.num_heads, hd)),
        wk=mk(ks[1], (d, cfg.num_kv_heads, hd)),
        wv=mk(ks[2], (d, cfg.num_kv_heads, hd)),
        wo=mk(ks[3], (cfg.num_heads, hd, d)),
        bq=bias((cfg.num_heads, hd)),
        bk=bias((cfg.num_kv_heads, hd)),
        bv=bias((cfg.num_kv_heads, hd)),
    )


def attn_param_logical(cfg: ModelConfig) -> AttnParams:
    """Logical axis names per parameter (layer-stacked callers prepend None).
    Bias entries are None when the config has no QKV bias, matching the
    params pytree structure exactly."""
    b = cfg.qkv_bias
    return AttnParams(
        wq=(None, "heads", None), wk=(None, "kv_heads", None),
        wv=(None, "kv_heads", None), wo=("heads", None, None),
        bq=("heads", None) if b else None,
        bk=("kv_heads", None) if b else None,
        bv=("kv_heads", None) if b else None,
    )


def _project_qkv(p: AttnParams, x: jax.Array, positions, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dgk->bsgk", x, p.wk)
    v = jnp.einsum("bsd,dgk->bsgk", x, p.wv)
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, "batch", None, "heads", None)
    k = shard_activation(k, "batch", None, "kv_heads", None)
    v = shard_activation(v, "batch", None, "kv_heads", None)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """q (B,S,H,hd) x k (B,T,KV,hd) -> (B, KV, qpk, S, T) in f32."""
    b, s, h, hd = q.shape
    kv = cfg.num_kv_heads
    qg = q.reshape(b, s, kv, cfg.q_per_kv, hd)
    scores = jnp.einsum("bsgqk,btgk->bgqst", qg, k,
                        preferred_element_type=jnp.float32)
    return scores / jnp.sqrt(jnp.float32(hd)).astype(jnp.float32)


def _gqa_out(probs: jax.Array, v: jax.Array, wo: jax.Array) -> jax.Array:
    """probs (B,KV,qpk,S,T) x v (B,T,KV,hd) -> (B,S,d)."""
    ctx = jnp.einsum("bgqst,btgk->bsgqk", probs, v)
    b, s, g, qpk, hd = ctx.shape
    ctx = ctx.reshape(b, s, g * qpk, hd).astype(wo.dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx, wo)
    return shard_activation(out, "batch", "seq", None)


def attention(p: AttnParams, x: jax.Array, cfg: ModelConfig,
              window: int = 0) -> jax.Array:
    """Causal self-attention over x (B,S,d); window>0 = sliding window."""
    out, _ = _attention_impl(p, x, cfg, window, want_cache=False)
    return out


def prefill_attention(p: AttnParams, x: jax.Array, cfg: ModelConfig,
                      window: int = 0) -> tuple[jax.Array, KVCache]:
    """Causal attention that also emits the KV cache for decode.

    Global layers cache all S positions. Sliding-window layers cache the last
    ``window`` positions laid out in ring-buffer order (position t at slot
    t %% window) so ``decode_attention`` continues seamlessly at index S.
    """
    return _attention_impl(p, x, cfg, window, want_cache=True)


def _attention_impl(p: AttnParams, x: jax.Array, cfg: ModelConfig,
                    window: int, want_cache: bool):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, positions, cfg)
    if cfg.attn_chunk and s > cfg.attn_chunk:
        out = _chunked_causal_attention(q, k, v, p.wo, cfg, window)
    else:
        scores = _gqa_scores(q, k, cfg)
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        mask = j <= i
        if window:
            mask &= (i - j) < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v, p.wo)
    cache = None
    if want_cache:
        if window and s >= window:
            offset = (s - window) % window
            kc = jnp.roll(k[:, s - window:], offset, axis=1)
            vc = jnp.roll(v[:, s - window:], offset, axis=1)
        else:
            kc, vc = k, v
        cache = KVCache(k=kc.astype(jnp.bfloat16), v=vc.astype(jnp.bfloat16))
    return out, cache


def _chunked_causal_attention(q, k, v, wo, cfg: ModelConfig,
                              window: int) -> jax.Array:
    """Flash-style tiled attention (beyond-paper §Perf optimization).

    Double scan — outer over query chunks, inner over KV chunks with the
    online-softmax recurrence — so the (S, T) score matrix never
    materializes: peak extra memory is one (B, KV, qpk, Qc, Tc) tile. The
    inner body is rematerialized, so backward recomputes score tiles instead
    of saving them. Enabled via ``cfg.attn_chunk``.
    """
    b, s, h, hd = q.shape
    kv = cfg.num_kv_heads
    qpk = cfg.q_per_kv
    qc = min(cfg.attn_chunk, s)
    tc = min(cfg.attn_chunk, s)
    assert s % qc == 0 and s % tc == 0, (s, qc, tc)
    nq, nt = s // qc, s // tc
    scale = 1.0 / np.sqrt(hd)

    qg = q.reshape(b, nq, qc, kv, qpk, hd)
    kg = k.reshape(b, nt, tc, kv, hd)
    vg = v.reshape(b, nt, tc, kv, hd)

    def q_block(qi, q_tile):
        # q_tile: (B, Qc, KV, qpk, hd)
        m0 = jnp.full((b, kv, qpk, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, qpk, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, qpk, qc, hd), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, vj, tj = inputs  # (B, Tc, KV, hd), (B, Tc, KV, hd), scalar
            sc = jnp.einsum("bqgph,btgh->bgpqt", q_tile, kj,
                            preferred_element_type=jnp.float32) * scale
            qpos = qi * qc + jnp.arange(qc)[:, None]
            kpos = tj * tc + jnp.arange(tc)[None, :]
            mask = kpos <= qpos
            if window:
                mask &= (qpos - kpos) < window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(sc - m_new[..., None])
            l = l * alpha + jnp.sum(pr, axis=-1)
            pv = jnp.einsum("bgpqt,btgh->bgpqh", pr.astype(vj.dtype), vj)
            acc = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (m_new, l, acc), None

        body = jax.checkpoint(kv_step, prevent_cse=False)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0),
             jnp.arange(nt)), unroll=cfg.scan_unroll)
        out = acc / jnp.maximum(l[..., None], 1e-30)   # (B,KV,qpk,Qc,hd)
        return jnp.moveaxis(out, 3, 1)                  # (B,Qc,KV,qpk,hd)

    tiles = []
    for qi in range(nq):  # static unroll keeps per-tile HLO simple
        tiles.append(q_block(qi, qg[:, qi]))
    ctx = jnp.concatenate(tiles, axis=1) if nq > 1 else tiles[0]
    ctx = ctx.reshape(b, s, h, hd).astype(wo.dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx, wo)
    return shard_activation(out, "batch", "seq", None)


def cross_attention(p: AttnParams, x: jax.Array, mem_k: jax.Array,
                    mem_v: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (B,T,KV,hd)."""
    b, s, _ = x.shape
    positions = jnp.zeros((b, s), jnp.int32)  # no RoPE offset on cross-attn
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    if p.bq is not None:
        q = q + p.bq
    q = shard_activation(q, "batch", None, "heads", None)
    scores = _gqa_scores(q, mem_k, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, mem_v, p.wo)


def project_memory_kv(p: AttnParams, mem: jax.Array) -> tuple[jax.Array, jax.Array]:
    k = jnp.einsum("btd,dgk->btgk", mem, p.wk)
    v = jnp.einsum("btd,dgk->btgk", mem, p.wv)
    if p.bk is not None:
        k, v = k + p.bk, v + p.bv
    return k, v


# --------------------------------------------------------------------------
# cached decode
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_logical() -> KVCache:
    return KVCache(k=("batch", None, "kv_heads", None),
                   v=("batch", None, "kv_heads", None))


def decode_attention(p: AttnParams, x: jax.Array, cache: KVCache,
                     index: jax.Array, cfg: ModelConfig,
                     window: int = 0) -> tuple[jax.Array, KVCache]:
    """One-token step. x: (B,1,d); index: current position — a scalar
    (lockstep batch; dry-run serve_step) or per-row (B,) vector (continuous
    batching in the serve engine).

    For sliding-window layers the cache is a ring buffer of size ``window``;
    for global layers it holds the full context.
    """
    b = x.shape[0]
    per_row = index.ndim == 1
    idx_rows = (index if per_row else jnp.broadcast_to(index, (b,))).astype(jnp.int32)
    positions = idx_rows[:, None]
    q, k_new, v_new = _project_qkv(p, x, positions, cfg)
    max_len = cache.k.shape[1]
    slots = idx_rows % jnp.int32(max_len) if window else idx_rows
    if per_row:
        rows = jnp.arange(b)
        k = cache.k.at[rows, slots].set(k_new[:, 0].astype(cache.k.dtype))
        v = cache.v.at[rows, slots].set(v_new[:, 0].astype(cache.v.dtype))
    else:
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, slots[0], 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, slots[0], 0, 0))
    scores = _gqa_scores(q, k, cfg)  # (B,KV,qpk,1,S_max)
    t = jnp.arange(max_len)[None, :]
    if window:
        # ring: every slot is live once the context has wrapped
        valid = (t <= slots[:, None]) | (idx_rows[:, None] >= jnp.int32(max_len))
    else:
        valid = t <= idx_rows[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, p.wo)
    return out, KVCache(k=k, v=v)
