"""Shared model substrate: config, params-as-pytrees, norms, RoPE, embeddings.

Models are pure pytrees + functions (no framework): ``init(key) -> params``
builds (or abstractly describes, via ``jax.eval_shape``) the parameters;
forward functions are pure. Layers stack along a leading axis and run under
``jax.lax.scan`` so compile time is O(1) in depth — a hard requirement for
lowering grok/arctic at 512 devices on a CPU host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard_activation

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    local_global_period: int = 0     # gemma3: period length; last layer global
    attn_chunk: int = 0              # >0: flash-style tiled attention
    # moe
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0                # expert hidden dim (defaults to d_ff)
    moe_every: int = 1               # MoE on layers where (i % moe_every)==moe_offset
    moe_offset: int = 0
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    moe_group_size: int = 4096
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_period: int = 0             # jamba: 1 attention layer per this many
    attn_offset: int = 0             # position of the attention layer in period
    # encoder-decoder
    encoder_layers: int = 0
    # frontends (stubbed modalities)
    frontend: str = "none"           # none | patches | frames
    frontend_tokens: int = 0         # prefix positions fed by the stub frontend
    # misc
    norm_eps: float = 1e-5
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    param_dtype: Any = jnp.bfloat16
    fsdp_params: bool = False        # giant models: extra data-axis sharding
    # Dry-run/roofline mode: fully unroll the layer scan so XLA's
    # HloCostAnalysis (which visits while-loop bodies once) reports true
    # per-step FLOPs/bytes. Training keeps the scan (compile-time O(1)).
    scan_unroll: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def is_moe_layer(self, i: int) -> bool:
        return (self.num_experts > 0
                and i % self.moe_every == self.moe_offset % self.moe_every)

    def is_attn_layer(self, i: int) -> bool:
        """hybrid: which layers use attention (vs Mamba); dense: all."""
        if self.family == "ssm":
            return False
        if self.attn_period:
            return i % self.attn_period == self.attn_offset
        return True

    def is_global_attn_layer(self, i: int) -> bool:
        """gemma3-style local:global interleave; others: all global unless
        sliding_window set without a period (then all local)."""
        if not self.local_global_period:
            return self.sliding_window == 0
        return (i + 1) % self.local_global_period == 0


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def stack_layer_init(per_layer_init, num_layers: int, key):
    """Initialize L layers as stacked leaves: leaf shape (L, ...)."""
    keys = jax.random.split(key, num_layers)
    return jax.vmap(per_layer_init)(keys)


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(dt) * gamma


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_tokens(embedding: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(embedding, tokens, axis=0)
    return shard_activation(out, "batch", "seq", None)


def lm_logits(x: jax.Array, embedding: jax.Array,
              head: Optional[jax.Array]) -> jax.Array:
    """Final projection; f32 logits, vocab-sharded."""
    w = embedding.T if head is None else head
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return shard_activation(logits, "batch", None, "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in f32; mask selects contributing positions."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
