"""Mamba2 (SSD — state-space duality) mixer: chunked dual-form training /
prefill and O(1) recurrent decode.

The chunked algorithm follows arXiv:2405.21060: within chunks of length Q the
dual "attention-like" form runs as masked matmuls (MXU-friendly); across
chunks a ``lax.scan`` carries the (H, P, N) SSM state. Decode is the pure
recurrence. All state math in f32 (decays exp(a), a <= 0).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_activation

from .common import ModelConfig, dense_init


class SSMParams(NamedTuple):
    in_proj: jax.Array    # (d, 2*d_inner + 2*N + H)
    conv_w: jax.Array     # (4, d_inner + 2*N) depthwise causal conv
    dt_bias: jax.Array    # (H,)
    a_log: jax.Array      # (H,)
    d_skip: jax.Array     # (H,)
    norm_g: jax.Array     # (d_inner,)
    out_proj: jax.Array   # (d_inner, d)


class SSMCache(NamedTuple):
    conv: jax.Array       # (B, 3, d_inner + 2*N) last inputs
    state: jax.Array      # (B, H, P, N) f32


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_state, cfg.ssm_head_dim


def init_ssm(key, cfg: ModelConfig) -> SSMParams:
    d_inner, heads, n, _ = _dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = d_inner + 2 * n
    return SSMParams(
        in_proj=dense_init(ks[0], (cfg.d_model, 2 * d_inner + 2 * n + heads),
                           cfg.param_dtype),
        conv_w=dense_init(ks[1], (4, conv_ch), cfg.param_dtype, scale=0.5),
        dt_bias=jnp.zeros((heads,), jnp.float32),
        a_log=jnp.zeros((heads,), jnp.float32),
        d_skip=jnp.ones((heads,), jnp.float32),
        norm_g=jnp.ones((d_inner,), cfg.param_dtype),
        out_proj=dense_init(ks[3], (d_inner, cfg.d_model), cfg.param_dtype),
    )


def ssm_param_logical() -> SSMParams:
    return SSMParams(in_proj=(None, "inner"), conv_w=(None, "inner"),
                     dt_bias=(None,), a_log=(None,), d_skip=(None,),
                     norm_g=("inner",), out_proj=("inner", None))


def _split_proj(p: SSMParams, x: jax.Array, cfg: ModelConfig):
    d_inner, heads, n, _ = _dims(cfg)
    zxbcdt = jnp.einsum("bld,de->ble", x, p.in_proj)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n:].astype(jnp.float32)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel 4, over (B, L, C)."""
    pad = jnp.pad(xbc, ((0, 0), (3, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(4))
    return jax.nn.silu(out)


def _rmsnorm_gated(y: jax.Array, z: jax.Array, g: jax.Array, eps: float):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    scale = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return (y * scale).astype(g.dtype) * g


def ssm_forward(p: SSMParams, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    out, _ = ssm_forward_with_cache(p, x, cfg, want_cache=False)
    return out


def ssm_forward_with_cache(p: SSMParams, x: jax.Array, cfg: ModelConfig,
                           want_cache: bool = True):
    """Chunked SSD over x (B, L, d). Ragged tails are zero-padded to the
    chunk size (zero inputs contribute nothing to the state; padded outputs
    are sliced off)."""
    d_inner, heads, n, hp = _dims(cfg)
    b, l_orig, _ = x.shape
    x_orig = x
    q = min(cfg.ssm_chunk, l_orig)
    pad = (-l_orig) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    l = l_orig + pad
    nchunks = l // q

    z, xbc, dt = _split_proj(p, x, cfg)
    xbc = _causal_conv(xbc, p.conv_w)
    xin = xbc[..., :d_inner]
    bmat = xbc[..., d_inner:d_inner + n].astype(jnp.float32)      # (B,L,N)
    cmat = xbc[..., d_inner + n:].astype(jnp.float32)             # (B,L,N)
    dt = jax.nn.softplus(dt + p.dt_bias)                          # (B,L,H)
    if pad:
        # Padded steps must neither decay the state (a = dt*A -> 0) nor
        # contribute to it (contribution is dt-scaled) — zero their dt.
        live = (jnp.arange(l) < l_orig).astype(dt.dtype)
        dt = dt * live[None, :, None]
    a = -jnp.exp(p.a_log)                                         # (H,)
    xh = xin.reshape(b, l, heads, hp).astype(jnp.float32)         # (B,L,H,P)
    xh = shard_activation(xh, "batch", None, "inner", None)

    # chunked layout
    dtc = dt.reshape(b, nchunks, q, heads)
    ac = dtc * a[None, None, None, :]                             # log-decay/step
    cum = jnp.cumsum(ac, axis=2)                                  # (B,NC,Q,H)
    total = cum[:, :, -1:, :]                                     # (B,NC,1,H)
    bc = bmat.reshape(b, nchunks, q, n)
    cc = cmat.reshape(b, nchunks, q, n)
    xc = xh.reshape(b, nchunks, q, heads, hp)

    # intra-chunk (dual/attention form)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)                # (B,NC,Q,Q)
    ii = jnp.arange(q)[:, None]
    jj = jnp.arange(q)[None, :]
    causal = (jj <= ii)[None, None, :, :, None]                   # (1,1,Q,Q,1)
    decay = jnp.exp(jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :],
                             -60.0, 0.0))                         # (B,NC,Q,Q,H)
    gate = jnp.where(causal, scores[..., None] * decay, 0.0)
    gate = gate * dtc[:, :, None, :, :]                           # weight dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", gate, xc)

    # inter-chunk state scan
    in_decay = jnp.exp(jnp.clip(total - cum, -60.0, 0.0))         # (B,NC,Q,H)
    state_in = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                          in_decay * dtc, bc, xc)                 # per-chunk contrib
    chunk_decay = jnp.exp(jnp.clip(total[:, :, 0, :], -60.0, 0.0))  # (B,NC,H)

    def scan_chunk(state, inputs):
        contrib, cdecay = inputs  # (B,H,P,N), (B,H)
        new_state = state * cdecay[:, :, None, None] + contrib
        return new_state, state  # emit the state *entering* the chunk

    state0 = jnp.zeros((b, heads, hp, n), jnp.float32)
    final_state, states = jax.lax.scan(
        scan_chunk, state0,
        (jnp.moveaxis(state_in, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    states = jnp.moveaxis(states, 0, 1)                           # (B,NC,H,P,N)

    out_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))                # (B,NC,Q,H)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, states, out_decay)

    y = (y_intra + y_inter).reshape(b, l, heads, hp)
    y = y + p.d_skip[None, None, :, None] * xh
    y = y.reshape(b, l, d_inner)[:, :l_orig]
    y = _rmsnorm_gated(y, z[:, :l_orig], p.norm_g, cfg.norm_eps)
    out = jnp.einsum("bld,de->ble", y.astype(p.out_proj.dtype), p.out_proj)
    out = shard_activation(out, "batch", "seq", None)
    cache = None
    if want_cache:
        # conv state: last 3 *pre-conv* projected inputs (of the real, unpadded
        # sequence); ssm state: final carry. Note the carry includes padded
        # positions' contributions, which are zero by construction.
        _, xbc_raw, _ = _split_proj(p, x_orig[:, -3:, :], cfg)
        cache = SSMCache(conv=xbc_raw.astype(p.conv_w.dtype), state=final_state)
    return out, cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int) -> SSMCache:
    d_inner, heads, n, hp = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, 3, d_inner + 2 * n), cfg.param_dtype),
        state=jnp.zeros((batch, heads, hp, n), jnp.float32),
    )


def ssm_cache_logical() -> SSMCache:
    return SSMCache(conv=("batch", None, "inner"),
                    state=("batch", "inner", None, None))


def ssm_decode_step(p: SSMParams, x: jax.Array, cache: SSMCache,
                    cfg: ModelConfig) -> tuple[jax.Array, SSMCache]:
    """One token: x (B, 1, d) -> (B, 1, d) with recurrent state update."""
    d_inner, heads, n, hp = _dims(cfg)
    b = x.shape[0]
    z, xbc, dt = _split_proj(p, x, cfg)                           # seq len 1
    hist = jnp.concatenate([cache.conv, xbc], axis=1)             # (B,4,C)
    conv = sum(hist[:, i, :] * p.conv_w[i][None, :] for i in range(4))
    conv = jax.nn.silu(conv)                                      # (B,C)
    xin = conv[:, :d_inner]
    bvec = conv[:, d_inner:d_inner + n].astype(jnp.float32)
    cvec = conv[:, d_inner + n:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0] + p.dt_bias)                   # (B,H)
    a = -jnp.exp(p.a_log)
    alpha = jnp.exp(dtv * a[None, :])                             # (B,H)
    xhead = xin.reshape(b, heads, hp).astype(jnp.float32)
    state = (cache.state * alpha[:, :, None, None]
             + jnp.einsum("bh,bn,bhp->bhpn", dtv, bvec, xhead))
    y = jnp.einsum("bn,bhpn->bhp", cvec, state)
    y = y + p.d_skip[None, :, None] * xhead
    y = y.reshape(b, 1, d_inner)
    y = _rmsnorm_gated(y, z, p.norm_g, cfg.norm_eps)
    out = jnp.einsum("bld,de->ble", y.astype(p.out_proj.dtype), p.out_proj)
    return out, SSMCache(conv=hist[:, 1:, :], state=state)
