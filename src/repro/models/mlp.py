"""Feed-forward layers: SwiGLU/GeLU MLP and capacity-based top-k MoE.

MoE uses the grouped one-hot dispatch formulation (T5X/Mixtral-style): tokens
are processed in groups of ``moe_group_size``; within a group, top-k routing
builds a (tokens, experts, capacity) dispatch tensor and two einsums move
tokens to experts and back. Dispatch overhead per token scales with group
size — the per-arch ``moe_group_size`` keeps it <15% of expert FLOPs (see
DESIGN.md). Experts shard over the "experts" logical axis when divisible
(arctic: 128/16), else the expert FFN dim takes tensor parallelism (grok:
8 experts, d_ff 32768/16) — resolved automatically by repro.dist.sharding.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_activation

from .common import ModelConfig, dense_init


class MLPParams(NamedTuple):
    w_in: jax.Array    # (d, ff) gate/up fused for swiglu: (d, 2*ff)
    w_out: jax.Array   # (ff, d)


class MoEParams(NamedTuple):
    router: jax.Array          # (d, E)
    w_in: jax.Array            # (E, d, 2*ff or ff)
    w_out: jax.Array           # (E, ff, d)
    dense: Optional[MLPParams]  # arctic's parallel dense residual


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> MLPParams:
    d_ff = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    width = 2 * d_ff if cfg.act == "swiglu" else d_ff
    return MLPParams(
        w_in=dense_init(k1, (cfg.d_model, width), cfg.param_dtype),
        w_out=dense_init(k2, (d_ff, cfg.d_model), cfg.param_dtype),
    )


def mlp_param_logical() -> MLPParams:
    return MLPParams(w_in=(None, "ff"), w_out=("ff", None))


def init_moe(key, cfg: ModelConfig) -> MoEParams:
    e = cfg.num_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    width = 2 * ff if cfg.act == "swiglu" else ff
    return MoEParams(
        router=dense_init(k1, (cfg.d_model, e), jnp.float32),
        w_in=dense_init(k2, (e, cfg.d_model, width), cfg.param_dtype),
        w_out=dense_init(k3, (e, ff, cfg.d_model), cfg.param_dtype),
        dense=init_mlp(k4, cfg) if cfg.dense_residual else None,
    )


def moe_param_logical(cfg: ModelConfig) -> MoEParams:
    return MoEParams(
        router=(None, None),
        w_in=("experts", None, "expert_ff"),
        w_out=("experts", "expert_ff", None),
        dense=mlp_param_logical() if cfg.dense_residual else None,
    )


def _act(h: jax.Array, act: str, d_ff: int) -> jax.Array:
    if act == "swiglu":
        gate, up = h[..., :d_ff], h[..., d_ff:]
        return jax.nn.silu(gate) * up
    return jax.nn.gelu(h)


def mlp(p: MLPParams, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    d_ff = p.w_out.shape[0]
    h = jnp.einsum("bsd,df->bsf", x, p.w_in)
    h = shard_activation(h, "batch", None, "ff")
    h = _act(h, cfg.act, d_ff)
    out = jnp.einsum("bsf,fd->bsd", h, p.w_out)
    return shard_activation(out, "batch", "seq", None)


def moe(p: MoEParams, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k MoE over x (B, S, d)."""
    b, s, d = x.shape
    e = cfg.num_experts
    topk = cfg.experts_per_tok
    ff = cfg.moe_d_ff or cfg.d_ff
    t = b * s
    g = max(1, min(cfg.moe_group_size, t))
    while t % g:  # largest divisor of T <= moe_group_size (trace-time loop)
        g -= 1
    ng = t // g
    xg = x.reshape(ng, g, d)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), p.router)
    weights, experts = jax.lax.top_k(logits, topk)          # (ng, g, topk)
    weights = jax.nn.softmax(weights, axis=-1)

    cap = int(g * topk / e * cfg.capacity_factor)
    cap = max(cap, topk)
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.float32)  # (ng, g, topk, e)
    # position of each (token, choice) in its expert's buffer
    pos = jnp.cumsum(onehot.reshape(ng, g * topk, e), axis=1).reshape(
        ng, g, topk, e) * onehot - 1.0
    keep = (pos < cap) & (onehot > 0)
    pos = jnp.where(keep, pos, 0.0).astype(jnp.int32)
    # (ng, g, topk, e, cap): 1 where (token, choice) lands in (expert, slot);
    # already masked by keep (capacity overflow drops the token's choice).
    poshot = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.sum(poshot, axis=2)                       # (ng, g, e, cap)
    combine = jnp.sum(weights[..., None, None] * poshot, axis=2)

    # Group dim is batch-major: shard it over ("pod","data") so the
    # dispatched tensors stay data-parallel. (Leaving it unsharded
    # replicates xe/h/ye on every device — at grok-1 scale that costs
    # ~23 TB/device/step of all-gathers; see EXPERIMENTS.md §Perf iter 1.)
    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg.astype(jnp.float32))
    xe = shard_activation(xe.astype(x.dtype), "batch", "experts", None, None)
    h = jnp.einsum("necd,edf->necf", xe, p.w_in)
    h = shard_activation(h, "batch", "experts", None, "expert_ff")
    h = _act(h, cfg.act, ff)
    ye = jnp.einsum("necf,efd->necd", h, p.w_out)
    ye = shard_activation(ye, "batch", "experts", None, None)
    y = jnp.einsum("ngec,necd->ngd", combine, ye.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(b, s, d)
    y = shard_activation(y, "batch", "seq", None)
    if p.dense is not None:
        y = y + mlp(p.dense, x, cfg)
    return y
