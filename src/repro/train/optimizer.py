"""AdamW with warmup-cosine schedule, global-norm clipping and
ZeRO-sharded f32 moments (sharding specs come from repro.dist.sharding).

State pytree mirrors params: {"m": f32, "v": f32, "step": i32 scalar}.
Parameters stay in their compute dtype (bf16 by default); moments are f32.
The update is elementwise, so GSPMD freely reshards the moments finer than
the params (ZeRO-1 semantics: reduce-scatter grads -> sharded update ->
all-gather params happens automatically from the sharding constraints).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 cfg: AdamWConfig) -> tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, new_state, metrics
