"""The jitted train step + sharding derivation.

``train_shardings`` turns a model's logical parameter annotations into
concrete NamedShardings for params, optimizer state and batch under the
active mesh — including the FSDP extension for giant configs and the
ZeRO-style moment sharding. ``make_train_step`` builds the jit-able
(params, opt_state, batch) -> (params, opt_state, metrics) function with
optional gradient-accumulation microbatching via ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import MeshRules, _resolve, opt_state_sharding
from repro.models.registry import ModelApi

from .optimizer import AdamWConfig, adamw_update

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1


def _is_logical(v) -> bool:
    return (isinstance(v, tuple) and not hasattr(v, "_fields")
            and all(x is None or isinstance(x, str) for x in v))


def param_shardings(api: ModelApi, mr: MeshRules) -> PyTree:
    """NamedShardings for every parameter from the model's logical names."""
    logical = api.param_logical()
    shapes = api.abstract_params()

    def one(names, shape):
        spec = _resolve(shape.shape, names, mr)
        if api.cfg.fsdp_params:
            # extend with data/pod axes on the largest replicated dim
            return opt_state_sharding(spec, shape.shape, mr)
        return NamedSharding(mr.mesh, spec)

    return jax.tree.map(one, logical, shapes, is_leaf=_is_logical)


def opt_shardings(api: ModelApi, mr: MeshRules, p_shardings: PyTree) -> PyTree:
    shapes = api.abstract_params()

    def one(sh, shape):
        return opt_state_sharding(sh.spec, shape.shape, mr)

    moments = jax.tree.map(one, p_shardings, shapes)
    return {"m": moments, "v": moments,
            "step": NamedSharding(mr.mesh, P())}


def batch_shardings(batch_specs: dict, mr: MeshRules) -> dict:
    out = {}
    for k, v in batch_specs.items():
        names = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mr.mesh, _resolve(v.shape, names, mr))
    return out


def train_shardings(api: ModelApi, mr: MeshRules, batch_specs: dict) -> dict:
    ps = param_shardings(api, mr)
    return {
        "params": ps,
        "opt_state": opt_shardings(api, mr, ps),
        "batch": batch_shardings(batch_specs, mr),
    }


def snapshot_for_checkpoint(state: PyTree) -> PyTree:
    """Device→host snapshot of train state for asynchronous checkpointing.

    Every leaf is copied into a fresh host array, so the returned tree
    aliases no device buffer: the next ``train_step`` may overwrite or
    donate its inputs while the checkpoint manager's background encode is
    still reading the snapshot. ``CheckpointManager.save_async`` performs
    an equivalent copy while flattening, so calling this is only required
    when the snapshot must be taken *earlier* than the save call (e.g. at
    a step boundary, with the save deferred past a metrics sync)."""
    return jax.tree.map(lambda x: np.array(jax.device_get(x)), state)


def make_train_step(api: ModelApi, tc: Optional[TrainConfig] = None):
    tc = tc or TrainConfig()

    def loss_fn(params, batch):
        return api.train_loss(params, batch)

    def train_step(params, opt_state, batch):
        if tc.microbatches > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gacc, grads)
                return (gacc, lacc + loss), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape((tc.microbatches,
                                     x.shape[0] // tc.microbatches) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mb_batch)
            inv = 1.0 / tc.microbatches
            grads = jax.tree.map(lambda g: g * inv, gsum)
            loss = lsum * inv
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  tc.opt)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
