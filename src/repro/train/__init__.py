"""Training substrate: AdamW + ZeRO-sharded state, schedules, train step."""
from .optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .train_step import TrainConfig, make_train_step, train_shardings  # noqa: F401
