"""Deterministic, restart-safe data pipeline.

Production concerns baked in:
* **Determinism / restartability**: batches are a pure function of
  (seed, step) — after a failure + checkpoint restore, the pipeline resumes
  at the right step with zero state to persist beyond the step counter.
  This is what makes the CP-LRC checkpoint-repair path sufficient for full
  job recovery.
* **Host sharding**: each host materializes only its slice of the global
  batch (``process_index``/``process_count``), matching the batch's
  ("pod", "data") sharding.
* Two sources: synthetic LM tokens (zipf-ish unigram mix so losses move)
  and a packed-documents mode over an on-disk token file.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"       # "synthetic" | "file"
    path: Optional[str] = None    # token file (uint16/uint32 raw) for "file"
    frontend: str = "none"        # mirror of the model's stub frontend
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Zipf-mixture synthetic token stream; batch = f(seed, step, host)."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        self.cfg = cfg
        if cfg.global_batch % process_count:
            raise ValueError("global batch must divide process count")
        self.local_batch = cfg.global_batch // process_count
        self.process_index = process_index

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.process_index]))
        # zipf-ish unigram distribution makes the LM loss learnable
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        shape = (self.local_batch, cfg.seq_len + 1)
        toks = rng.choice(cfg.vocab_size, size=shape, p=probs).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend == "frames":
            out["frames"] = rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.d_model)).astype(np.float32)
        elif cfg.frontend == "patches":
            out["prefix_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.frontend_tokens, cfg.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileLM(SyntheticLM):
    """Packed-document reader: strided windows over a raw token file."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        super().__init__(cfg, process_index, process_count)
        if not cfg.path:
            raise ValueError("file pipeline needs cfg.path")
        self.tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        n = len(self.tokens) - cfg.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.process_index]))
        starts = rng.integers(0, n, size=self.local_batch)
        rows = np.stack([self.tokens[s:s + cfg.seq_len + 1] for s in starts])
        rows = (rows % cfg.vocab_size).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_pipeline(cfg: DataConfig, process_index: int = 0,
                  process_count: int = 1) -> SyntheticLM:
    if cfg.kind == "file":
        return FileLM(cfg, process_index, process_count)
    return SyntheticLM(cfg, process_index, process_count)
