"""Sharding specs for decode caches, dispatched on cache node types."""
from __future__ import annotations

from jax.sharding import NamedSharding

from repro.dist.sharding import MeshRules, _resolve
from repro.models.attention import KVCache
from repro.models.ssm import SSMCache


def cache_shardings(caches, mr: MeshRules):
    """Map a cache pytree (ShapeDtypeStructs) to NamedShardings.

    Layer-stacked attention KV: (R, B, L, KV, hd) -> batch + kv_heads.
    SSM conv (R, B, 3, C) -> batch + inner; SSM state (R, B, H, P, N) ->
    batch + inner(on H). Encoder-decoder memory tuples look like KV leaves
    (rank-5 bf16) and take the KV layout.
    """

    def walk(node):
        if isinstance(node, KVCache):
            return KVCache(k=_ns(node.k, mr, kv=True), v=_ns(node.v, mr, kv=True))
        if isinstance(node, SSMCache):
            return SSMCache(
                conv=NamedSharding(mr.mesh, _resolve(
                    node.conv.shape, (None, "batch", None, "inner"), mr)),
                state=NamedSharding(mr.mesh, _resolve(
                    node.state.shape, (None, "batch", "inner", None, None), mr)),
            )
        if isinstance(node, (list, tuple)):
            t = type(node)
            if hasattr(node, "_fields"):  # other namedtuples
                return t(*(walk(x) for x in node))
            return t(walk(x) for x in node)
        # bare array leaf (e.g. encdec memory): rank-5 KV layout
        return _ns(node, mr, kv=True)

    return walk(caches)


def _ns(leaf, mr: MeshRules, kv: bool):
    # Rank-5 KV: (layers, batch, length, kv_heads, head_dim). "kv_seq" is
    # inert by default; long-context cells map it to ("data",) so a 512k
    # batch=1 cache context-parallel-shards instead of replicating (GSPMD
    # turns the softmax reductions into all-reduces over "data").
    names = (None, "batch", "kv_seq", "kv_heads", None)[:len(leaf.shape)]
    if len(leaf.shape) != 5:
        names = ("batch",) + (None,) * (len(leaf.shape) - 1)
    return NamedSharding(mr.mesh, _resolve(leaf.shape, names, mr))
