"""End-to-end training driver.

On this CPU container it runs reduced (smoke) configs for real — synthetic
data, AdamW, CP-LRC erasure-coded checkpoints, failure-injected restore —
exercising the exact code paths the dry run lowers for the 512-chip mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-every 20 [--kill-host 2]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_model
from repro.data.pipeline import DataConfig, make_pipeline
from repro.dist.sharding import with_rules
from repro.ftx.checkpoint import CheckpointConfig, CheckpointManager
from repro.ftx.stripestore import StoreConfig
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-async", action="store_true",
                    help="asynchronous checkpointing: snapshot the state "
                         "(one host copy), then encode + persist in the "
                         "background while training continues")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-scheme", default="cp-azure")
    ap.add_argument("--kill-host", type=int, default=-1,
                    help="fail this checkpoint host mid-run and restore "
                         "through the CP-LRC repair path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    api = get_model(args.arch, smoke=args.smoke)
    cfg = api.cfg
    mesh = make_host_mesh()
    data = make_pipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, frontend=cfg.frontend,
        frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model,
    ))
    tc = TrainConfig(opt=AdamWConfig(peak_lr=args.lr, warmup_steps=10,
                                     decay_steps=max(args.steps, 20)),
                     microbatches=args.microbatches)
    cm = None
    if args.ckpt_every:
        cm = CheckpointManager(args.ckpt_dir, CheckpointConfig(
            store=StoreConfig(scheme=args.ckpt_scheme, k=8, r=2, p=2,
                              block_size=1 << 18)))

    with with_rules(mesh):
        params = api.init_params(jax.random.key(args.seed))
        opt_state = adamw_init(params)
        step_fn = jax.jit(make_train_step(api, tc), donate_argnums=(0, 1))
        t0 = time.time()
        pending = None                    # (CheckpointFuture, submit step)

        def collect(at_step: int) -> None:
            """Join the in-flight async save and report what it overlapped."""
            nonlocal pending
            if pending is None:
                return
            fut, submit_step = pending
            pending = None
            info = fut.result()
            enc = info["encode"]
            print(f"  [ckpt] step {fut.step}: {info['bytes']/1e6:.1f} MB "
                  f"encoded async in {info['encode_seconds']:.2f}s "
                  f"(train stalled {fut.snapshot_seconds*1e3:.1f}ms for the "
                  f"snapshot, encode overlap {enc['overlap_fraction']:.0%}, "
                  f"{at_step - submit_step} steps ran during encode)",
                  flush=True)

        for step in range(args.steps):
            batch = jax.tree.map(jax.numpy.asarray, data.batch_at(step))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if pending and pending[0].done():
                collect(step)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            if cm and step and step % args.ckpt_every == 0:
                if args.ckpt_async:
                    collect(step)         # at most one save in flight
                    pending = (cm.save_async(
                        step, {"params": params, "opt": opt_state}), step)
                else:
                    info = cm.save(step, {"params": params, "opt": opt_state})
                    print(f"  [ckpt] step {step}: {info['bytes']/1e6:.1f} MB "
                          f"encoded in {info['encode_seconds']:.2f}s",
                          flush=True)
                if args.kill_host >= 0:
                    collect(step)         # seal before failing its hosts
                    print(f"  [ftx ] killing host {args.kill_host}, "
                          f"restoring via CP-LRC repair", flush=True)
                    cm.fail_hosts(step, [args.kill_host])
                    state, tele = cm.restore(
                        step, {"params": params, "opt": opt_state})
                    params = jax.tree.map(jax.numpy.asarray, state["params"])
                    opt_state = jax.tree.map(jax.numpy.asarray, state["opt"])
                    print(f"  [ftx ] restored: {tele}", flush=True)
                    args.kill_host = -1  # once
        collect(args.steps)
        print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
