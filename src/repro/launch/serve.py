"""Serving CLI: the continuous-batching LLM engine, or degraded block reads.

PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --requests 8
PYTHONPATH=src python -m repro.launch.serve --blocks --requests 400

``--blocks`` serves a Zipfian multi-client read load from a demo stripe
store with one failed node: live blocks stream straight from disk, lost
blocks reconstruct inline through the planner (local group first), with
request coalescing and the hot-block cache on — then prints the
degraded-read report (p50/p99, coalescing ratio, cache hit rate).
"""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np


def serve_blocks(args) -> None:
    from repro.ftx import StoreConfig, StripeStore, read_report
    from repro.serve.blocks import BlockServer, zipf_requests

    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2,
                      block_size=args.block_size, pipeline_window=0)
    with tempfile.TemporaryDirectory() as tmp:
        store = StripeStore(Path(tmp) / "store", cfg)
        payload = np.random.default_rng(0).integers(
            0, 256, args.stripes * cfg.k * cfg.block_size, dtype=np.uint8)
        store.put("blob", payload.tobytes())
        store.seal()
        requests = zipf_requests(store, args.requests, seed=1)
        store.fail_node(store.stripes[0].node_of_block[0])
        server = BlockServer(store, clients=args.clients)
        t0 = time.time()
        server.run(requests)
        dt = time.time() - t0
        rep = read_report(store)
        print(f"{len(requests)} reads ({args.clients} clients) in {dt:.2f}s: "
              f"{rep.direct_reads} direct, {rep.degraded_reads} degraded")
        print(f"decode launches {rep.decode_launches} "
              f"(coalescing ratio {rep.coalescing_ratio:.1f}x, "
              f"coalesced {rep.coalesced_reads}, "
              f"cache hit rate {rep.cache_hit_rate:.2f}, "
              f"local fraction {rep.local_decode_fraction:.2f})")
        print(f"latency p50 {rep.p50_ms:.2f}ms p99 {rep.p99_ms:.2f}ms "
              f"({rep.served_bytes} bytes served)")


def serve_model(args) -> None:
    import jax

    from repro.configs import get_model
    from repro.serve.engine import ServeEngine

    api = get_model(args.arch, smoke=True)
    engine = ServeEngine(api, max_batch=args.max_batch, max_len=args.max_len)
    engine.load(api.init_params(jax.random.key(0)))
    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, api.cfg.vocab_size,
                                       int(rng.integers(4, 32))),
                          max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    engine.run()
    toks = sum(len(r.out_tokens) for r in reqs)
    stats = engine.latency_stats()
    print(f"{len(reqs)} requests -> {toks} tokens in {time.time() - t0:.1f}s "
          f"(p50 {stats['p50_ms']:.0f}ms p99 {stats['p99_ms']:.0f}ms)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--blocks", action="store_true",
                    help="serve degraded block reads from a demo stripe "
                         "store instead of the LLM engine")
    ap.add_argument("--stripes", type=int, default=32,
                    help="demo store size for --blocks")
    ap.add_argument("--block-size", type=int, default=4096)
    ap.add_argument("--clients", type=int, default=8,
                    help="front-end reader threads for --blocks")
    args = ap.parse_args()
    if args.blocks:
        serve_blocks(args)
    else:
        serve_model(args)


if __name__ == "__main__":
    main()
