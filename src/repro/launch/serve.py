"""Serving CLI: run the continuous-batching engine on a reduced config.

PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_model
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()
    api = get_model(args.arch, smoke=True)
    engine = ServeEngine(api, max_batch=args.max_batch, max_len=args.max_len)
    engine.load(api.init_params(jax.random.key(0)))
    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, api.cfg.vocab_size,
                                       int(rng.integers(4, 32))),
                          max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    engine.run()
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"{len(reqs)} requests -> {toks} tokens in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
