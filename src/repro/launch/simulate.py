"""Fleet reliability simulation driver.

Runs the event-driven simulator (``repro.sim``) for one scheme/config and
prints a JSON summary; ``--closed-form`` adds the Markov-chain MTTDL for
side-by-side comparison, ``--oracle`` re-runs the pure-Python reference
loop and verifies the batched engine against it bit for bit, and
``--calibrate DIR`` first measures the real repair pipeline's effective
bandwidth on a scratch store under DIR and feeds it into the failure
model.

Usage:
  PYTHONPATH=src python -m repro.launch.simulate --scheme cp-azure \\
      --k 6 --r 2 --p 2 --trials 500 --horizon-hours 8000 \\
      --disk-mttf-hours 200 --bandwidth-gbps 0.002 --closed-form
  PYTHONPATH=src python -m repro.launch.simulate --scheme azure --k 4 \\
      --r 2 --p 1 --trials 50 --horizon-hours 2000 --oracle --events out.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.core.reliability import (HOURS_PER_YEAR, ReliabilityParams,
                                    stripe_mttdl_years)
from repro.core.schemes import make_scheme
from repro.dist.topology import POLICIES, Topology
from repro.ftx.events import to_doc
from repro.sim import (SimParams, UnitHierarchy, calibrated, simulate,
                       simulate_oracle)
from repro.sim.units import COST_MODELS, MODELS


def _replay(args) -> int:
    """``--replay``: drive a real store through a committed failure trace.

    Builds a scratch :class:`~repro.ftx.StripeStore` under the requested
    geometry, fills it with seeded deterministic objects, and replays the
    trace through :func:`repro.ftx.failures.replay_trace` — correlated
    same-timestamp failures repair as one batch, under the requested
    orchestration knobs. The printed JSON carries only deterministic
    fields (simulated time, block/read counts, relocations, rebalance
    moves), so two runs over the same trace are byte-identical — the
    replay-determinism property the golden-file tests pin.
    """
    import tempfile

    import numpy as np

    from repro.ftx.events import load_trace
    from repro.ftx.failures import replay_trace
    from repro.ftx.options import RepairOptions
    from repro.ftx.stripestore import StoreConfig, StripeStore

    nodes = args.nodes or 24
    topo = Topology(num_nodes=nodes, num_domains=args.domains, seed=args.seed)
    cfg = StoreConfig(scheme=args.scheme, k=args.k, r=args.r, p=args.p,
                      block_size=1024, batch_stripes=8,
                      placement_policy=args.policy, seed=args.seed)
    with tempfile.TemporaryDirectory() as scratch:
        root = args.replay_store or scratch
        store = StripeStore(Path(root) / "replay_store", cfg,
                            num_nodes=nodes, topology=topo)
        rng = np.random.default_rng(args.seed)
        for i in range(12):
            store.put(f"obj{i}", rng.integers(
                0, 256, 4 * args.k * cfg.block_size // 5,
                dtype=np.uint8).tobytes())
        store.seal()
        events = load_trace(args.replay)
        res = replay_trace(store, events,
                           options=RepairOptions(
                               schedule=args.schedule,
                               destinations=args.destinations),
                           revive=args.destinations != "topology",
                           rebalance_after=args.rebalance)
    # Simulated seconds accumulate across reader-pool threads, so their
    # float sum can wiggle in the last ulp between runs; round them to a
    # stable precision. Every other replay field is an exact count.
    for row in res["batches"] + [res["totals"]]:
        row["sim_seconds"] = round(row["sim_seconds"], 6)
    out = {
        "scheme": args.scheme, "k": args.k, "r": args.r, "p": args.p,
        "nodes": nodes, "domains": args.domains, "policy": args.policy,
        "trace": args.replay, "trace_events": len(events),
        "schedule": args.schedule or cfg.stripe_schedule,
        "destinations": args.destinations or cfg.rebuild_destinations,
        "batches": res["batches"], "totals": res["totals"],
        "rebalance": res["rebalance"],
    }
    print(json.dumps(out, indent=1, sort_keys=True))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scheme", default="cp-azure")
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--trials", type=int, default=500)
    ap.add_argument("--horizon-hours", type=float, default=8000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model", choices=MODELS, default="paper")
    ap.add_argument("--cost-model", choices=COST_MODELS, default="planner")
    ap.add_argument("--disk-mttf-hours", type=float, default=None,
                    help="mean disk life (default: reliability params' "
                         "node MTTF)")
    ap.add_argument("--weibull-shape", type=float, default=1.0)
    ap.add_argument("--node-burst-hours", type=float, default=0.0)
    ap.add_argument("--rack-burst-hours", type=float, default=0.0)
    ap.add_argument("--lse-hours", type=float, default=0.0)
    ap.add_argument("--scrub-hours", type=float, default=0.0)
    ap.add_argument("--bandwidth-gbps", type=float, default=None)
    ap.add_argument("--nodes", type=int, default=None,
                    help="fleet nodes (default: one per disk)")
    ap.add_argument("--domains", type=int, default=1)
    ap.add_argument("--policy", choices=POLICIES, default="contiguous")
    ap.add_argument("--closed-form", action="store_true",
                    help="also evaluate the Markov-chain MTTDL")
    ap.add_argument("--oracle", action="store_true",
                    help="re-run the pure-Python oracle and verify the "
                         "batched engine bit for bit")
    ap.add_argument("--calibrate", metavar="DIR", default=None,
                    help="measure real repair-pipeline bandwidth on a "
                         "scratch store under DIR and use it")
    ap.add_argument("--events", metavar="OUT.json", default=None,
                    help="record per-trial FleetEvent logs to a file")
    ap.add_argument("--replay", metavar="TRACE.json", default=None,
                    help="replay a FleetEvent trace against a real "
                         "StripeStore with correlated-arrival batching "
                         "(repro.ftx.failures.replay_trace) instead of "
                         "running the simulator")
    ap.add_argument("--replay-store", metavar="DIR", default=None,
                    help="scratch directory for the replay store "
                         "(default: a temp dir)")
    ap.add_argument("--schedule", default=None,
                    choices=("none", "locality", "global"),
                    help="stripe schedule for --replay repairs")
    ap.add_argument("--destinations", default=None,
                    choices=("in_place", "topology"),
                    help="rebuild destinations for --replay repairs")
    ap.add_argument("--rebalance", action="store_true",
                    help="run one rebalance pass after the --replay trace")
    args = ap.parse_args(argv)

    if args.replay:
        return _replay(args)

    scheme = make_scheme(args.scheme, args.k, args.r, args.p)
    rel = ReliabilityParams()
    if args.bandwidth_gbps is not None:
        rel = dataclasses.replace(rel, bandwidth_gbps=args.bandwidth_gbps)
    if args.calibrate:
        from repro.ftx.stripestore import StoreConfig

        from repro.sim import measure_repair_bandwidth
        tele = measure_repair_bandwidth(
            Path(args.calibrate),
            StoreConfig(scheme=args.scheme, k=args.k, r=args.r, p=args.p,
                        block_size=2048))
        rel = calibrated(rel, tele)
        print(f"# measured repair bandwidth: {tele['gbps']:.4f} Gbps",
              file=sys.stderr)
    params = SimParams(
        disk_mttf_hours=(args.disk_mttf_hours if args.disk_mttf_hours
                         else rel.node_mttf_years * HOURS_PER_YEAR),
        weibull_shape=args.weibull_shape,
        node_burst_hours=args.node_burst_hours,
        rack_burst_hours=args.rack_burst_hours,
        lse_hours=args.lse_hours, scrub_hours=args.scrub_hours,
        model=args.model, cost_model=args.cost_model, reliability=rel)
    topo = (Topology(num_nodes=args.nodes, num_domains=args.domains)
            if args.nodes else None)
    hier = UnitHierarchy.from_topology(scheme.n, topo, args.policy)
    kw = dict(trials=args.trials, horizon_hours=args.horizon_hours,
              seed=args.seed, hierarchy=hier,
              record_events=bool(args.events or args.oracle))
    res = simulate(scheme, params, **kw)
    out = {
        "scheme": args.scheme, "k": args.k, "r": args.r, "p": args.p,
        "model": args.model, "cost_model": args.cost_model,
        "trials": res.trials, "horizon_hours": res.horizon_hours,
        "seed": res.seed, "losses": res.losses,
        "observed_hours": res.observed_hours,
        "mttdl_hours": res.mttdl_hours, "mttdl_years": res.mttdl_years,
        "events": res.events, "epochs": res.epochs,
        "event_parallelism": res.event_parallelism,
        "events_per_sec": res.events / max(res.wall_seconds, 1e-9),
        "counts": res.counts, "wall_seconds": res.wall_seconds,
    }
    if args.closed_form:
        # Chain and sim must price failures at the same disk rate.
        chain_rel = dataclasses.replace(
            rel, node_mttf_years=params.disk_mttf_hours / HOURS_PER_YEAR)
        out["closed_form_years"] = stripe_mttdl_years(scheme, chain_rel,
                                                      model=args.model)
        if out["mttdl_years"] != float("inf"):
            out["sim_over_closed_form"] = (out["mttdl_years"]
                                           / out["closed_form_years"])
    if args.oracle:
        ref = simulate_oracle(scheme, params, **kw)
        mismatches = sum(a != b for a, b in zip(res.event_log,
                                                ref.event_log))
        out["oracle"] = {"losses": ref.losses,
                         "observed_hours": ref.observed_hours,
                         "trials_mismatching_engine": mismatches,
                         "bit_identical": mismatches == 0 and
                         res.observed_hours == ref.observed_hours}
        if not out["oracle"]["bit_identical"]:
            print("ERROR: batched engine diverged from the oracle",
                  file=sys.stderr)
    if args.events:
        Path(args.events).write_text(json.dumps(
            [[to_doc(e) for e in trial] for trial in res.event_log]))
        out["events_path"] = args.events
    print(json.dumps(out, indent=1))
    return 1 if args.oracle and not out["oracle"]["bit_identical"] else 0


if __name__ == "__main__":
    sys.exit(main())
