"""Fleet reliability simulation driver.

Runs the event-driven simulator (``repro.sim``) for one scheme/config and
prints a JSON summary; ``--closed-form`` adds the Markov-chain MTTDL for
side-by-side comparison, ``--oracle`` re-runs the pure-Python reference
loop and verifies the batched engine against it bit for bit, and
``--calibrate DIR`` first measures the real repair pipeline's effective
bandwidth on a scratch store under DIR and feeds it into the failure
model.

Usage:
  PYTHONPATH=src python -m repro.launch.simulate --scheme cp-azure \\
      --k 6 --r 2 --p 2 --trials 500 --horizon-hours 8000 \\
      --disk-mttf-hours 200 --bandwidth-gbps 0.002 --closed-form
  PYTHONPATH=src python -m repro.launch.simulate --scheme azure --k 4 \\
      --r 2 --p 1 --trials 50 --horizon-hours 2000 --oracle --events out.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.core.reliability import (HOURS_PER_YEAR, ReliabilityParams,
                                    stripe_mttdl_years)
from repro.core.schemes import make_scheme
from repro.dist.topology import POLICIES, Topology
from repro.ftx.events import to_doc
from repro.sim import (SimParams, UnitHierarchy, calibrated, simulate,
                       simulate_oracle)
from repro.sim.units import COST_MODELS, MODELS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scheme", default="cp-azure")
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--trials", type=int, default=500)
    ap.add_argument("--horizon-hours", type=float, default=8000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model", choices=MODELS, default="paper")
    ap.add_argument("--cost-model", choices=COST_MODELS, default="planner")
    ap.add_argument("--disk-mttf-hours", type=float, default=None,
                    help="mean disk life (default: reliability params' "
                         "node MTTF)")
    ap.add_argument("--weibull-shape", type=float, default=1.0)
    ap.add_argument("--node-burst-hours", type=float, default=0.0)
    ap.add_argument("--rack-burst-hours", type=float, default=0.0)
    ap.add_argument("--lse-hours", type=float, default=0.0)
    ap.add_argument("--scrub-hours", type=float, default=0.0)
    ap.add_argument("--bandwidth-gbps", type=float, default=None)
    ap.add_argument("--nodes", type=int, default=None,
                    help="fleet nodes (default: one per disk)")
    ap.add_argument("--domains", type=int, default=1)
    ap.add_argument("--policy", choices=POLICIES, default="contiguous")
    ap.add_argument("--closed-form", action="store_true",
                    help="also evaluate the Markov-chain MTTDL")
    ap.add_argument("--oracle", action="store_true",
                    help="re-run the pure-Python oracle and verify the "
                         "batched engine bit for bit")
    ap.add_argument("--calibrate", metavar="DIR", default=None,
                    help="measure real repair-pipeline bandwidth on a "
                         "scratch store under DIR and use it")
    ap.add_argument("--events", metavar="OUT.json", default=None,
                    help="record per-trial FleetEvent logs to a file")
    args = ap.parse_args(argv)

    scheme = make_scheme(args.scheme, args.k, args.r, args.p)
    rel = ReliabilityParams()
    if args.bandwidth_gbps is not None:
        rel = dataclasses.replace(rel, bandwidth_gbps=args.bandwidth_gbps)
    if args.calibrate:
        from repro.ftx.stripestore import StoreConfig

        from repro.sim import measure_repair_bandwidth
        tele = measure_repair_bandwidth(
            Path(args.calibrate),
            StoreConfig(scheme=args.scheme, k=args.k, r=args.r, p=args.p,
                        block_size=2048))
        rel = calibrated(rel, tele)
        print(f"# measured repair bandwidth: {tele['gbps']:.4f} Gbps",
              file=sys.stderr)
    params = SimParams(
        disk_mttf_hours=(args.disk_mttf_hours if args.disk_mttf_hours
                         else rel.node_mttf_years * HOURS_PER_YEAR),
        weibull_shape=args.weibull_shape,
        node_burst_hours=args.node_burst_hours,
        rack_burst_hours=args.rack_burst_hours,
        lse_hours=args.lse_hours, scrub_hours=args.scrub_hours,
        model=args.model, cost_model=args.cost_model, reliability=rel)
    topo = (Topology(num_nodes=args.nodes, num_domains=args.domains)
            if args.nodes else None)
    hier = UnitHierarchy.from_topology(scheme.n, topo, args.policy)
    kw = dict(trials=args.trials, horizon_hours=args.horizon_hours,
              seed=args.seed, hierarchy=hier,
              record_events=bool(args.events or args.oracle))
    res = simulate(scheme, params, **kw)
    out = {
        "scheme": args.scheme, "k": args.k, "r": args.r, "p": args.p,
        "model": args.model, "cost_model": args.cost_model,
        "trials": res.trials, "horizon_hours": res.horizon_hours,
        "seed": res.seed, "losses": res.losses,
        "observed_hours": res.observed_hours,
        "mttdl_hours": res.mttdl_hours, "mttdl_years": res.mttdl_years,
        "events": res.events, "epochs": res.epochs,
        "event_parallelism": res.event_parallelism,
        "events_per_sec": res.events / max(res.wall_seconds, 1e-9),
        "counts": res.counts, "wall_seconds": res.wall_seconds,
    }
    if args.closed_form:
        # Chain and sim must price failures at the same disk rate.
        chain_rel = dataclasses.replace(
            rel, node_mttf_years=params.disk_mttf_hours / HOURS_PER_YEAR)
        out["closed_form_years"] = stripe_mttdl_years(scheme, chain_rel,
                                                      model=args.model)
        if out["mttdl_years"] != float("inf"):
            out["sim_over_closed_form"] = (out["mttdl_years"]
                                           / out["closed_form_years"])
    if args.oracle:
        ref = simulate_oracle(scheme, params, **kw)
        mismatches = sum(a != b for a, b in zip(res.event_log,
                                                ref.event_log))
        out["oracle"] = {"losses": ref.losses,
                         "observed_hours": ref.observed_hours,
                         "trials_mismatching_engine": mismatches,
                         "bit_identical": mismatches == 0 and
                         res.observed_hours == ref.observed_hours}
        if not out["oracle"]["bit_identical"]:
            print("ERROR: batched engine diverged from the oracle",
                  file=sys.stderr)
    if args.events:
        Path(args.events).write_text(json.dumps(
            [[to_doc(e) for e in trial] for trial in res.event_log]))
        out["events_path"] = args.events
    print(json.dumps(out, indent=1))
    return 1 if args.oracle and not out["oracle"]["bit_identical"] else 0


if __name__ == "__main__":
    sys.exit(main())
