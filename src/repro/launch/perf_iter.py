import os

if __name__ == "__main__":
    # must precede jax init; guarded against import side effects (see
    # dryrun.py).
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness.

Lowers one (arch x shape) cell under a sequence of named variants (config /
sharding overrides), compiles each, and prints the roofline-term deltas —
the hypothesis -> change -> measure loop of EXPERIMENTS.md §Perf as one
command:

  PYTHONPATH=src python -m repro.launch.perf_iter --arch qwen2.5-3b \
      --shape train_4k --variants baseline,flash2048 [--unroll]
"""
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import SHAPES, get_config, input_specs
from repro.dist.sharding import with_rules
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, wire_bytes
from repro.models.registry import build
from repro.train.train_step import TrainConfig, make_train_step, train_shardings

# variant name -> (config overrides, rule overrides, train overrides)
VARIANTS = {
    "baseline": ({}, None, {}),
    "flash1024": ({"attn_chunk": 1024}, None, {}),
    "flash2048": ({"attn_chunk": 2048}, None, {}),
    "flash4096": ({"attn_chunk": 4096}, None, {}),
    "seqshard": ({}, {"seq": ("model",)}, {}),  # sequence-parallel activations
    "flash2048+seqshard": ({"attn_chunk": 2048}, {"seq": ("model",)}, {}),
    "micro2": ({}, None, {"microbatches": 2}),
    "micro4": ({}, None, {"microbatches": 4}),
    "flash2048+micro4": ({"attn_chunk": 2048}, None, {"microbatches": 4}),
    "nofsdp": ({"fsdp_params": False}, None, {}),
    "flash2048+nofsdp": ({"attn_chunk": 2048, "fsdp_params": False}, None, {}),
    "moegroup512": ({"moe_group_size": 512}, None, {}),
    "flash2048+moegroup512": ({"attn_chunk": 2048, "moe_group_size": 512},
                              None, {}),
}


def measure(arch: str, shape: str, variant: str, *, unroll: bool,
            multi_pod: bool = False) -> dict:
    cfg_over, rules_over, train_over = VARIANTS[variant]
    cfg = dataclasses.replace(get_config(arch), scan_unroll=unroll, **cfg_over)
    api = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = SHAPES[shape]
    tc = TrainConfig(**train_over) if train_over else None
    with with_rules(mesh, rules_over) as mr:
        specs = input_specs(arch, shape)
        if spec.kind == "train":
            from repro.train.optimizer import adamw_init

            step = make_train_step(api, tc)
            sh = train_shardings(api, mr, specs["batch"])
            params_abs = api.abstract_params()
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            jitted = jax.jit(step,
                             in_shardings=(sh["params"], sh["opt_state"],
                                           sh["batch"]),
                             out_shardings=(sh["params"], sh["opt_state"],
                                            None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
        elif spec.kind == "prefill":
            from repro.train.train_step import batch_shardings, param_shardings

            psh = param_shardings(api, mr)
            bsh = batch_shardings(specs["batch"], mr)
            jitted = jax.jit(api.prefill, in_shardings=(psh, bsh))
            lowered = jitted.lower(api.abstract_params(), specs["batch"])
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.dist.sharding import _resolve
            from repro.launch.serve_shardings import cache_shardings
            from repro.train.train_step import param_shardings

            psh = param_shardings(api, mr)
            csh = cache_shardings(specs["caches"], mr)
            tsh = NamedSharding(mr.mesh, _resolve(specs["tokens"].shape,
                                                  ("batch", None), mr))
            jitted = jax.jit(api.decode_step,
                             in_shardings=(psh, csh, tsh,
                                           NamedSharding(mr.mesh, P())),
                             donate_argnums=(1,))
            lowered = jitted.lower(api.abstract_params(), specs["caches"],
                                   specs["tokens"], specs["index"])
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    hbytes = float(cost.get("bytes accessed", 0.0))
    wb = wire_bytes(coll)
    return {
        "variant": variant, "compile_s": round(compile_s, 1),
        "temp_gb": mem.temp_size_in_bytes / 2**30,
        "flops_per_dev": flops, "bytes_per_dev": hbytes,
        "wire_bytes_per_dev": wb,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbytes / HBM_BW,
        "collective_s": wb / LINK_BW,
        "collectives": {k: v for k, v in coll.items() if v["count"]},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline,flash2048")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = []
    for v in args.variants.split(","):
        print(f"[perf] {args.arch}|{args.shape} variant={v} ...", flush=True)
        try:
            r = measure(args.arch, args.shape, v, unroll=args.unroll,
                        multi_pod=args.multi_pod)
        except Exception as e:
            r = {"variant": v, "error": f"{type(e).__name__}: {e}"}
        rows.append(r)
        print(json.dumps(r, indent=1, default=str), flush=True)
    base = next((r for r in rows if r["variant"] == "baseline"
                 and "error" not in r), None)
    if base:
        print("\nvariant            temp_gb  compute_s  memory_s  coll_s")
        for r in rows:
            if "error" in r:
                print(f"{r['variant']:18s} ERROR {r['error'][:60]}")
                continue
            print(f"{r['variant']:18s} {r['temp_gb']:8.1f} "
                  f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
                  f"{r['collective_s']:7.4f}")
    if args.out:
        from pathlib import Path

        Path(args.out).write_text(json.dumps(rows, indent=1, default=str))


if __name__ == "__main__":
    main()
