"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run driver must set XLA_FLAGS
before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips/pod) single-pod, or 2x16x16 = 512 chips multi-pod.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod —
    "pod" carries data parallelism across the pod-interconnect (DCN), "data"
    batch parallelism within a pod, "model" tensor/expert parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist on this host, as a (data, model) mesh — used by
    the CPU examples and smoke tests (typically 1x1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
