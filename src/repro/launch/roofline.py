"""Roofline analysis from dry-run artifacts (deliverable g).

Terms per (arch x shape) cell, all per-chip and in seconds (TPU v5e):

  compute    = HLO_FLOPs / 197e12            (bf16 peak per chip)
  memory     = HLO_bytes / 819e9             (HBM stream bandwidth)
  collective = wire_bytes / 50e9             (one ICI link, conservative)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` of the
*unrolled* dry-run (the scan variant undercounts loop bodies — see
EXPERIMENTS.md §Dry-run); wire_bytes follow the ring models:
2x for all-reduce, output for all-gather / collective-permute, input for
reduce-scatter / all-to-all.

``HLO bytes accessed`` counts every operand+result touch, i.e. an upper
bound on HBM traffic (fusion keeps much of it in VMEM/registers); the memory
term is therefore pessimistic — noted per row.

MODEL_FLOPS uses the classic accounting: train 6·N_active·tokens,
prefill 2·N_active·tokens, decode 2·N_active·batch per step. The
``useful`` column is MODEL_FLOPS / (chips · HLO_FLOPs) — remat & dispatch
overhead shows up here. ``roofline_frac`` = compute / max(all terms): the
fraction of the bounding resource's time spent at peak compute.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"

_WIRE = {"all-reduce": ("in", 2.0), "all-gather": ("out", 1.0),
         "reduce-scatter": ("in", 1.0), "all-to-all": ("in", 1.0),
         "collective-permute": ("out", 1.0)}


def wire_bytes(coll: dict) -> float:
    total = 0.0
    for kind, (field, mult) in _WIRE.items():
        rec = coll.get(kind, {})
        if isinstance(rec, dict):
            total += mult * rec.get(field, 0)
        else:  # legacy scalar format
            total += mult * rec
    return total


def model_flops(arch: str, shape: str) -> float:
    from repro.configs import SHAPES, get_model

    api = get_model(arch)
    spec = SHAPES[shape]
    n_active = api.active_param_count()
    if spec.kind == "train":
        return 6.0 * n_active * spec.seq_len * spec.global_batch
    if spec.kind == "prefill":
        return 2.0 * n_active * spec.seq_len * spec.global_batch
    return 2.0 * n_active * spec.global_batch  # decode: one token / sequence


def _advice(dom: str, row: dict) -> str:
    if dom == "collective":
        k = max(row["coll_detail"], key=lambda kk: row["coll_detail"][kk])
        return (f"dominated by {k}: reshard to turn it into overlapped "
                f"reduce-scatter/all-gather or shrink the payload dtype")
    if dom == "memory":
        if row["useful"] < 0.4:
            return ("HBM-bound with low useful-FLOP ratio: cut remat "
                    "recompute / fuse dispatch einsums")
        return "HBM-bound: fuse elementwise chains, widen arithmetic intensity"
    if row["useful"] < 0.5:
        return "compute-bound but half the FLOPs are overhead: fix remat/dispatch"
    return "near compute roofline: only kernel-level wins left"


def build_table(mesh: str = "single") -> list[dict]:
    from repro.configs import ALIASES, SHAPES, cell_valid

    unrolled = RESULTS_DIR / f"dryrun_{mesh}_unrolled.json"
    scan = RESULTS_DIR / f"dryrun_{mesh}.json"
    data = {}
    if scan.exists():
        data.update(json.loads(scan.read_text()))
    udata = json.loads(unrolled.read_text()) if unrolled.exists() else {}
    rows = []
    for arch in ALIASES:
        for shape in SHAPES:
            key = f"{arch}|{shape}"
            ok, reason = cell_valid(arch, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape, "skip": reason})
                continue
            rec = udata.get(key) or data.get(key)
            if not rec or "cost" not in rec or "flops" not in rec.get("cost", {}):
                rows.append({"arch": arch, "shape": shape,
                             "skip": "no dry-run record"})
                continue
            chips = 1
            for v in rec["mesh"].values():
                chips *= v
            flops = rec["cost"]["flops"]
            hbytes = rec["cost"].get("bytes accessed", 0.0)
            coll = rec.get("collectives", {})
            wb = wire_bytes(coll)
            compute_s = flops / PEAK_FLOPS
            memory_s = hbytes / HBM_BW
            coll_s = wb / LINK_BW
            terms = {"compute": compute_s, "memory": memory_s,
                     "collective": coll_s}
            dom = max(terms, key=terms.get)
            mf = model_flops(arch, shape)
            useful = mf / (chips * flops) if flops else 0.0
            row = {
                "arch": arch, "shape": shape, "kind": rec["kind"],
                "chips": chips, "unrolled": key in udata,
                "flops_per_chip": flops, "bytes_per_chip": hbytes,
                "wire_bytes_per_chip": wb,
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dom,
                "model_flops": mf, "useful": useful,
                "mfu_like": compute_s / max(max(terms.values()), 1e-30),
                "coll_detail": {k: (v.get("out", 0) if isinstance(v, dict)
                                    else v) for k, v in coll.items()},
                "memory_bytes_per_device": rec.get("memory", {}),
            }
            row["advice"] = _advice(dom, row)
            rows.append(row)
    return rows


def render(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'frac':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skip"):
            lines.append(f"{r['arch']:22s} {r['shape']:12s} SKIP: {r['skip']}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['useful']:7.3f} {r['mfu_like']:6.3f}")
    return "\n".join(lines)


def main() -> None:  # pragma: no cover
    print(render(build_table()))


if __name__ == "__main__":
    main()
