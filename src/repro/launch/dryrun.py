import os

if __name__ == "__main__":
    # MUST precede every other import (jax locks the device count on first
    # init). 512 placeholder host devices back the 2x16x16 production mesh.
    # Guarded so importing this module (tests, benchmarks) never mutates the
    # host's device topology.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell, lower + compile the step
function (train_step / prefill / serve_step) against the production mesh
with ShapeDtypeStruct stand-ins (zero allocation), then record:

* memory_analysis()  — per-device bytes: proves the configuration fits;
* cost_analysis()    — per-device HLO FLOPs / bytes for the roofline;
* collective bytes   — parsed from the post-SPMD HLO text, per collective
  kind, for the roofline's interconnect term.

Results cache to benchmarks/results/dryrun_<mesh>.json keyed by cell, so
re-runs only compile missing cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  --arch grok-1-314b --shape train_4k
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ALIASES, SHAPES, cell_valid, get_model, input_specs
from repro.dist.sharding import with_rules
from repro.launch.mesh import make_production_mesh
from repro.train.train_step import TrainConfig, make_train_step, train_shardings

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every typed shape literal in an HLO result type string."""
    total = 0
    for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, dict[str, int]]:
    """Per collective kind: summed input/output bytes + op count (the module
    is the per-device program after SPMD partitioning).

    Wire-byte modeling downstream (repro.launch.roofline): ring algorithms
    move ~2x payload for all-reduce, ~output for all-gather, ~input for
    reduce-scatter / all-to-all, ~output for collective-permute.
    """
    out = {k: {"in": 0, "out": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?\S+\s*=\s*(\([^)]*\)|\S+)\s+([\w-]+)"
                     r"(?:\.\d+)?\((.*)$", ls)
        if not m:
            continue
        result_type, opname, args = m.groups()
        for kind in _COLLECTIVES:
            if opname == kind or opname == kind + "-start":
                rec = out[kind]
                rec["out"] += _shape_bytes(result_type)
                # operand types are printed inline in post-opt HLO; cut at
                # the first ')' (end of the operand list) so attributes /
                # metadata strings can't contribute shape literals. If the
                # printer elides operand types, approximate in == out.
                inb = _shape_bytes(args.split(")")[0])
                rec["in"] += inb if inb else _shape_bytes(result_type)
                rec["count"] += 1
                break
    return out


def lower_cell(arch: str, shape: str, mesh, *, smoke: bool = False,
               tc: TrainConfig | None = None, donate: bool = True,
               extra_rules: dict | None = None, unroll: bool = False):
    """Lower + compile one cell. Returns (record, lowered, compiled).

    ``unroll=True`` fully unrolls the layer scan so cost_analysis reports
    true per-step FLOPs/bytes (XLA visits while bodies once) — used for the
    roofline table; the scan variant stays the production default.
    """
    spec = SHAPES[shape]
    if unroll:
        import dataclasses as _dc

        from repro.configs import get_config
        from repro.models.registry import build
        api = build(_dc.replace(get_config(arch, smoke), scan_unroll=True))
    else:
        api = get_model(arch, smoke=smoke)
    with with_rules(mesh, extra_rules) as mr:
        specs = input_specs(arch, shape, smoke=smoke)
        if spec.kind == "train":
            step = make_train_step(api, tc)
            sh = train_shardings(api, mr, specs["batch"])
            params_abs = api.abstract_params()
            opt_abs = jax.eval_shape(
                lambda p: __import__("repro.train.optimizer", fromlist=["x"])
                .adamw_init(p), params_abs)
            jitted = jax.jit(
                step,
                in_shardings=(sh["params"], sh["opt_state"], sh["batch"]),
                out_shardings=(sh["params"], sh["opt_state"], None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
        elif spec.kind == "prefill":
            from repro.train.train_step import batch_shardings, param_shardings
            psh = param_shardings(api, mr)
            bsh = batch_shardings(specs["batch"], mr)
            jitted = jax.jit(api.prefill, in_shardings=(psh, bsh))
            lowered = jitted.lower(api.abstract_params(), specs["batch"])
        else:  # decode
            from repro.launch.serve_shardings import cache_shardings
            from repro.train.train_step import param_shardings
            from repro.dist.sharding import _resolve
            from jax.sharding import NamedSharding, PartitionSpec as P
            psh = param_shardings(api, mr)
            csh = cache_shardings(specs["caches"], mr)
            tsh = NamedSharding(mr.mesh, _resolve(
                specs["tokens"].shape, ("batch", None), mr))
            ish = NamedSharding(mr.mesh, P())
            jitted = jax.jit(api.decode_step,
                             in_shardings=(psh, csh, tsh, ish),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(api.abstract_params(), specs["caches"],
                                   specs["tokens"], specs["index"])
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    record = {"arch": arch, "shape": shape, "kind": spec.kind,
              "mesh": dict(mesh.shape), "compile_s": round(compile_s, 1)}
    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        record["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float)) and
                          (k in ("flops", "bytes accessed", "optimal_seconds")
                           or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        record["cost"] = {"error": str(e)}
    try:
        record["collectives"] = collective_bytes(compiled.as_text())
    except Exception:
        record["collectives"] = collective_bytes(lowered.as_text())
    return record, lowered, compiled


def run(meshname: str, archs: list[str], shapes: list[str],
        force: bool = False, unroll: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(meshname == "multi"))
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "_unrolled" if unroll else ""
    path = RESULTS_DIR / f"dryrun_{meshname}{suffix}.json"
    results = json.loads(path.read_text()) if path.exists() else {}
    for arch in archs:
        for shape in shapes:
            key = f"{arch}|{shape}"
            ok, reason = cell_valid(arch, shape)
            if not ok:
                results[key] = {"arch": arch, "shape": shape, "skip": reason}
                continue
            if key in results and not force and "error" not in results[key]:
                print(f"[cached] {key}")
                continue
            print(f"[lower ] {key} ...", flush=True)
            t0 = time.time()
            # batch=1 long-context: context-parallel-shard the KV length
            # axis over the idle "data" axis instead of replicating 512k KV.
            extra = ({"kv_seq": ("data",)}
                     if SHAPES[shape].global_batch < 16 else None)
            try:
                record, _, _ = lower_cell(arch, shape, mesh,
                                          extra_rules=extra, unroll=unroll)
                results[key] = record
                print(f"[ok    ] {key} compile={record['compile_s']}s "
                      f"total={time.time() - t0:.0f}s", flush=True)
            except Exception as e:
                results[key] = {"arch": arch, "shape": shape,
                                "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL  ] {key}: {e}\n{traceback.format_exc()}",
                      flush=True)
            path.write_text(json.dumps(results, indent=1, sort_keys=True))
    path.write_text(json.dumps(results, indent=1, sort_keys=True))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact roofline cost counts")
    args = ap.parse_args()
    archs = list(ALIASES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    results = run(args.mesh, archs, shapes, force=args.force,
                  unroll=args.unroll)
    bad = [k for k, v in results.items() if "error" in v]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells ok; "
          f"failures: {bad or 'none'}")


if __name__ == "__main__":
    main()
