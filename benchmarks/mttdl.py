"""Table VI: MTTDL. Calibrated once on Azure P1 = 2.66e17 years; both the
paper's Figure-2 chain semantics and the rank-faithful strict model are
reported (see DESIGN.md / EXPERIMENTS.md for the discussion)."""
from __future__ import annotations

import time

from repro.core.reliability import ReliabilityParams, calibrate_scale, stripe_mttdl_years
from repro.core.schemes import PAPER_PARAMS, make_scheme

from ._util import PAPER, SCHEME_ORDER, csv

_CAL = {}


def _params() -> ReliabilityParams:
    if "p" not in _CAL:
        az = make_scheme("azure", 6, 2, 2)
        base = ReliabilityParams(detect_hours_single=0.0,
                                 detect_hours_multi=0.0)
        _CAL["p"] = calibrate_scale(az, 2.66e17, params=base, samples=800)
    return _CAL["p"]


def run(fast: bool = False) -> dict:
    labels = ["P1", "P5"] if fast else ["P1", "P2", "P3", "P5", "P6"]
    params = _params()
    out = {"repair_time_scale": params.repair_time_scale}
    for model in ("paper", "strict"):
        print(f"-- model={model} --")
        for name in SCHEME_ORDER:
            row = {}
            for lbl in labels:
                k, r, p = PAPER_PARAMS[lbl]
                s = make_scheme(name, k, r, p)
                t0 = time.perf_counter()
                v = stripe_mttdl_years(s, params, samples=600, model=model)
                us = (time.perf_counter() - t0) * 1e6
                ref = PAPER["MTTDL"][name][list(PAPER_PARAMS).index(lbl)]
                row[lbl] = {"ours": v, "paper": ref}
                csv(f"MTTDL[{model}]/{name}/{lbl}", us,
                    f"ours={v:.2e} paper={ref:.2e} ratio={v / ref:.2f}")
            out[f"{model}/{name}"] = row
    return out
