"""Pipelined vs synchronous fleet repair (the PR-3 tentpole numbers).

Sweeps pipeline window size x simulated read latency and times a full
single-node ``repair_all`` through:

  sync  — the serial path: gather a pattern chunk's surviving blocks from
          disk, launch, write back, repeat.
  pipe  — ``repro.ftx.pipeline``: double-buffered windows whose prefetch
          (reader thread pool), device launch and write-back overlap.

Read latency is made wall-real through ``StoreConfig.io_stall_scale``,
calibrated *against the measured compute time* of the same store: a latency
ratio of R sleeps R x compute_seconds across the repair's reads (spread over
the per-node simulated latency model), so "read latency >= compute" is R >=
1 by construction on any machine.

Every run checks the rebuilt blocks bit-identical against a pre-failure
snapshot. Acceptance: at S >= 64 stripes and R >= 1 the best window gives
>= 1.3x end-to-end speedup over sync (CPU interpret-mode; real disks and
TPUs widen it — reads get slower and compute faster).
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.ftx import RepairOptions, StoreConfig, StripeStore

from ._util import csv

SCHEME = "cp-azure"
GEOM = (6, 2, 2)
ACCEPT_SPEEDUP = 1.3


def _build(root: Path, S: int, B: int) -> StripeStore:
    k, r, p = GEOM
    cfg = StoreConfig(scheme=SCHEME, k=k, r=r, p=p, block_size=B,
                      batch_stripes=S, pipeline_window=S)
    store = StripeStore(root, cfg)
    payload = np.random.default_rng(7).integers(
        0, 256, S * k * B, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == S
    return store


def _snapshot(store: StripeStore, node: int) -> dict:
    return {(sid, b): store._block_path(sid, b).read_bytes()
            for sid, st in store.stripes.items()
            for b, n in enumerate(st.node_of_block) if n == node}


def _repair(store: StripeStore, node: int, *, pipeline: bool,
            window: int | None, truth: dict) -> dict:
    store.fail_node(node)
    t0 = time.perf_counter()
    tele = store.repair_all(options=RepairOptions(pipeline=pipeline, window=window))
    wall = time.perf_counter() - t0
    store.revive_node(node)
    for (sid, b), want in truth.items():
        got = store._block_path(sid, b).read_bytes()
        assert got == want, f"repair corrupted stripe {sid} block {b}"
    tele["wall_seconds"] = wall
    return tele


def _bench_one(store: StripeStore, S: int, B: int, window: int,
               ratio: float, sync: dict, truth: dict, node: int) -> dict:
    pipe = _repair(store, node, pipeline=True, window=window, truth=truth)
    row = {
        "scheme": SCHEME, "S": S, "B": B, "window": window,
        "lat_ratio": ratio,
        "sync_s": sync["wall_seconds"],
        "pipe_s": pipe["wall_seconds"],
        "speedup": sync["wall_seconds"] / pipe["wall_seconds"],
        "windows": pipe["windows"],
        "read_s": pipe["read_seconds"],
        "compute_s": pipe["compute_seconds"],
        "write_s": pipe["write_seconds"],
        "overlap_s": pipe["overlap_seconds"],
        "stripes_per_sec_pipe": S / pipe["wall_seconds"],
    }
    csv(f"pipe,{SCHEME},S={S},B={B},W={window},R={ratio}",
        pipe["wall_seconds"] * 1e6 / S,
        f"speedup={row['speedup']:.2f}x overlap={pipe['overlap_seconds']:.2f}s")
    return row


def run(fast: bool = False) -> dict:
    sweep_s = (64,) if fast else (64, 128)
    sweep_b = (4096,) if fast else (4096, 16384)
    windows = (2, 8) if fast else (1, 2, 8, 16)
    ratios = (1.5,) if fast else (0.5, 1.0, 2.0)
    rows = []
    print("bench,scheme,S,B,window,ratio,us_per_stripe,derived")
    with tempfile.TemporaryDirectory() as tmp:
        for S in sweep_s:
            for B in sweep_b:
                store = _build(Path(tmp) / f"s{S}_b{B}", S, B)
                node = store.stripes[0].node_of_block[0]
                truth = _snapshot(store, node)
                # Calibrate: one stall-free sync run measures compute and the
                # simulated I/O total; scale makes slept-read-time = R x
                # compute on *this* machine.
                base = _repair(store, node, pipeline=False, window=None,
                               truth=truth)
                per_sim = base["compute_seconds"] / max(1e-12,
                                                        base["sim_seconds"])
                for ratio in ratios:
                    store.cfg = dataclasses.replace(
                        store.cfg, io_stall_scale=ratio * per_sim)
                    sync = _repair(store, node, pipeline=False, window=None,
                                   truth=truth)
                    for window in windows:
                        rows.append(_bench_one(store, S, B, window, ratio,
                                               sync, truth, node))
    gate = [r for r in rows if r["S"] >= 64 and r["lat_ratio"] >= 1.0]
    # Per (S, B, ratio) cell the *best* window is the operating point.
    best: dict = {}
    for r in gate:
        key = (r["S"], r["B"], r["lat_ratio"])
        best[key] = max(best.get(key, 0.0), r["speedup"])
    floor = min(best.values()) if best else float("nan")
    print(f"min best-window speedup at S>=64, latency>=compute: "
          f"{floor:.2f}x (acceptance: >= {ACCEPT_SPEEDUP}x)")
    return {"geometry": GEOM, "rows": rows,
            "min_speedup_at_acceptance": floor,
            "accept_floor": ACCEPT_SPEEDUP}
