"""Batched vs per-stripe repair throughput (the PR-1 tentpole numbers).

Sweeps S (stripes per batch) x B (block bytes) x scheme and times
single-node and two-node repair through:

  looped   — the seed path: ``StripeCodec.repair_single`` per stripe, one
             kernel dispatch each (plan cache warm, so this measures pure
             per-stripe execution overhead, not planning).
  batched  — ``BatchedCodecEngine``: one compiled plan + one launch for the
             whole batch.

Reports per-stripe microseconds for both and the speedup. Acceptance: the
batched path sustains >= 3x per-stripe throughput at S >= 32 (interpret-mode
CPU numbers; the TPU Mosaic grid widens the gap). Results are bit-identical
by construction — tests/test_engine.py asserts it on every path.
"""
from __future__ import annotations

import numpy as np

from repro.core.codec import StripeCodec
from repro.core.engine import BatchedCodecEngine
from repro.core.schemes import make_scheme

from ._util import csv, timed

SCHEMES = ("cp-azure", "cp-uniform", "azure")
GEOM = (24, 2, 2)  # the paper's P5


def _bench_one(name: str, S: int, B: int, rng) -> dict:
    k, r, p = GEOM
    scheme = make_scheme(name, k, r, p)
    codec = StripeCodec(scheme)
    engine = BatchedCodecEngine(scheme, backend=codec.backend,
                                planner=codec.planner)
    data = rng.integers(0, 256, (S, k, B), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))

    failed = 0  # a data block: local-group repair
    batch_avail = {i: stripes[:, i, :] for i in range(scheme.n) if i != failed}
    per_stripe_avail = [{i: stripes[s, i, :] for i in range(scheme.n)
                         if i != failed} for s in range(S)]

    def looped():
        return [np.asarray(codec.repair_single(failed, a)[0])
                for a in per_stripe_avail]

    def batched():
        out, _ = engine.repair_single(failed, batch_avail)
        return np.asarray(out)

    got_loop, us_loop = timed(looped)
    got_batch, us_batch = timed(batched)
    assert (np.stack(got_loop) == got_batch).all(), "batched != looped"

    # Two-node (cascading) pattern: data block + first local parity.
    pattern = frozenset({0, k})
    mb_avail = {i: stripes[:, i, :] for i in range(scheme.n)
                if i not in pattern}
    ms_avail = [{i: stripes[s, i, :] for i in range(scheme.n)
                 if i not in pattern} for s in range(S)]

    def looped2():
        return [{b: np.asarray(v) for b, v in
                 codec.repair_multi(pattern, a)[0].items()} for a in ms_avail]

    def batched2():
        out, _ = engine.repair_multi(pattern, mb_avail)
        return {b: np.asarray(v) for b, v in out.items()}

    got_loop2, us_loop2 = timed(looped2)
    got_batch2, us_batch2 = timed(batched2)
    for b in pattern:
        assert (np.stack([g[b] for g in got_loop2]) == got_batch2[b]).all()

    row = {
        "scheme": name, "S": S, "B": B,
        "single_looped_us_per_stripe": us_loop / S,
        "single_batched_us_per_stripe": us_batch / S,
        "single_speedup": us_loop / us_batch,
        "multi_looped_us_per_stripe": us_loop2 / S,
        "multi_batched_us_per_stripe": us_batch2 / S,
        "multi_speedup": us_loop2 / us_batch2,
    }
    csv(f"single,{name},S={S},B={B}", us_batch / S,
        f"speedup={row['single_speedup']:.1f}x")
    csv(f"multi,{name},S={S},B={B}", us_batch2 / S,
        f"speedup={row['multi_speedup']:.1f}x")
    return row


def run(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    schemes = SCHEMES[:1] if fast else SCHEMES
    sweep_s = (8, 32) if fast else (8, 32, 64)
    sweep_b = (4096,) if fast else (4096, 16384)
    print("bench,scheme,S,B,us_per_stripe,derived")
    rows = [_bench_one(name, S, B, rng)
            for name in schemes for S in sweep_s for B in sweep_b]
    worst = min(r["single_speedup"] for r in rows if r["S"] >= 32)
    print(f"min single-repair speedup at S>=32: {worst:.1f}x "
          f"(acceptance: >= 3x)")
    return {"geometry": GEOM, "rows": rows,
            "min_single_speedup_at_S32": worst}
