"""Encode-kernel microbenchmarks: Pallas gf256 (interpret on CPU) vs CRS vs
MXU-mod2 vs jnp table reference. On-TPU the interesting comparison is the
roofline-level one in EXPERIMENTS.md §Perf; here we verify relative CPU
costs and record bytes/s for the codec default path."""
from __future__ import annotations

import numpy as np

from repro.kernels.ops import encode_op

from ._util import csv, timed


def run(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    # CPU container: Pallas paths run in interpret mode (python per tile) —
    # keep byte counts modest; the jnp ref path is XLA-compiled.
    cases = [(4, 24, 1 << 13)] if fast else [
        (4, 24, 1 << 13), (4, 24, 1 << 15), (9, 96, 1 << 14)]
    out = {}
    for (m, k, b) in cases:
        coef = rng.integers(1, 256, (m, k), dtype=np.uint8)
        data = rng.integers(0, 256, (k, b), dtype=np.uint8)
        for backend in ("ref", "gf", "crs", "mxu"):
            try:
                _, us = timed(lambda: np.asarray(
                    encode_op(coef, data, backend=backend)), repeats=2)
                mbps = k * b / (us / 1e6) / 1e6
                out[f"{backend}/{m}x{k}x{b}"] = {"us": us, "MBps": mbps}
                csv(f"kernels/{backend}/{m}x{k}x{b}", us, f"{mbps:.1f}MB/s")
            except Exception as e:  # pragma: no cover
                out[f"{backend}/{m}x{k}x{b}"] = {"error": str(e)}
                csv(f"kernels/{backend}/{m}x{k}x{b}", -1, f"error={e}")
    return out
