"""Tables IV + V: portion of (effective) local repair under 2-node failures."""
from __future__ import annotations

import time

from repro.core import metrics as M
from repro.core.schemes import PAPER_PARAMS, make_scheme

from ._util import PAPER, SCHEME_ORDER, csv


def run(fast: bool = False) -> dict:
    labels = list(PAPER_PARAMS)
    if fast:
        labels = ["P1", "P5"]
    out = {}
    for metric, fn in (("LOCAL", M.local_portion),
                       ("EFFECTIVE", M.effective_local_portion)):
        print(f"-- {metric} --")
        for name in SCHEME_ORDER:
            row = {}
            for lbl in labels:
                k, r, p = PAPER_PARAMS[lbl]
                s = make_scheme(name, k, r, p)
                t0 = time.perf_counter()
                v = fn(s)
                us = (time.perf_counter() - t0) * 1e6
                ref = PAPER[metric][name][list(PAPER_PARAMS).index(lbl)]
                row[lbl] = {"ours": round(v, 3), "paper": ref}
                csv(f"{metric}/{name}/{lbl}", us,
                    f"ours={v:.2f} paper={ref}")
            out[f"{metric}/{name}"] = row
    return out
