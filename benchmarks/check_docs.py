"""Docs-consistency gate: ``benchmarks.run --list`` <-> EXPERIMENTS.md.

``python -m benchmarks.check_docs``

Asserts that every benchmark section registered in ``benchmarks.run``
(what ``--list`` prints) has a row in the section table of
``docs/EXPERIMENTS.md``, and that every row in that table names a
registered section — so the table cannot rot in either direction: a new
benchmark lands with its paper analogue documented, and a renamed/removed
benchmark takes its stale row with it. Runs in the CI lint job (no jax
needed; ``benchmarks.run`` is import-light by design).

Exit 0 when the two sets match, 1 with a per-name diff otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

from benchmarks.run import SECTIONS

EXPERIMENTS = Path(__file__).resolve().parents[1] / "docs" / "EXPERIMENTS.md"

# First column of the section table: | `section_name` | paper analogue | ...
_ROW = re.compile(r"^\|\s*`([a-z_]+)`\s*\|", re.MULTILINE)


def table_sections(text: str) -> list[str]:
    """Section names from the EXPERIMENTS.md table, in row order."""
    return _ROW.findall(text)


def main() -> int:
    documented = table_sections(EXPERIMENTS.read_text())
    dupes = sorted({s for s in documented if documented.count(s) > 1})
    registered = set(SECTIONS)
    missing_doc = [s for s in SECTIONS if s not in documented]
    stale_doc = [s for s in documented if s not in registered]
    ok = not (missing_doc or stale_doc or dupes)
    if missing_doc:
        print("sections registered in benchmarks.run but missing from the "
              f"docs/EXPERIMENTS.md table: {', '.join(missing_doc)}",
              file=sys.stderr)
    if stale_doc:
        print("rows in the docs/EXPERIMENTS.md table naming no registered "
              f"benchmark section: {', '.join(stale_doc)}", file=sys.stderr)
    if dupes:
        print(f"duplicate rows in the docs/EXPERIMENTS.md table: "
              f"{', '.join(dupes)}", file=sys.stderr)
    if ok:
        print(f"docs consistent: {len(SECTIONS)} benchmark sections all "
              f"documented in {EXPERIMENTS.name}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
