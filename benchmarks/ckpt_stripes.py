"""Async EC checkpointing vs 3x replication (the PR-9 tentpole numbers).

For a training state of each (reduced) architecture:

  save    — sync (serial encode, training blocked end-to-end) vs async
            (``save_async``: snapshot on the training thread, windowed
            encode + drain in the background while a simulated training
            loop keeps stepping). The persistence medium is made slow via
            ``EncodePipeline``'s ``drain_stall``, calibrated to the
            measured per-window encode compute of the same store — the
            simulated-stall config, where hiding the encode matters.
            Headline: **steps stalled per checkpoint** and the fraction of
            the save wall that training kept running (overlap fraction).

  restore — after losing a data-holding host, the parallel degraded
            restore (per-host reader pools + serving-plan decodes fed from
            the restore buffer) vs a 3x-replication baseline. Replication
            stores every data block three times; after a host loss it must
            re-read the full state (``data_blocks``) and re-replicate the
            lost host's share (``3 * data_blocks / n`` block copies) —
            the EC restore must read **strictly fewer blocks** (counted,
            not timed: both sides are deterministic functions of the
            state size and geometry). Simulated restore time uses the same
            per-link model for both.

Both headline metrics are asserted here (overlap fraction > 0.5, EC blocks
< replication blocks) and floored in the CI regression gate
(``check_regression.py`` section ``ckpt_stripes``).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_model
from repro.ftx.checkpoint import CheckpointConfig, CheckpointManager
from repro.ftx.stripestore import StoreConfig
from repro.train.optimizer import adamw_init

from ._util import csv

SCHEME = "cp-azure"
GEOM = (8, 2, 2)
BLOCK = 1 << 16
ENCODE_WINDOW = 2
REPLICAS = 3
STEP_SECONDS = 0.02          # one simulated train step
ACCEPT_OVERLAP = 0.5         # min fraction of save wall overlapped


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _bench_arch(arch: str) -> dict:
    k, r, p = GEOM
    api = get_model(arch, smoke=True)
    params = api.init_params(jax.random.key(0))
    state = {"params": params, "opt": adamw_init(params)}
    tmp = tempfile.mkdtemp(prefix="bench_ck_")
    try:
        cm = CheckpointManager(tmp, CheckpointConfig(
            store=StoreConfig(scheme=SCHEME, k=k, r=r, p=p, block_size=BLOCK),
            encode_window=ENCODE_WINDOW))
        # Calibrate the simulated-stall config: one stall-free save
        # measures this machine's per-window encode compute; the drain
        # stall makes persistence cost about as much — the regime where a
        # synchronous save visibly stalls training.
        cal = cm.save(1, state)
        stall = max(0.01, cal["encode"]["compute_seconds"]
                    / max(1, cal["encode"]["windows"]))

        # Sync save: serial stages, training blocked for the whole wall.
        t0 = time.perf_counter()
        cm.save_async(2, state, pipelined=False, drain_stall=stall).result()
        sync_wall = time.perf_counter() - t0

        # Async save: training stalls only for the snapshot, then keeps
        # stepping until the background encode seals.
        t0 = time.perf_counter()
        fut = cm.save_async(3, state, drain_stall=stall)
        t_ret = time.perf_counter()
        steps_during = 0
        while not fut.done():
            time.sleep(STEP_SECONDS)     # one simulated train step
            steps_during += 1
        t_done = time.perf_counter()
        info = fut.result()
        save_wall = t_done - t0
        overlap_fraction = (t_done - t_ret) / save_wall

        # Restore after losing a data-holding host.
        store = cm.store_for(3)
        victim = store.stripes[0].node_of_block[0]
        cm.fail_hosts(3, [victim])
        got, tele = cm.restore(3, state)
        assert _tree_equal(got, state), f"{arch}: degraded restore corrupt"
        ser, tele_ser = cm.restore(3, state, parallel=False)
        assert _tree_equal(ser, state)

        n = store.scheme.n
        cfg = store.cfg
        data_blocks = -(-info["bytes"] // BLOCK)
        # Replication baseline: full re-read + re-replicating the lost
        # host's share of the 3x copies, in blocks (counted, not timed).
        baseline_blocks = data_blocks + -(-REPLICAS * data_blocks // n)
        mean_lat_s = float(np.mean(list(store.latency_ms.values()))) / 1e3
        per_block_s = BLOCK * 8 / (cfg.bandwidth_gbps * 1e9) + mean_lat_s
        baseline_sim = baseline_blocks * per_block_s
        assert tele["blocks_read"] < baseline_blocks, (
            f"{arch}: EC restore read {tele['blocks_read']} blocks, "
            f"replication baseline {baseline_blocks}")

        row = {
            "state_mb": info["bytes"] / 1e6,
            "stripes": info["stripes"],
            "encode_windows": info["encode"]["windows"],
            "drain_stall_s": stall,
            # --- steps stalled per checkpoint ---
            "sync_save_wall_s": sync_wall,
            "async_snapshot_s": fut.snapshot_seconds,
            "async_save_wall_s": save_wall,
            "steps_stalled_sync": sync_wall / STEP_SECONDS,
            "steps_stalled_async": fut.snapshot_seconds / STEP_SECONDS,
            "steps_ran_during_async_encode": steps_during,
            "train_overlap_fraction": overlap_fraction,
            "encode_pipeline_overlap_fraction":
                info["encode"]["overlap_fraction"],
            # --- restore after host loss ---
            "restore_blocks_ec": tele["blocks_read"],
            "restore_blocks_replication": baseline_blocks,
            "restore_blocks_ratio": baseline_blocks / tele["blocks_read"],
            "restore_degraded_blocks": tele["degraded_blocks"],
            "restore_extra_source_reads": tele["extra_source_reads"],
            "restore_sim_s_ec": tele["sim_seconds"],
            "restore_sim_s_replication": baseline_sim,
            "restore_wall_parallel_s": tele["restore_seconds"],
            "restore_wall_serial_s": tele_ser["restore_seconds"],
        }
        csv(f"ckpt/{arch}/{SCHEME}", save_wall * 1e6,
            f"state={row['state_mb']:.1f}MB "
            f"stalled_steps={row['steps_stalled_async']:.2f}"
            f"(sync={row['steps_stalled_sync']:.1f}) "
            f"overlap={overlap_fraction:.0%} "
            f"restore_blocks={tele['blocks_read']}"
            f"(repl={baseline_blocks})")
        return row
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(fast: bool = False) -> dict:
    archs = ARCHS[:2] if fast else ARCHS[:4]
    rows = {arch: _bench_arch(arch) for arch in archs}
    min_overlap = min(r["train_overlap_fraction"] for r in rows.values())
    min_ratio = min(r["restore_blocks_ratio"] for r in rows.values())
    min_stall_reduction = min(
        r["steps_stalled_sync"] / max(r["steps_stalled_async"], 1e-9)
        for r in rows.values())
    assert min_overlap > ACCEPT_OVERLAP, (
        f"encode-overlap fraction {min_overlap:.2f} <= {ACCEPT_OVERLAP}")
    print(f"min train-overlap fraction: {min_overlap:.2f} "
          f"(acceptance: > {ACCEPT_OVERLAP}); "
          f"min replication/EC restore-blocks ratio: {min_ratio:.2f} "
          f"(acceptance: > 1)")
    return {"scheme": SCHEME, "geometry": GEOM, "block_size": BLOCK,
            "step_seconds": STEP_SECONDS, "rows": rows,
            "min_train_overlap_fraction": min_overlap,
            "min_restore_blocks_ratio": min_ratio,
            "min_stall_reduction": min_stall_reduction,
            "accept_overlap": ACCEPT_OVERLAP}
