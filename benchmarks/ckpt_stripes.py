"""Framework integration: EC-checkpoint encode + failure-repair cost for a
training state of each (reduced) architecture, CP-Azure vs Azure."""
from __future__ import annotations

import shutil
import tempfile

import jax

from repro.configs import ARCHS, get_model
from repro.ftx.checkpoint import CheckpointConfig, CheckpointManager
from repro.ftx.stripestore import StoreConfig
from repro.train.optimizer import adamw_init

from ._util import csv


def run(fast: bool = False) -> dict:
    archs = ARCHS[:2] if fast else ARCHS[:6]
    out = {}
    for arch in archs:
        api = get_model(arch, smoke=True)
        params = api.init_params(jax.random.key(0))
        state = {"params": params, "opt": adamw_init(params)}
        for scheme in ("azure", "cp-azure"):
            tmp = tempfile.mkdtemp(prefix="bench_ck_")
            try:
                cm = CheckpointManager(tmp, CheckpointConfig(
                    store=StoreConfig(scheme=scheme, k=8, r=2, p=2,
                                      block_size=1 << 17)))
                info = cm.save(1, state)
                # lose the host holding the last parity + one data host
                store = cm.store_for(1)
                gr_node = store.stripes[0].node_of_block[store.scheme.n - 1]
                cm.fail_hosts(1, [gr_node])
                tele = cm.repair(1)
                out[f"{arch}/{scheme}"] = {
                    "state_mb": info["bytes"] / 1e6,
                    "encode_s": info["encode_seconds"],
                    "repair_blocks": tele["blocks_read"],
                    "repair_sim_s": tele["sim_seconds"]}
                csv(f"ckpt/{arch}/{scheme}", info["encode_seconds"] * 1e6,
                    f"state={info['bytes'] / 1e6:.1f}MB "
                    f"parity_repair_blocks={tele['blocks_read']}")
            finally:
                shutil.rmtree(tmp, ignore_errors=True)
    return out
