"""Locality-aware stripe scheduling: policy x devices x failure pattern.

The PR-5 tentpole numbers: fleet repair through the locality-aware stripe
scheduler (``repro.dist.schedule``) vs the contiguous stripe->device-shard
assignment, under each block-placement policy (``repro.dist.topology``) —
the experiment that turns the placement cost model into a measured win.

Every scenario repairs twin stores built identically (same topology, same
seeded placement): store *a* pipelined with ``schedule="locality"``, store
*b* synchronous with ``schedule="none"``, then asserts every rebuilt block
file bit-identical — the scheduler is a pure permutation of which shard
reads which stripes; GF(2^8) bytes never change.

Three sweeps (each device count in its own subprocess; jax locks the
topology at first init, like ``sharded_repair``/``sharded_gather``):

* **devices** (spread policy, single-node failure): the scheduled local
  fraction vs the contiguous one as the stripe axis widens — domains track
  the device count, so each device slice reads through its own rack.
* **policy** (at the max device count): ``contiguous`` arcs make every
  pattern group share one node set (nothing to schedule, uplift exactly
  1.0); ``round_robin`` disperses every stripe over all domains (flat
  affinity, nothing to win); ``spread``/copyset concentrates each stripe
  in few domains — the skewed scenario where scheduling pays.
* **failure pattern** (spread, max devices): single-node and cross-domain
  two-node repair.

Locality fractions are *deterministic* (seeded placement, counted reads —
no timing in the metric), so the CI gate on the spread-policy uplift
(``min_local_uplift``, ``min_scheduled_local_fraction`` via
``benchmarks.check_regression``) is machine-independent, unlike the
throughput gates. ``remote_read_multiplier=4`` also surfaces the win in
``sim_seconds`` (reported as ``sim_speedup``): fewer cross-domain reads is
simulated repair time saved, the paper's Figs 6/9 metric under placement.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from ._util import csv

GEOM = (6, 2, 2)
SCHEME = "cp-azure"
NODES_PER_DOMAIN = 10
SPREAD_WIDTH = 2
BATCH = 8                 # stripes per window: one full-span launch at 8 dev
REMOTE_MULT = 4.0
SEED = 7


def _worker(devices: int, stripes: int, block: int, policy: str,
            pattern: str) -> dict:
    """Runs in a fresh process with ``devices`` forced host devices."""
    import tempfile

    import numpy as np

    import jax

    from repro.dist.sharding import with_rules
    from repro.dist.topology import Topology
    from repro.ftx import (RepairOptions, StoreConfig, StripeStore,
                           repair_failed_nodes)

    assert len(jax.devices()) == devices
    k, r, p = GEOM
    domains = max(1, devices)
    num_nodes = NODES_PER_DOMAIN * max(domains, 2)
    topo = Topology(num_nodes=num_nodes, num_domains=domains,
                    spread_width=SPREAD_WIDTH, seed=SEED)
    cfg = StoreConfig(scheme=SCHEME, k=k, r=r, p=p, block_size=block,
                      batch_stripes=BATCH, pipeline_window=BATCH,
                      prefetch_threads=2, placement_policy=policy,
                      remote_read_multiplier=REMOTE_MULT)

    def build(root):
        store = StripeStore(root, cfg, num_nodes=num_nodes, topology=topo)
        payload = np.random.default_rng(11).integers(
            0, 256, stripes * k * block, dtype=np.uint8)
        store.put("blob", payload.tobytes())
        store.seal()
        assert len(store.stripes) == stripes
        return store

    with tempfile.TemporaryDirectory() as tmp:
        sa = build(Path(tmp) / "a")
        sb = build(Path(tmp) / "b")
        n0 = sa.stripes[0].node_of_block[0]
        nodes = [n0]
        if pattern == "double":
            # second failure in a different domain, so the two-node groups
            # keep per-stripe diversity instead of collapsing onto one rack
            d0 = topo.domain_of(n0)
            nodes.append(next(
                n for n in range(num_nodes) if topo.domain_of(n) != d0
                and any(n in sa.stripes[s].node_of_block for s in sa.stripes)))
        mesh = jax.make_mesh((devices, 1), ("data", "model"))
        with with_rules(mesh):
            rep = repair_failed_nodes(
                sa, nodes, options=RepairOptions(pipeline=True,
                                                 schedule="locality"))
            # like-for-like baseline: same mesh, same sharded gather, the
            # contiguous stripe->shard assignment — only the scheduler off
            base = repair_failed_nodes(
                sb, nodes, options=RepairOptions(pipeline=False,
                                                 schedule="none"))
        for sid in sa.stripes:
            for b in range(sa.scheme.n):
                assert sa._block_path(sid, b).read_bytes() == \
                    sb._block_path(sid, b).read_bytes(), \
                    f"scheduled repair not bit-identical at ({sid}, {b})"
        assert rep.blocks_read == base.blocks_read
        assert rep.schedule == "locality" and base.schedule == "none"
        return {
            "devices": devices, "S": stripes, "B": block,
            "policy": policy, "pattern": pattern, "domains": domains,
            "nodes": num_nodes,
            "stripes_repaired": rep.stripes_repaired,
            "scheduled_local_fraction": rep.local_read_fraction,
            "contiguous_local_fraction": base.local_read_fraction,
            "predicted_scheduled_fraction": rep.scheduled_local_read_fraction,
            "predicted_contiguous_fraction":
                rep.contiguous_local_read_fraction,
            "local_uplift": rep.local_read_fraction
            / max(base.local_read_fraction, 1e-9),
            "sim_seconds_scheduled": rep.sim_seconds,
            "sim_seconds_contiguous": base.sim_seconds,
            "sim_speedup": base.sim_seconds / max(rep.sim_seconds, 1e-9),
            "wall_us_per_stripe": 1e6 * rep.wall_seconds
            / max(1, rep.stripes_repaired),
        }


def _spawn(devices: int, stripes: int, block: int, policy: str,
           pattern: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = Path(__file__).resolve().parents[1]
    src = str(root / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, str(root)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.stripe_schedule",
         "--worker", str(devices), str(stripes), str(block), policy, pattern],
        env=env, cwd=root, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"worker devices={devices} policy={policy} pattern={pattern} "
            f"failed:\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def run(fast: bool = False) -> dict:
    S, B = (640, 1024) if fast else (960, 4096)
    counts = (1, 8) if fast else (1, 2, 4, 8)
    print("bench,policy,devices,us_per_stripe,derived")
    rows = []

    def show(r):
        rows.append(r)
        csv(f"schedule,{r['policy']},{r['devices']}dev,{r['pattern']}",
            r["wall_us_per_stripe"],
            f"local={r['scheduled_local_fraction']:.3f} "
            f"contig={r['contiguous_local_fraction']:.3f} "
            f"uplift={r['local_uplift']:.2f}x "
            f"sim_speedup={r['sim_speedup']:.2f}x")

    # devices sweep: the skewed (spread) placement, single-node failure
    for d in counts:
        show(_spawn(d, S, B, "spread", "single"))
    # policy sweep at the widest mesh
    for policy in ("contiguous", "round_robin"):
        show(_spawn(counts[-1], S, B, policy, "single"))
    # failure-pattern sweep: cross-domain two-node repair under spread
    show(_spawn(counts[-1], S, B, "spread", "double"))

    gated = [r for r in rows if r["policy"] == "spread"
             and r["devices"] == counts[-1]]
    uplift = min(r["local_uplift"] for r in gated)
    frac = min(r["scheduled_local_fraction"] for r in gated)
    sim = min(r["sim_speedup"] for r in gated)
    print(f"skewed-placement uplift at {counts[-1]} devices: "
          f"{uplift:.2f}x (scheduled local fraction >= {frac:.3f}, "
          f"sim speedup >= {sim:.2f}x)")
    return {"geometry": GEOM, "scheme": SCHEME, "rows": rows,
            "max_devices": counts[-1],
            "min_local_uplift": uplift,
            "min_scheduled_local_fraction": frac,
            "min_sim_speedup": sim}


if __name__ == "__main__":
    if len(sys.argv) >= 7 and sys.argv[1] == "--worker":
        devices, stripes, block = map(int, sys.argv[2:5])
        print(json.dumps(_worker(devices, stripes, block,
                                 sys.argv[5], sys.argv[6])))
    else:
        print(json.dumps(run(fast="--fast" in sys.argv), indent=1))
