"""CI benchmark-regression gate.

``python -m benchmarks.check_regression [--sections a,b] [--tolerance 0.30]``

Reads the JSON written by ``benchmarks.run --fast`` for each gated section,
extracts a small set of higher-is-better metrics, and compares them against
the committed baseline (``benchmarks/results/baseline_ci.json``). Any metric
more than ``--tolerance`` (default 30%) below its baseline value fails the
run with exit 1 — the CI tier1 job runs this after the benchmark smoke, so a
change that quietly halves repair throughput cannot merge green.

The gate prefers *ratio* metrics (batched-vs-looped speedup, pipelined-vs-
sync speedup) over absolute throughput where possible: ratios compare two
paths on the same silicon, so they transfer between the machine that seeded
the baseline and whatever runner CI lands on. Aggregate absolute throughput
is gated too (min across the sweep), since a uniform slowdown leaves ratios
untouched.

``--update-baseline`` rewrites the baseline from the current results (run it
locally after an intentional perf change and commit the file).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
BASELINE = RESULTS / "baseline_ci.json"
DEFAULT_TOLERANCE = 0.30


def _batched_repair(doc: dict) -> dict[str, float]:
    rows = doc["rows"]
    return {
        "min_single_speedup_at_S32": doc["min_single_speedup_at_S32"],
        "min_single_stripes_per_sec": min(
            1e6 / r["single_batched_us_per_stripe"] for r in rows),
        "min_multi_speedup": min(r["multi_speedup"] for r in rows),
    }


def _pipelined_repair(doc: dict) -> dict[str, float]:
    rows = doc["rows"]
    return {
        "min_speedup_at_acceptance": doc["min_speedup_at_acceptance"],
        "best_stripes_per_sec_pipe": max(
            r["stripes_per_sec_pipe"] for r in rows),
    }


def _sharded_gather(doc: dict) -> dict[str, float]:
    return {
        "gather_speedup_at_max_devices": doc["gather_speedup_at_max_devices"],
        "min_shard_balance": doc["min_shard_balance"],
    }


def _stripe_schedule(doc: dict) -> dict[str, float]:
    # Locality fractions are deterministic (seeded placement, counted
    # reads), so these floors hold machine-independently — a scheduler
    # change that stops beating the contiguous assignment on the skewed
    # scenarios cannot merge green.
    return {
        "min_local_uplift": doc["min_local_uplift"],
        "min_scheduled_local_fraction": doc["min_scheduled_local_fraction"],
    }


def _degraded_read(doc: dict) -> dict[str, float]:
    # Both metrics are counted, not timed: the coalescing ratio is naive
    # launches over serving launches on the same seeded Zipfian stream, and
    # the local fraction is which plan tier each serving decode used — both
    # deterministic given (workload seed, placement), so the floors hold
    # machine-independently. Tail latencies are asserted inside the
    # benchmark (serve p99 < RS p99), not floored here.
    return {
        "min_coalescing_ratio": doc["min_coalescing_ratio"],
        "min_local_decode_fraction": doc["min_local_decode_fraction"],
    }


def _batched_decode(doc: dict) -> dict[str, float]:
    # All three metrics are counts/models, not timings. The expansion
    # amortization is launches over byte->bit matrix expansions (the
    # once-per-pattern-chunk cache contract; expansions_per_plan == 1 is
    # additionally asserted inside the benchmark). The speedup floors are
    # roofline-model ratios evaluated at the actual compiled plan's shape
    # and measured bit density — deterministic given (scheme, pattern), so
    # they hold machine-independently on the CPU interpret path.
    return {
        "expansion_amortization": doc["expansion_amortization"],
        "crs_vs_ref_model_speedup": doc["crs_vs_ref_model_speedup"],
        "crs_vs_gf_model_speedup": doc["crs_vs_gf_model_speedup"],
    }


def _reliability_sim(doc: dict) -> dict[str, float]:
    # Everything floored here is a deterministic function of (config, seed):
    # the paper's MTTDL ordering as a count (CP-Azure >= Azure-LRC and
    # CP-Uniform >= uniform at matched overhead), sim-vs-closed-form
    # agreement as a min/max ratio in (0, 1], the batched engine's events
    # retired per epoch (a parallelism *model* ratio — how much each JAX
    # selection/draw launch amortizes — never a wall time), and the counted
    # local-decode fraction inside the rebuild window. Tail latencies
    # (steady vs window p99) are reported in the JSON, not floored.
    return {
        "mttdl_ordering_ok": float(doc["schemes"]["ordering_ok"]),
        "closed_form_agreement": doc["closed_form"]["agreement"],
        "event_parallelism": min(
            r["event_parallelism"] for r in doc["schemes"]["rows"].values()),
        "window_local_decode_fraction":
            doc["rebuild_window"]["window_local_decode_fraction"],
    }


def _ckpt_stripes(doc: dict) -> dict[str, float]:
    # The restore ratio is counted blocks (replication re-read baseline
    # over EC parallel degraded restore) — a deterministic function of
    # state size and geometry. The overlap fraction is wall-clock but
    # structurally pinned near 1: the training thread stalls only for a
    # host-memory snapshot (ms) while the background encode pays at least
    # windows x drain_stall (>= 10ms each); the 30% tolerance still keeps
    # the floor above the 0.5 acceptance line asserted in-bench.
    # Wall-time ratios (min_stall_reduction) are reported, not floored.
    return {
        "min_train_overlap_fraction": doc["min_train_overlap_fraction"],
        "min_restore_blocks_ratio": doc["min_restore_blocks_ratio"],
    }


def _repair_orchestration(doc: dict) -> dict[str, float]:
    # All three floors are deterministic counts over the committed failure
    # trace (seeded placement, counted reads/moves — no timing): the
    # cross-window assignment's scheduled-local-read count ratio vs the
    # per-chunk greedy, the fraction of blocks on UP nodes after the
    # permanent-loss replay under topology destinations, and the committed
    # rebalance move count after the one-rack expansion. Strict dominance
    # (global > greedy > contiguous; topology > in-place) is additionally
    # asserted inside the benchmark worker itself.
    return {
        "assignment_uplift_global_vs_greedy":
            doc["assignment_uplift_global_vs_greedy"],
        "destination_live_fraction": doc["destination_live_fraction"],
        "rebalance_moves": doc["rebalance_moves"],
    }


EXTRACTORS = {
    "batched_repair": _batched_repair,
    "batched_decode": _batched_decode,
    "pipelined_repair": _pipelined_repair,
    "sharded_gather": _sharded_gather,
    "stripe_schedule": _stripe_schedule,
    "degraded_read": _degraded_read,
    "reliability_sim": _reliability_sim,
    "repair_orchestration": _repair_orchestration,
    "ckpt_stripes": _ckpt_stripes,
}


def extract(section: str, results_dir: Path) -> dict[str, float]:
    path = results_dir / f"{section}.json"
    if not path.exists():
        raise FileNotFoundError(
            f"{path} missing — run `python -m benchmarks.run --fast "
            f"--only {section}` first")
    return EXTRACTORS[section](json.loads(path.read_text()))


def check(current: dict[str, dict[str, float]],
          baseline: dict, tolerance: float) -> list[str]:
    """Returns a list of failure messages (empty = gate passes)."""
    failures = []
    for section, base_metrics in baseline["sections"].items():
        cur = current.get(section)
        if cur is None:
            continue  # section not gated this run
        for metric, base in base_metrics.items():
            got = cur.get(metric)
            if got is None:
                failures.append(f"{section}/{metric}: missing from results")
                continue
            floor = base * (1.0 - tolerance)
            status = "ok" if got >= floor else "REGRESSION"
            print(f"{status:>10}  {section}/{metric}: {got:.3f} "
                  f"(baseline {base:.3f}, floor {floor:.3f})")
            if got < floor:
                failures.append(
                    f"{section}/{metric}: {got:.3f} < floor {floor:.3f} "
                    f"({tolerance:.0%} below baseline {base:.3f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=Path, default=BASELINE)
    ap.add_argument("--results", type=Path, default=RESULTS)
    ap.add_argument("--sections", default=",".join(EXTRACTORS),
                    metavar="SECTION[,SECTION...]")
    ap.add_argument("--tolerance", type=float, default=None,
                    help=f"allowed drop below baseline "
                         f"(default: baseline file's, else {DEFAULT_TOLERANCE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current results")
    args = ap.parse_args(argv)
    sections = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = [s for s in sections if s not in EXTRACTORS]
    if unknown:
        ap.error(f"no regression extractor for: {', '.join(unknown)} "
                 f"(known: {', '.join(EXTRACTORS)})")
    try:
        current = {s: extract(s, args.results) for s in sections}
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if args.update_baseline:
        # Merge into the existing baseline: reseeding one section (via
        # --sections) must never drop the other sections' floors.
        old: dict = {}
        if args.baseline.exists():
            old = json.loads(args.baseline.read_text()).get("sections", {})
        # A re-seeded section must still produce every metric its old
        # baseline gated: a rename or a dropped field in the benchmark's
        # JSON would otherwise silently delete the floor and the gate
        # would never notice the metric going away.
        dropped = [f"{s}/{m}" for s in sorted(set(current) & set(old))
                   for m in old[s] if m not in current[s]]
        if dropped:
            print("error: --update-baseline would drop gated metric(s) "
                  "missing from the new results:", file=sys.stderr)
            for d in dropped:
                print(f"  - {d}", file=sys.stderr)
            print("fix the benchmark/extractor (or intentionally remove "
                  "the metric from the baseline by hand)", file=sys.stderr)
            return 1
        sections = {**old, **current}
        doc = {"tolerance": (args.tolerance if args.tolerance is not None
                             else DEFAULT_TOLERANCE),
               "note": "seeded from a --fast run; regenerate with "
                       "`python -m benchmarks.check_regression "
                       "--update-baseline` after intentional perf changes",
               "sections": sections}
        args.baseline.parent.mkdir(parents=True, exist_ok=True)
        args.baseline.write_text(json.dumps(doc, indent=1) + "\n")
        # Say what happened per section, so a baseline bump in a CI log or
        # a PR diff is auditable: which floors moved vs merely carried.
        reseeded = sorted(set(current) & set(old))
        added = sorted(set(current) - set(old))
        kept = sorted(set(old) - set(current))
        print(f"baseline written: {args.baseline}")
        print(f"  re-seeded from current results: {', '.join(reseeded) or '-'}")
        print(f"  newly added: {', '.join(added) or '-'}")
        print(f"  kept (merged from old baseline): {', '.join(kept) or '-'}")
        return 0
    if not args.baseline.exists():
        print(f"error: baseline {args.baseline} missing — seed it with "
              f"--update-baseline and commit it", file=sys.stderr)
        return 1
    baseline = json.loads(args.baseline.read_text())
    tolerance = (args.tolerance if args.tolerance is not None
                 else baseline.get("tolerance", DEFAULT_TOLERANCE))
    failures = check(current, baseline, tolerance)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
