"""Experiment 2 (Figs 7, 8): single-node repair time + throughput vs block
size (64 KB - 4 MB here; the paper sweeps to 16 MB on real VMs)."""
from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.ftx.stripestore import StoreConfig, StripeStore

from ._util import csv


def run(fast: bool = False) -> dict:
    sizes_kb = [64, 256] if fast else [64, 256, 1024, 4096]
    out = {}
    for name in ("azure", "azure+1", "optimal", "uniform", "cp-azure",
                 "cp-uniform"):
        for kb in sizes_kb:
            tmp = tempfile.mkdtemp(prefix="bench_bs_")
            try:
                cfg = StoreConfig(scheme=name, k=24, r=2, p=2,
                                  block_size=kb * 1024)
                store = StripeStore(tmp, cfg)
                rng = np.random.default_rng(0)
                for i in range(24):
                    store.put(f"o{i}", rng.integers(
                        0, 256, cfg.block_size - 16, dtype=np.uint8).tobytes())
                store.seal()
                # repair a data block and the last global parity
                times = []
                for b in (0, store.scheme.n - 1):
                    node = store.stripes[0].node_of_block[b]
                    store.fail_node(node)
                    tele = store.repair_all()
                    store.revive_node(node)
                    times.append(tele["sim_seconds"])
                t = float(np.mean(times))
                thr = kb / 1024 / t if t else 0.0  # MB repaired per sim-sec
                out[f"{name}/{kb}KB"] = {"repair_s": t, "throughput_MBps": thr}
                csv(f"blocksize/{name}/{kb}KB", t * 1e6,
                    f"repair={t * 1e3:.1f}ms thr={thr:.1f}MB/s")
            finally:
                shutil.rmtree(tmp, ignore_errors=True)
    return out
