"""Experiments 1 + 3 (Figs 6, 9): single- and two-node repair time on the
simulated cluster (bandwidth-model time + real JAX encode/decode compute),
P1-P8, all six schemes."""
from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.core.schemes import PAPER_PARAMS
from repro.ftx.stripestore import StoreConfig, StripeStore

from ._util import SCHEME_ORDER, csv


def _mk_store(scheme, k, r, p, block_kb, tmp, stripes=2):
    cfg = StoreConfig(scheme=scheme, k=k, r=r, p=p,
                      block_size=block_kb * 1024, bandwidth_gbps=1.0)
    store = StripeStore(tmp, cfg)
    rng = np.random.default_rng(0)
    for s in range(stripes):
        for i in range(k):
            store.put(f"s{s}o{i}", rng.integers(0, 256, cfg.block_size - 8,
                                                dtype=np.uint8).tobytes())
        store.seal()
    store.save_manifest()
    return store


def run(fast: bool = False) -> dict:
    labels = ["P1", "P5"] if fast else list(PAPER_PARAMS)
    block_kb = 64 if fast else 256
    out = {}
    rng = np.random.default_rng(7)
    for lbl in labels:
        k, r, p = PAPER_PARAMS[lbl]
        for name in SCHEME_ORDER:
            tmp = tempfile.mkdtemp(prefix="bench_rt_")
            try:
                store = _mk_store(name, k, r, p, block_kb, tmp)
                n = store.scheme.n
                # single-node: average over every block position of stripe 0
                singles = []
                positions = range(n) if n <= 16 else \
                    sorted(rng.choice(n, 12, replace=False).tolist())
                for b in positions:
                    node = store.stripes[0].node_of_block[b]
                    store.fail_node(node)
                    tele = store.repair_all()
                    store.revive_node(node)
                    singles.append(tele["sim_seconds"])
                # two-node: 8 random pairs
                doubles = []
                for _ in range(8):
                    bs = rng.choice(n, 2, replace=False)
                    nodes = [store.stripes[0].node_of_block[b] for b in bs]
                    for nd in nodes:
                        store.fail_node(nd)
                    tele = store.repair_all()
                    for nd in nodes:
                        store.revive_node(nd)
                    doubles.append(tele["sim_seconds"])
                s1 = float(np.mean(singles))
                s2 = float(np.mean(doubles))
                out[f"{lbl}/{name}"] = {"single_s": s1, "double_s": s2}
                csv(f"repair_time/{name}/{lbl}", s1 * 1e6,
                    f"single={s1:.3f}s double={s2:.3f}s")
            finally:
                shutil.rmtree(tmp, ignore_errors=True)
    return out
