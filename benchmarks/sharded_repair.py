"""Repair throughput vs. virtual device count (the PR-2 tentpole numbers).

Times batched multi-node repair through ``BatchedCodecEngine`` at a fixed
stripe count S while the stripe axis is sharded over 1 / 2 / 4 / 8 forced
host devices (``repro.dist.stripes``). Each device count runs in its own
subprocess — jax locks the device topology at first init, so the sweep
cannot run in-process.

On a CPU container the per-device work is the fused table path; virtual
devices share the same silicon, so perfect scaling is not expected — the
benchmark's value is (a) the scaling *trend* as the per-device S shrinks
and (b) a regression guard proving the sharded path stays bit-identical
(each worker checksums its output against the unsharded result).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from ._util import csv

GEOM = (24, 2, 2)  # the paper's P5
SCHEME = "cp-azure"


def _worker(devices: int, S: int, B: int) -> dict:
    """Runs in a fresh process with ``devices`` forced host devices."""
    import numpy as np

    import jax

    from repro.core.engine import BatchedCodecEngine
    from repro.core.schemes import make_scheme
    from repro.dist.sharding import with_rules

    from benchmarks._util import timed

    assert len(jax.devices()) == devices
    k, r, p = GEOM
    scheme = make_scheme(SCHEME, k, r, p)
    engine = BatchedCodecEngine(scheme)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, (S, k, B), dtype=np.uint8)
    stripes = np.asarray(engine.encode(data))
    pattern = frozenset({0, k})  # data block + first local parity (cascade)
    avail = {i: stripes[:, i, :] for i in range(scheme.n) if i not in pattern}

    base, _ = engine.repair_multi(pattern, avail)
    base = {b: np.asarray(v) for b, v in base.items()}

    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    with with_rules(mesh) as mr:
        def sharded():
            out, _ = engine.repair_multi(pattern, avail, mesh_rules=mr)
            return {b: np.asarray(v) for b, v in out.items()}

        got, us = timed(sharded)
    span = engine.last_span
    for b in pattern:
        assert (got[b] == base[b]).all(), "sharded repair not bit-identical"
    return {"devices": devices, "span": span, "S": S, "B": B,
            "us_per_stripe": us / S,
            "stripe_mb_per_s": S * B * len(avail) / max(us, 1e-9)}


def _spawn(devices: int, S: int, B: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = Path(__file__).resolve().parents[1]
    src = str(root / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, str(root)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_repair",
         "--worker", str(devices), str(S), str(B)],
        env=env, cwd=root, capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"worker devices={devices} failed:\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def run(fast: bool = False) -> dict:
    S, B = (32, 4096) if fast else (64, 16384)
    counts = (1, 4) if fast else (1, 2, 4, 8)
    print("bench,devices,S,B,us_per_stripe,derived")
    rows = [_spawn(d, S, B) for d in counts]
    base = rows[0]["us_per_stripe"]
    for r in rows:
        r["speedup_vs_1dev"] = base / r["us_per_stripe"]
        csv(f"sharded,{r['devices']},S={r['S']},B={r['B']}",
            r["us_per_stripe"],
            f"span={r['span']} speedup={r['speedup_vs_1dev']:.2f}x")
    return {"geometry": GEOM, "scheme": SCHEME, "rows": rows}


if __name__ == "__main__":
    if len(sys.argv) >= 5 and sys.argv[1] == "--worker":
        devices, S, B = map(int, sys.argv[2:5])
        print(json.dumps(_worker(devices, S, B)))
    else:
        print(json.dumps(run(fast="--fast" in sys.argv), indent=1))
