"""Deliverable (g): roofline table from the dry-run artifacts.

Delegates to repro.launch.roofline (importable without the 512-device flag —
it only reads the cached dry-run JSONs)."""
from __future__ import annotations

from repro.launch.roofline import build_table, render

from ._util import csv


def run(fast: bool = False) -> dict:
    table = build_table()
    print(render(table))
    for row in table:
        if row.get("skip"):
            continue
        csv(f"roofline/{row['arch']}/{row['shape']}", 0.0,
            f"dom={row['dominant']} frac={row['mfu_like']:.3f}")
    return {"rows": table}
