"""Per-shard gather scaling x locality cost (the PR-4 tentpole numbers).

Times fleet repair through the placement-aware read stack while the stripe
axis is sharded over 1 / 2 / 4 / 8 forced host devices: each device shard's
slice of the batched ``(S, |reads|, B)`` input is prefetched by that
shard's own reader pool (its simulated host's disks) into its own buffer
and device_put directly onto its shard — the single-host gather stack is
gone. ``io_stall_scale`` makes the per-read link model wall-real, so the
measured gather span is the simulated I/O actually being paid.

Two sweeps:

* **devices** (at ``remote_read_multiplier=1.0``): per-stripe gather span
  must *scale down* with the device count — the gather leaving the
  single-host critical path. The headline ``gather_speedup_at_max_devices``
  is CI-gated (``benchmarks.check_regression``).
* **locality ratio** (at the max device count): sweeping the cross-shard
  read multiplier shows the locality cost model charging remote traffic —
  ``sim_seconds`` inflates with the multiplier while disk bytes and output
  stay identical.

Every worker also repairs a twin store through the unsharded synchronous
path and asserts every rebuilt block file is bit-identical — the sharded
gather is a pure data-movement refactor, GF(2^8) bytes never change.

Each device count runs in its own subprocess (jax locks the topology at
first init, like ``sharded_repair``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from ._util import csv

GEOM = (6, 2, 2)
SCHEME = "cp-azure"


def _worker(devices: int, stripes: int, block: int, stall: float,
            mult: float) -> dict:
    """Runs in a fresh process with ``devices`` forced host devices."""
    import tempfile

    import numpy as np

    import jax

    from repro.dist.sharding import with_rules
    from repro.ftx import (RepairOptions, StoreConfig, StripeStore,
                           repair_failed_nodes)

    assert len(jax.devices()) == devices
    k, r, p = GEOM
    cfg = StoreConfig(scheme=SCHEME, k=k, r=r, p=p, block_size=block,
                      batch_stripes=max(devices, 8),
                      pipeline_window=max(devices, 8), prefetch_threads=2,
                      io_stall_scale=stall, remote_read_multiplier=mult)

    def build(root):
        store = StripeStore(root, cfg)
        payload = np.random.default_rng(11).integers(
            0, 256, stripes * k * block, dtype=np.uint8)
        store.put("blob", payload.tobytes())
        store.seal()
        assert len(store.stripes) == stripes
        return store

    with tempfile.TemporaryDirectory() as tmp:
        sa = build(Path(tmp) / "a")
        sb = build(Path(tmp) / "b")
        node = sa.stripes[0].node_of_block[0]
        mesh = jax.make_mesh((devices, 1), ("data", "model"))
        with with_rules(mesh):
            rep = repair_failed_nodes(sa, [node], options=RepairOptions(pipeline=True))
        assert rep.devices == devices, (rep.devices, devices)
        base = repair_failed_nodes(sb, [node], options=RepairOptions(pipeline=False))
        for sid in sa.stripes:
            for b in range(sa.scheme.n):
                assert sa._block_path(sid, b).read_bytes() == \
                    sb._block_path(sid, b).read_bytes(), \
                    f"sharded gather not bit-identical at ({sid}, {b})"
        assert rep.blocks_read == base.blocks_read
        gbs = rep.gather_bytes_per_shard
        return {
            "devices": devices, "S": stripes, "B": block,
            "remote_multiplier": mult,
            "stripes_repaired": rep.stripes_repaired,
            "gather_seconds": rep.read_seconds,
            "gather_us_per_stripe": 1e6 * rep.read_seconds
            / max(1, rep.stripes_repaired),
            "wall_seconds": rep.wall_seconds,
            "sim_seconds": rep.sim_seconds,
            "local_reads": rep.local_reads,
            "remote_reads": rep.remote_reads,
            "local_fraction": rep.local_read_fraction,
            "shards": len(gbs),
            # 1.0 = every shard gathered the same byte count
            "shard_balance": (sum(gbs.values())
                              / (max(gbs.values()) * len(gbs))
                              if gbs else 1.0),
        }


def _spawn(devices: int, stripes: int, block: int, stall: float,
           mult: float) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = Path(__file__).resolve().parents[1]
    src = str(root / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, str(root)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_gather",
         "--worker", str(devices), str(stripes), str(block), str(stall),
         str(mult)],
        env=env, cwd=root, capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"worker devices={devices} failed:\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def run(fast: bool = False) -> dict:
    # S is a multiple of n * 8 so round-robin placement yields pattern
    # groups whose windows stay divisible across every device count.
    S, B, stall = (80, 4096, 0.05) if fast else (160, 16384, 0.1)
    counts = (1, 4, 8) if fast else (1, 2, 4, 8)
    mults = (2.0,) if fast else (2.0, 4.0)
    print("bench,devices,S,B,us_per_stripe,derived")
    rows = [_spawn(d, S, B, stall, 1.0) for d in counts]
    base = rows[0]["gather_us_per_stripe"]
    for r in rows:
        r["gather_speedup_vs_1dev"] = base / max(r["gather_us_per_stripe"],
                                                 1e-9)
        csv(f"gather,{r['devices']},S={r['S']},B={r['B']}",
            r["gather_us_per_stripe"],
            f"speedup={r['gather_speedup_vs_1dev']:.2f}x "
            f"local={r['local_fraction']:.2f} "
            f"balance={r['shard_balance']:.2f}")
    # Locality-ratio sweep at the widest mesh: the cost model must charge
    # cross-shard traffic (sim time inflates with the multiplier).
    loc_rows = [_spawn(counts[-1], S, B, stall, m) for m in mults]
    sim_base = rows[-1]["sim_seconds"]
    for r in loc_rows:
        r["sim_inflation"] = r["sim_seconds"] / max(sim_base, 1e-9)
        csv(f"locality,{r['devices']},mult={r['remote_multiplier']}",
            r["gather_us_per_stripe"],
            f"sim_inflation={r['sim_inflation']:.2f}x "
            f"remote={1 - r['local_fraction']:.2f}")
    speedup = rows[-1]["gather_speedup_vs_1dev"]
    print(f"gather speedup at {counts[-1]} devices: {speedup:.2f}x")
    return {"geometry": GEOM, "scheme": SCHEME, "rows": rows,
            "locality_rows": loc_rows,
            "max_devices": counts[-1],
            "gather_speedup_at_max_devices": speedup,
            "min_shard_balance": min(r["shard_balance"] for r in rows)}


if __name__ == "__main__":
    if len(sys.argv) >= 7 and sys.argv[1] == "--worker":
        devices, stripes, block = map(int, sys.argv[2:5])
        stall, mult = map(float, sys.argv[5:7])
        print(json.dumps(_worker(devices, stripes, block, stall, mult)))
    else:
        print(json.dumps(run(fast="--fast" in sys.argv), indent=1))
