"""Global repair orchestration on a replayed correlated-failure trace.

The PR-10 tentpole numbers, all driven by the committed trace fixture
(``tests/data/correlated_trace.json`` — same-timestamp node bursts plus a
whole-rack loss, replayed through ``repro.ftx.failures.replay_trace`` so
each correlated arrival repairs as one batch):

* **assignment** — the cross-window min-cost assignment
  (``schedule="global"``) vs the per-chunk greedy (``"locality"``) vs the
  contiguous stripe->shard order (``"none"``), on twin stores under a
  forced 8-device mesh. The metric is *counted* shard-local gather reads,
  and the in-bench assert pins the strict dominance chain
  ``global > greedy > contiguous`` on this trace; every rebuilt block is
  verified bit-identical across all three stores (assignment is a pure
  permutation).
* **destinations** — topology-aware rebuild destinations
  (``destinations="topology"``) vs write-back-in-place, with failed nodes
  *not* revived (the permanent-loss case destination selection exists
  for). In-place leaves every rebuilt block on a dead address (live
  fraction 0 for the first batch); topology relocates all of them onto UP
  nodes of least-loaded surviving domains (live fraction 1.0) while
  preserving the placement policy's invariants (asserted via
  ``placement_ok``).
* **rebalance** — after the full trace the store has lost six nodes and
  relocation has piled load onto the survivors; the fleet then *expands*
  by one rack (``StripeStore.expand``) and one ``repro.ftx.rebalance``
  pass migrates blocks through the windowed double-buffer loop. Metrics:
  planned == committed move count and the strict imbalance drop.

Every gated number is a deterministic count (seeded placement, fixed
trace), so the CI floors (``benchmarks.check_regression``:
``assignment_uplift_global_vs_greedy``, ``destination_live_fraction``,
``rebalance_moves``) hold machine-independently.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from ._util import csv

GEOM = (6, 2, 2)
SCHEME = "cp-azure"
NODES = 24
DOMAINS = 12              # 2-node racks: a rack loss stays inside the
#                           scheme's universal 2-erasure decodability
SPREAD_WIDTH = 2
BATCH = 8
REMOTE_MULT = 4.0
SEED = 7
DEVICES = 8
TRACE = Path(__file__).resolve().parents[1] / "tests" / "data" / \
    "correlated_trace.json"


def _worker(devices: int, stripes: int, block: int) -> dict:
    """Runs in a fresh process with ``devices`` forced host devices."""
    import tempfile

    import numpy as np

    import jax

    from repro.dist.placement import block_loads
    from repro.dist.sharding import with_rules
    from repro.dist.topology import Topology, placement_ok
    from repro.ftx import RepairOptions, StoreConfig, StripeStore, rebalance
    from repro.ftx.events import NodeFailEvent, RackFailEvent, load_trace
    from repro.ftx.failures import replay_trace

    assert len(jax.devices()) == devices
    k, r, p = GEOM
    topo = Topology(num_nodes=NODES, num_domains=DOMAINS,
                    spread_width=SPREAD_WIDTH, seed=SEED)
    cfg = StoreConfig(scheme=SCHEME, k=k, r=r, p=p, block_size=block,
                      batch_stripes=BATCH, pipeline_window=BATCH,
                      prefetch_threads=2, placement_policy="spread",
                      remote_read_multiplier=REMOTE_MULT)
    events = load_trace(TRACE)

    def build(root):
        store = StripeStore(root, cfg, num_nodes=NODES, topology=topo)
        payload = np.random.default_rng(11).integers(
            0, 256, stripes * k * block, dtype=np.uint8)
        store.put("blob", payload.tobytes())
        store.seal()
        assert len(store.stripes) == stripes
        return store

    mesh = jax.make_mesh((devices, 1), ("data", "model"))
    out: dict = {"devices": devices, "S": stripes, "B": block,
                 "nodes": NODES, "domains": DOMAINS,
                 "trace_events": len(events)}

    with tempfile.TemporaryDirectory() as tmp:
        # ---- assignment: global vs greedy vs contiguous, bit-identical
        stores, totals = {}, {}
        for sched in ("global", "locality", "none"):
            s = build(Path(tmp) / sched)
            with with_rules(mesh):
                res = replay_trace(s, events, options=RepairOptions(
                    schedule=sched, pipeline=True))
            stores[sched], totals[sched] = s, res["totals"]
        ref = stores["global"]
        for sid in ref.stripes:
            for b in range(ref.scheme.n):
                blob = ref._block_path(sid, b).read_bytes()
                for other in ("locality", "none"):
                    assert stores[other]._block_path(sid, b).read_bytes() \
                        == blob, f"not bit-identical at ({sid}, {b})"
        g, l, c = (totals[s]["scheduled_local"]
                   for s in ("global", "locality", "none"))
        assert g > l > c, f"dominance chain broken: {g} > {l} > {c}"
        assert totals["global"]["schedule_total"] \
            == totals["none"]["schedule_total"]
        out.update({
            "scheduled_local_global": g,
            "scheduled_local_greedy": l,
            "contiguous_local": c,
            "schedule_total": totals["global"]["schedule_total"],
            "assignment_uplift_global_vs_greedy": g / max(l, 1),
            "assignment_uplift_global_vs_contiguous": g / max(c, 1),
            "sim_seconds_global": totals["global"]["sim_seconds"],
            "sim_seconds_contiguous": totals["none"]["sim_seconds"],
        })

        # ---- destinations: topology vs write-back-in-place (first batch,
        # permanent loss — no revive), then the full trace under topology
        first_t = min(e.t for e in events)
        first = [e for e in events if e.t == first_t]
        live = {}
        for dest in ("topology", "in_place"):
            s = build(Path(tmp) / f"dest_{dest}")
            lost_nodes = set()
            for e in first:
                lost_nodes.update([e.node] if isinstance(e, NodeFailEvent)
                                  else topo.nodes_in(e.rack)
                                  if isinstance(e, RackFailEvent) else [])
            lost = sum(nodes.count(n) for st in s.stripes.values()
                       for nodes in [st.node_of_block] for n in lost_nodes)
            with with_rules(mesh):
                replay_trace(s, first, options=RepairOptions(
                    destinations=dest), revive=False)
            up = {n for n, state in s.nodes.items() if state.name == "UP"}
            total_blocks = sum(len(st.node_of_block)
                               for st in s.stripes.values())
            on_up = sum(1 for st in s.stripes.values()
                        for n in st.node_of_block if n in up)
            live[dest] = {"lost_blocks": lost,
                          "live_fraction": on_up / total_blocks}
        assert live["topology"]["live_fraction"] \
            > live["in_place"]["live_fraction"]

        # Full trace under topology destinations. On this fleet every
        # copyset is *saturated* (10 blocks fill five 2-node racks), so a
        # rack loss forces the width up — the hard invariants here are
        # liveness + distinctness + readable bytes; width *preservation*
        # under spare capacity is pinned by the property tests.
        sd = build(Path(tmp) / "dest_full")
        widths = {sid: len({topo.domain_of(n) for n in st.node_of_block})
                  for sid, st in sd.stripes.items()}
        with with_rules(mesh):
            full = replay_trace(sd, events, options=RepairOptions(
                destinations="topology"), revive=False)
        up = {n for n, state in sd.nodes.items() if state.name == "UP"}
        growth = 0
        for sid, st in sd.stripes.items():
            assert all(n in up for n in st.node_of_block), sid
            assert placement_ok("contiguous", topo, st.node_of_block), sid
            growth = max(growth, len({topo.domain_of(n)
                                      for n in st.node_of_block})
                         - widths[sid])
        blob = np.asarray(sd.get("blob"))
        assert blob.tobytes() == np.asarray(
            stores["global"].get("blob")).tobytes()
        out.update({
            "first_batch_lost_blocks": live["topology"]["lost_blocks"],
            "destination_live_fraction": live["topology"]["live_fraction"],
            "in_place_live_fraction": live["in_place"]["live_fraction"],
            "blocks_relocated": full["totals"]["blocks_relocated"],
            "max_width_growth": growth,
        })

        # ---- rebalance after expansion by one rack (2 nodes)
        topo2 = Topology(num_nodes=NODES + 2, num_domains=DOMAINS + 1,
                         spread_width=SPREAD_WIDTH, seed=SEED)
        assert all(topo.domain_of(i) == topo2.domain_of(i)
                   for i in range(NODES))
        sd.expand(topo2)
        rep = rebalance(sd)
        alive = [n for n, state in sd.nodes.items() if state.name == "UP"]
        loads = block_loads((s.node_of_block for s in sd.stripes.values()),
                            sd.num_nodes)
        assert rep.moved == rep.planned and rep.moved > 0
        assert rep.imbalance_after < rep.imbalance_before
        assert all(loads[n] == 0 or n in alive for n in loads)
        out.update({
            "rebalance_moves": rep.moved,
            "rebalance_windows": rep.windows,
            "rebalance_bytes": rep.bytes_moved,
            "imbalance_before": rep.imbalance_before,
            "imbalance_after": rep.imbalance_after,
        })
    return out


def _spawn(devices: int, stripes: int, block: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = Path(__file__).resolve().parents[1]
    src = str(root / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, str(root)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH")
                            else []))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.repair_orchestration",
         "--worker", str(devices), str(stripes), str(block)],
        env=env, cwd=root, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"worker devices={devices} failed:\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def run(fast: bool = False) -> dict:
    S, B = (160, 1024) if fast else (320, 2048)
    print("bench,leg,devices,metric,derived")
    r = _spawn(DEVICES, S, B)
    csv(f"orchestration,assignment,{DEVICES}dev",
        r["assignment_uplift_global_vs_greedy"],
        f"global={r['scheduled_local_global']} "
        f"greedy={r['scheduled_local_greedy']} "
        f"contig={r['contiguous_local']} of {r['schedule_total']}")
    csv(f"orchestration,destinations,{DEVICES}dev",
        r["destination_live_fraction"],
        f"in_place={r['in_place_live_fraction']:.3f} "
        f"relocated={r['blocks_relocated']}")
    csv(f"orchestration,rebalance,{DEVICES}dev", r["rebalance_moves"],
        f"imbalance {r['imbalance_before']} -> {r['imbalance_after']} "
        f"windows={r['rebalance_windows']}")
    print(f"global-vs-greedy local-read uplift: "
          f"{r['assignment_uplift_global_vs_greedy']:.3f}x; "
          f"destination live fraction {r['destination_live_fraction']:.3f} "
          f"vs in-place {r['in_place_live_fraction']:.3f}; "
          f"{r['rebalance_moves']} rebalance moves")
    return {"geometry": GEOM, "scheme": SCHEME, "trace": str(TRACE),
            "row": r,
            "assignment_uplift_global_vs_greedy":
                r["assignment_uplift_global_vs_greedy"],
            "assignment_uplift_global_vs_contiguous":
                r["assignment_uplift_global_vs_contiguous"],
            "destination_live_fraction": r["destination_live_fraction"],
            "blocks_relocated": r["blocks_relocated"],
            "rebalance_moves": r["rebalance_moves"]}


if __name__ == "__main__":
    if len(sys.argv) >= 5 and sys.argv[1] == "--worker":
        devices, stripes, block = map(int, sys.argv[2:5])
        print(json.dumps(_worker(devices, stripes, block)))
    else:
        print(json.dumps(run(fast="--fast" in sys.argv), indent=1))
