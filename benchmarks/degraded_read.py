"""Degraded-read serving under Zipfian multi-client load.

The PR-6 tentpole numbers: tail latency and decode-launch counts of the
degraded-read serving path (``StripeStore.read`` via
``repro.serve.blocks.BlockServer`` — coalescing + hot-block cache +
local-first planning) against two baselines on identically-built twin
stores replaying the *same* seeded Zipfian request stream:

* **naive** — the serving path with coalescing and the cache disabled:
  every degraded request plans, gathers and launches its own decode (what
  a store without the serving layer would do per read);
* **rs** — the full-stripe RS decode baseline: every degraded request
  decodes the data extent from k surviving blocks, locality-blind (the
  "XORing Elephants" degraded-read cost the paper's local groups avoid).

Every served byte is asserted bit-identical to the healthy (pre-failure)
read — correctness is part of the benchmark, not just the tests.

Two failure scenarios: a single failed node (every reconstruction is
local-group) and a cross-group double failure (a deterministic mix of
local, cascaded and global-fallback plans — the local fraction the CI gate
floors). ``io_stall_scale`` makes the simulated link model wall-real, so
the latency split (cache hit ≈ 0, local decode ≈ g reads, RS decode = k
reads) is measured, not modeled.

The gated metrics (``benchmarks.check_regression``) are **counts, not
timings** — the coalescing ratio (naive launches per serving launch) and
the local-decode fraction are exact functions of the seeded workload and
placement, so the floors hold machine-independently. The p99 comparison is
asserted in-benchmark (serve p99 must beat the RS baseline p99 on degraded
requests) but not floored in CI.
"""
from __future__ import annotations

import sys
import tempfile
from pathlib import Path

import numpy as np

from ._util import csv

GEOM = (6, 2, 2)
SCHEME = "cp-azure"
STALL = 0.05              # fraction of simulated link time actually slept
CLIENTS = 8
ALPHA = 1.2
SEED = 5
COALESCE_FLOOR = 4.0      # acceptance: >=4x fewer launches than naive


def _build(root, stripes: int, block: int, **over):
    from repro.ftx import StoreConfig, StripeStore

    k, r, p = GEOM
    cfg = StoreConfig(scheme=SCHEME, k=k, r=r, p=p, block_size=block,
                      pipeline_window=0, io_stall_scale=STALL, **over)
    store = StripeStore(root, cfg)
    payload = np.random.default_rng(11).integers(
        0, 256, stripes * k * block, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    assert len(store.stripes) == stripes
    return store


class _RSBaseline:
    """Full-stripe RS decode per degraded request, locality-blind.

    Duck-types the slice of the store API ``BlockServer`` drives
    (``read_range``): live blocks stream from disk exactly like the real
    path; lost blocks decode the whole data extent from a rank-k alive
    set — no request coalescing, no cache, k source reads per request.
    """

    def __init__(self, store):
        self.store = store
        self.decodes = 0

    def read_range(self, sid, block, lo=0, hi=None):
        from repro.core.repair import global_decode_set

        store = self.store
        hi = store.cfg.block_size if hi is None else hi
        down = store._down_blocks(sid)
        if block not in down:
            return store._read_block(sid, block, (lo, hi))
        alive = frozenset(range(store.scheme.n)) - down
        ids = global_decode_set(store.scheme, alive)
        plan = store.engine.planner.decode_plan(ids)
        stacked = np.stack(
            [store._read_block(sid, b) for b in plan.reads])[None]
        out = np.asarray(store.engine.execute(plan, stacked))
        self.decodes += 1
        return out[0, block, lo:hi].copy()


def _fail_nodes(store, pattern: str) -> list[int]:
    """Deterministic failed-node pick shared by all twin stores."""
    n0 = store.stripes[0].node_of_block[0]
    if pattern == "single":
        return [n0]
    # double: the node of a sibling data block — stride-7 arc placement
    # turns one node pair into per-stripe patterns mixing same-group
    # (global fallback) and cross-group (still local) failures.
    return [n0, store.stripes[0].node_of_block[1]]


def _degraded_pairs(store, requests):
    down_of = {sid: store._down_blocks(sid) for sid in store.stripes}
    return [i for i, (sid, b) in enumerate(requests) if b in down_of[sid]]


def _percentile_ms(samples, p):
    return float(np.percentile(np.asarray(samples), p)) * 1e3 \
        if len(samples) else 0.0


def _run_path(store_or_wrapper, requests, truth, label):
    """Replay the stream through the client pool; verify every byte."""
    from repro.serve.blocks import BlockServer

    server = BlockServer(store_or_wrapper, clients=CLIENTS)
    results = server.run(requests, timed=True)
    for (sid, b), (data, _) in zip(requests, results):
        assert data.tobytes() == truth[(sid, b)], \
            f"{label}: served bytes differ from healthy read at ({sid}, {b})"
    return [dt for _, dt in results]


def _scenario(stripes: int, block: int, requests_n: int,
              pattern: str) -> dict:
    from repro.ftx import read_report
    from repro.serve.blocks import BlockServer, zipf_requests

    with tempfile.TemporaryDirectory() as tmp:
        serve = _build(Path(tmp) / "serve", stripes, block)
        naive = _build(Path(tmp) / "naive", stripes, block,
                       read_cache_blocks=0, coalesce_reads=False)
        rs_store = _build(Path(tmp) / "rs", stripes, block)
        requests = zipf_requests(serve, requests_n, alpha=ALPHA, seed=SEED)
        # Healthy ground truth for every block the workload will touch.
        truth = {}
        for sid, b in set(requests):
            truth[(sid, b)] = serve._read_block(sid, b).tobytes()
        for store in (serve, naive, rs_store):
            for node in _fail_nodes(store, pattern):
                store.fail_node(node)
        degraded_idx = set(_degraded_pairs(serve, requests))
        assert degraded_idx, "workload never touches a lost block"
        warm = sorted({requests[i] for i in degraded_idx})

        # Per-path warmup: decode every lost block once on each path so the
        # measured p99s see warm jit caches, warm client pools and warm page
        # caches — not first-launch compile tails. Serving state (hot cache,
        # counters, latency window) resets to cold before measurement; only
        # the degraded-request *counts* must stay deterministic, and those
        # restart from zero.
        BlockServer(serve, clients=CLIENTS).run(warm)
        serve._hot_cache.clear()
        BlockServer(naive, clients=CLIENTS).run(warm)
        rs_warm = _RSBaseline(rs_store)
        BlockServer(rs_warm, clients=CLIENTS).run(warm)
        for store in (serve, naive, rs_store):
            store.telemetry.reset()
            store.read_latency.reset()

        lat_serve = _run_path(serve, requests, truth, "serve")
        lat_naive = _run_path(naive, requests, truth, "naive")
        rs = _RSBaseline(rs_store)
        lat_rs = _run_path(rs, requests, truth, "rs")

        rep = read_report(serve)
        rep_naive = read_report(naive)
        assert rep.degraded_reads == rep_naive.degraded_reads == \
            len(degraded_idx)
        assert rs.decodes == len(degraded_idx)

        def split(lat):
            deg = [lat[i] for i in degraded_idx]
            return {"p50_ms": _percentile_ms(lat, 50),
                    "p99_ms": _percentile_ms(lat, 99),
                    "p99_degraded_ms": _percentile_ms(deg, 99)}

        return {
            "pattern": pattern, "S": stripes, "B": block,
            "requests": requests_n, "clients": CLIENTS, "alpha": ALPHA,
            "degraded_requests": len(degraded_idx),
            "distinct_lost_blocks": len(warm),
            "launches_serve": rep.decode_launches,
            "launches_naive": rep_naive.decode_launches,
            "launches_rs": rs.decodes,
            "coalesced_reads": rep.coalesced_reads,
            "cache_hits": rep.cache_hits,
            "cache_hit_rate": rep.cache_hit_rate,
            "coalescing_ratio": rep_naive.decode_launches
            / max(1, rep.decode_launches),
            "local_decodes": rep.local_decodes,
            "global_decodes": rep.global_decodes,
            "local_decode_fraction": rep.local_decode_fraction,
            "blocks_read_serve": rep.blocks_read,
            "blocks_read_naive": rep_naive.blocks_read,
            "blocks_read_rs": rs_store.telemetry.blocks_read,
            "serve": split(lat_serve),
            "naive": split(lat_naive),
            "rs": split(lat_rs),
        }


def run(fast: bool = False) -> dict:
    S, B, R = (32, 1024, 3200) if fast else (64, 4096, 8000)
    print("bench,pattern,path,us_per_read,derived")
    rows = []
    for pattern in ("single", "double"):
        row = _scenario(S, B, R, pattern)
        rows.append(row)
        for path in ("serve", "naive", "rs"):
            csv(f"degraded_read,{pattern},{path}",
                1e3 * row[path]["p99_ms"],
                f"p99={row[path]['p99_ms']:.2f}ms "
                f"p99_deg={row[path]['p99_degraded_ms']:.2f}ms")
        print(f"{pattern}: {row['degraded_requests']} degraded reads over "
              f"{row['distinct_lost_blocks']} lost blocks -> "
              f"{row['launches_serve']} launches "
              f"(naive {row['launches_naive']}, "
              f"coalescing {row['coalescing_ratio']:.1f}x, "
              f"local fraction {row['local_decode_fraction']:.3f})")

    min_ratio = min(r["coalescing_ratio"] for r in rows)
    min_local = min(r["local_decode_fraction"] for r in rows)
    p99_uplift = min(r["rs"]["p99_degraded_ms"]
                     / max(r["serve"]["p99_degraded_ms"], 1e-9)
                     for r in rows)
    # Acceptance: coalescing collapses >=4x the naive launch count, and the
    # serving path's degraded p99 beats the full-stripe RS baseline.
    assert min_ratio >= COALESCE_FLOOR, \
        f"coalescing ratio {min_ratio:.2f} < {COALESCE_FLOOR}"
    for r in rows:
        assert r["serve"]["p99_degraded_ms"] < r["rs"]["p99_degraded_ms"], \
            (f"{r['pattern']}: serve p99 {r['serve']['p99_degraded_ms']:.2f}"
             f"ms not better than RS {r['rs']['p99_degraded_ms']:.2f}ms")
    print(f"coalescing >= {min_ratio:.1f}x, local fraction >= "
          f"{min_local:.3f}, degraded p99 {p99_uplift:.1f}x better than RS")
    return {"geometry": GEOM, "scheme": SCHEME, "rows": rows,
            "min_coalescing_ratio": min_ratio,
            "min_local_decode_fraction": min_local,
            "min_p99_uplift_vs_rs": p99_uplift}


if __name__ == "__main__":
    import json

    print(json.dumps(run(fast="--fast" in sys.argv), indent=1))
