"""Bit-plane batched decode: backend x S x B sweep (the PR-7 tentpole).

Routes the same batched repair/decode work through all four kernel backends
— ``ref`` (fused jnp table path), ``gf`` (bit-serial byte kernel), ``crs``
(select-and-XOR on packed bit-planes), ``mxu`` (mod-2 systolic matmul) —
asserting bit-identity against ``ref`` on every combination, and reports:

* measured per-stripe wall time per backend (interpret-mode CPU numbers;
  informational — the backends' relative wall order flips on real TPUs,
  which is the point of the roofline below);
* an interpret-mode roofline model per backend: bytes moved and XOR/MAC
  counts per output byte, derived from the *actual* compiled plan shapes
  and the actual bit-matrix density — fully deterministic, so the
  regression gate floors model ratios and cache counts, never wall times;
* bit-matrix expansion amortization: the whole sweep reuses each pattern's
  cached ``CompiledPlan.bit_coeffs()`` expansion, so expansions == distinct
  plans and launches/expansion >> 1.

Roofline model (per stripe, plan ``coeffs (m, t)``, block bytes B,
bit-matrix density d — measured, ~0.5 for random GF coefficients):

  ref   moves t*B in + m*B out + m*t*B gathered table bytes; every product
        is a random-access gather, which vectorizes poorly — modelled at
        ``GATHER_COST`` vector-op equivalents each — plus (t-1)*m*B XORs.
  gf    bit-serial shift-and-XOR: 8 rounds x 3 vector ops over the (m,t,B)
        product lattice = 24*m*t*B vector byte-ops, no table traffic.
  crs   XOR-only: d*(8m)*(8t)*(B/8) = 8*d*m*t*B byte-XORs (~4*m*t*B at
        d=0.5) + 2*t*B packetize traffic. This 24-vs-4 ops ratio is why
        crs beats gf wherever XOR throughput is the limit (DESIGN.md §11).
  mxu   (8m)*(8t)*8B bf16 MACs = 512*m*t*B — 128x more arithmetic than
        crs, but issued on the systolic array at matmul rate, modelled at
        ``MXU_RATIO`` MACs per VPU-op slot; wins once m*t is large enough
        to fill the array.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import BatchedCodecEngine
from repro.core.planner import bitmatrix_expansions
from repro.core.schemes import make_scheme
from repro.kernels.ops import BACKENDS, effective_backend

from ._util import csv, timed

GEOM = (24, 2, 2)           # the paper's P5
SCHEME = "cp-azure"
# Vector-op equivalents charged per table gather (ref path): a gather
# issues element-at-a-time where an XOR covers a full 8-wide int32 lane.
GATHER_COST = 8.0
# MACs the systolic array retires per VPU vector-op slot (128x128 array
# vs 8x128 vector unit).
MXU_RATIO = 256.0


def _roofline(m: int, t: int, B: int, density: float) -> dict:
    """Per-backend bytes-moved / op-count model for one stripe (see module
    docstring). All inputs are deterministic plan properties."""
    base_io = t * B + m * B                      # read stack + written out
    ops = {
        "ref": GATHER_COST * m * t * B + (t - 1) * m * B,
        "gf": 24.0 * m * t * B,
        "crs": 8.0 * density * m * t * B,
        "mxu": 512.0 * m * t * B / MXU_RATIO,
    }
    bytes_moved = {
        "ref": base_io + m * t * B,              # gathered table bytes
        "gf": float(base_io),
        "crs": base_io + 2.0 * t * B,            # packetize round-trip
        "mxu": base_io + 2.0 * t * B,
    }
    out = m * B
    return {b: {"bytes_moved": bytes_moved[b], "ops": ops[b],
                "ops_per_output_byte": ops[b] / out}
            for b in BACKENDS}


def _bench_combo(engines: dict, S: int, B: int, rng) -> dict:
    """One (S, B) cell: repair the cascading two-block pattern through every
    backend, assert bit-identity against ref, time each."""
    k, r, p = GEOM
    scheme = engines["ref"].scheme
    data = rng.integers(0, 256, (S, k, B), dtype=np.uint8)
    stripes = np.asarray(engines["ref"].encode(data))
    pattern = frozenset({0, k})                  # data block + local parity
    avail = {i: stripes[:, i, :] for i in range(scheme.n)
             if i not in pattern}

    want = None
    row = {"S": S, "B": B}
    # ref runs first so every other backend is asserted against the oracle
    for backend in ("ref",) + tuple(b for b in BACKENDS if b != "ref"):
        eng = engines[backend]

        def decode():
            out, _ = eng.repair_multi(pattern, avail)
            return {b: np.asarray(v) for b, v in out.items()}

        got, us = timed(decode)
        assert eng.effective_backend == effective_backend(backend)
        if want is None:
            want = got
        else:
            for b in sorted(pattern):
                assert (got[b] == want[b]).all(), \
                    f"{backend} decode differs from ref at block {b}"
        row[f"{backend}_us_per_stripe"] = us / S
        csv(f"decode,{backend},S={S},B={B}", us / S,
            f"effective={eng.effective_backend}")
    row["crs_vs_ref_measured"] = (row["ref_us_per_stripe"]
                                  / row["crs_us_per_stripe"])
    return row


def run(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    k, r, p = GEOM
    scheme = make_scheme(SCHEME, k, r, p)
    engines = {b: BatchedCodecEngine(scheme, backend=b) for b in BACKENDS}
    sweep_s = (8,) if fast else (8, 32)
    sweep_b = (4096,) if fast else (4096, 16384)

    exp_before = bitmatrix_expansions()
    print("bench,backend,S,B,us_per_stripe,derived")
    rows = [_bench_combo(engines, S, B, rng)
            for S in sweep_s for B in sweep_b]
    expansions = bitmatrix_expansions() - exp_before

    # Every (S, B) cell launches the bit backends repeatedly (timed()
    # warmup + repeats), yet each engine expands its one cascade plan
    # exactly once for the whole sweep: amortization = launches/expansion.
    cells = len(rows)
    launches_per_bit_backend = cells * 4         # 1 warmup + 3 repeats
    bit_launches = 2 * launches_per_bit_backend  # crs + mxu engines
    assert expansions == 2, \
        f"expected one expansion per bit-backend plan, got {expansions}"
    amortization = bit_launches / expansions

    # Deterministic roofline at the sweep's plan: the crs engine's actual
    # compiled cascade plan supplies (m, t) and the real bit density.
    plan = engines["crs"].planner.multi_plan(frozenset({0, k}))
    density = float(plan.bit_coeffs().mean())
    m, t = plan.coeffs.shape
    B_model = sweep_b[0]
    model = _roofline(m, t, B_model, density)
    crs_vs_ref_model = model["ref"]["ops"] / model["crs"]["ops"]
    crs_vs_gf_model = model["gf"]["ops"] / model["crs"]["ops"]
    for b in BACKENDS:
        print(f"roofline[{b}]: bytes={model[b]['bytes_moved']:.0f} "
              f"ops/out-byte={model[b]['ops_per_output_byte']:.2f}")
    print(f"bit-matrix density: {density:.3f}")
    print(f"crs-vs-ref model speedup (interpret path): "
          f"{crs_vs_ref_model:.2f}x; crs-vs-gf: {crs_vs_gf_model:.2f}x")
    print(f"expansion amortization: {bit_launches} bit launches / "
          f"{expansions} expansions = {amortization:.0f}x")

    return {
        "geometry": GEOM, "scheme": SCHEME, "rows": rows,
        "bit_density": density,
        "roofline": model,
        "roofline_block_bytes": B_model,
        "crs_vs_ref_model_speedup": crs_vs_ref_model,
        "crs_vs_gf_model_speedup": crs_vs_gf_model,
        "bit_launches": bit_launches,
        "bit_expansions": expansions,
        "expansion_amortization": amortization,
        "expansions_per_plan": expansions / 2,
    }
