"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--fast] [--only SECTION] [--list]``

Sections (paper analogue in brackets):
  repair_costs      ADRC / ARC1 / ARC2, P1-P8 x 6 schemes   [Tables I, III]
  local_portion     (effective) local-repair portions       [Tables IV, V]
  mttdl             Markov MTTDL, paper + strict models     [Table VI]
  repair_time       simulated cluster single/two-node repair [Figs 6, 9]
  blocksize_sweep   repair time/throughput vs block size    [Figs 7, 8]
  filelevel         file-level degraded-read optimization   [Fig 10]
  batched_repair    batched vs per-stripe repair throughput [PR-1 tentpole]
  sharded_repair    repair throughput vs device count        [PR-2 tentpole]
  pipelined_repair  async pipeline vs sync repair overlap    [PR-3 tentpole]
  sharded_gather    per-shard gather scaling x locality cost [PR-4 tentpole]
  stripe_schedule   locality-aware stripe scheduling uplift  [PR-5 tentpole]
  degraded_read     coalesced degraded serving vs RS decode  [PR-6 tentpole]
  batched_decode    bit-plane batched decode, backend sweep  [PR-7 tentpole]
  reliability_sim   event-driven fleet reliability simulator [PR-8 tentpole]
  repair_orchestration  trace-replayed global assignment +
                    destinations + rebalance                 [PR-10 tentpole]
  kernels           encode kernels vs jnp reference          [§V substrate]
  ckpt_stripes      EC-checkpoint encode/repair per arch    [framework]
  roofline          dry-run roofline table                   [deliverable g]

Each section prints ``name,us_per_call,derived`` CSV rows and writes JSON to
benchmarks/results/.

``--list`` prints the registered section names (one per line) and exits 0 —
the discovery counterpart of the strict ``--only`` validation. ``--only``
accepts a comma-separated list; an unknown name exits 2 (so a typo'd CI
step cannot silently run nothing), and any failed section makes the whole
run exit 1 (the regression gate depends on that).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"

SECTIONS = ("repair_costs", "local_portion", "mttdl", "repair_time",
            "blocksize_sweep", "filelevel", "batched_repair",
            "sharded_repair", "pipelined_repair", "sharded_gather",
            "stripe_schedule", "degraded_read", "batched_decode",
            "reliability_sim", "repair_orchestration", "kernels",
            "ckpt_stripes", "roofline")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="SECTION[,SECTION...]",
                    help=f"run only these sections; one of: {', '.join(SECTIONS)}")
    ap.add_argument("--fast", action="store_true",
                    help="narrow parameter subsets (CI mode)")
    ap.add_argument("--list", action="store_true",
                    help="print registered section names and exit")
    args = ap.parse_args(argv)
    if args.list:
        for name in SECTIONS:
            print(name)
        return 0
    RESULTS.mkdir(parents=True, exist_ok=True)
    if args.only:
        todo = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in todo if s not in SECTIONS]
        if unknown:
            ap.error(f"unknown benchmark section(s): {', '.join(unknown)} "
                     f"(choose from {', '.join(SECTIONS)})")  # exits 2
    else:
        todo = list(SECTIONS)
    failures = []
    for name in todo:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"\n===== {name} =====", flush=True)
        try:
            out = mod.run(fast=args.fast)
            (RESULTS / f"{name}.json").write_text(json.dumps(out, indent=1))
        except Exception as e:  # keep the suite going; report at the end
            import traceback

            failures.append(name)
            print(f"SECTION FAILED: {name}: {e}")
            traceback.print_exc()
    print(f"\nsections failed: {failures or 'none'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
