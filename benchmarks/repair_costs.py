"""Tables I + III: ADRC / ARC1 / ARC2 for P1-P8 x 6 schemes, with paper
reference values and per-cell deltas."""
from __future__ import annotations

import time

from repro.core import metrics as M
from repro.core.schemes import PAPER_PARAMS, make_scheme

from ._util import PAPER, SCHEME_ORDER, csv


def run(fast: bool = False) -> dict:
    labels = list(PAPER_PARAMS)
    if fast:
        labels = ["P1", "P4", "P5"]
    out = {}
    for metric, fn in (("ADRC", M.adrc), ("ARC1", M.arc1), ("ARC2", M.arc2)):
        print(f"-- {metric} --")
        for name in SCHEME_ORDER:
            row = {}
            for li, lbl in enumerate(labels):
                k, r, p = PAPER_PARAMS[lbl]
                s = make_scheme(name, k, r, p)
                t0 = time.perf_counter()
                v = fn(s)
                us = (time.perf_counter() - t0) * 1e6
                ref = PAPER[metric][name][list(PAPER_PARAMS).index(lbl)]
                row[lbl] = {"ours": round(v, 3), "paper": ref,
                            "delta": round(v - ref, 3)}
                csv(f"{metric}/{name}/{lbl}", us,
                    f"ours={v:.2f} paper={ref} delta={v - ref:+.2f}")
            out[f"{metric}/{name}"] = row
    return out
