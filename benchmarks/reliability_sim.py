"""Event-driven fleet reliability simulation (PR-8 tentpole).

Four legs, all seeded and deterministic:

* ``schemes``: Table-VI-style comparison with the *real* repair planner in
  the loop (``cost_model="planner"``) — CP-Azure vs Azure-LRC and
  CP-Uniform vs uniform LRC at matched overhead, failure rates accelerated
  so every scheme observes hundreds of losses. The gate is the paper's
  *ordering* (cascaded parities repair faster, so they survive longer),
  counted as ``ordering_ok`` — never a wall time.
* ``closed_form``: the simulator cross-validated against
  ``core/reliability.py``'s Markov chain on a calibrated single-failure-
  mode config (azure(4,2,1): every pattern up to p+r is decodable, so the
  chain is exact and paper == strict). Gate: min(sim, chain)/max(sim,
  chain) agreement ratio.
* ``rebuild_window``: the serving-side row — degraded-read latency and
  local-decode fraction *during* a rebuild window vs steady state, on a
  real store through the coalescing serving path (p99 reported, the
  deterministic local-decode fraction gated).
* ``calibration``: measured repair-pipeline throughput
  (``repro.sim.calibrate``) fed back into the failure model — the
  simulated MTTDL responds to the pipeline's *effective* bandwidth.
"""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core.reliability import (HOURS_PER_YEAR, ReliabilityParams,
                                    stripe_mttdl_years)
from repro.core.schemes import make_scheme
from repro.ftx import (RepairOptions, ServeOptions, StoreConfig, StripeStore,
                       read_report)
from repro.sim import (SimParams, calibrated, measure_repair_bandwidth,
                       simulate)

from ._util import csv

# Accelerated failure environment: mean disk life ~175 h, repair channel
# slow enough that the vulnerability window (where CP's cheaper plans help)
# dominates. Deterministic per seed; losses number in the hundreds.
_REL = ReliabilityParams(node_mttf_years=0.02, bandwidth_gbps=0.002,
                         detect_hours_single=2.0, detect_hours_multi=10.0)

_PAIRS = (("azure", "cp-azure"), ("uniform", "cp-uniform"))


def _sim(scheme_name: str, k: int, r: int, p: int, *, trials: int,
         horizon: float, seed: int, model: str = "paper",
         cost_model: str = "planner",
         rel: ReliabilityParams = _REL):
    sch = make_scheme(scheme_name, k, r, p)
    params = SimParams(disk_mttf_hours=rel.node_mttf_years * HOURS_PER_YEAR,
                       weibull_shape=1.0, model=model, cost_model=cost_model,
                       reliability=rel)
    return simulate(sch, params, trials=trials, horizon_hours=horizon,
                    seed=seed)


def _scheme_rows(fast: bool) -> dict:
    trials = 250 if fast else 900
    horizon = 4000.0 if fast else 8000.0
    rows = {}
    for name in ("azure", "cp-azure", "uniform", "cp-uniform"):
        t0 = time.perf_counter()
        res = _sim(name, 6, 2, 2, trials=trials, horizon=horizon, seed=17)
        us = (time.perf_counter() - t0) * 1e6
        rows[name] = {
            "mttdl_years": res.mttdl_years, "losses": res.losses,
            "events": res.events, "epochs": res.epochs,
            "event_parallelism": res.event_parallelism,
            "rejected": res.rejected,
            "events_per_sec": res.events / max(res.wall_seconds, 1e-9),
        }
        csv(f"reliability_sim/{name}", us,
            f"mttdl={res.mttdl_years:.3f}y losses={res.losses} "
            f"par={res.event_parallelism:.0f} "
            f"ev/s={rows[name]['events_per_sec']:.0f}")
    ordering_ok = sum(rows[cp]["mttdl_years"] >= rows[base]["mttdl_years"]
                      for base, cp in _PAIRS)
    for base, cp in _PAIRS:
        csv(f"reliability_sim/order/{cp}_vs_{base}", 0.0,
            f"ratio={rows[cp]['mttdl_years'] / rows[base]['mttdl_years']:.2f}")
    return {"rows": rows, "ordering_ok": int(ordering_ok),
            "trials": trials, "horizon_hours": horizon}


def _closed_form(fast: bool) -> dict:
    trials = 400 if fast else 1500
    sch = make_scheme("azure", 4, 2, 1)
    chain = stripe_mttdl_years(sch, _REL, model="paper")
    res = _sim("azure", 4, 2, 1, trials=trials, horizon=8000.0, seed=11,
               cost_model="average")
    ratio = res.mttdl_years / chain
    agreement = min(ratio, 1.0 / ratio) if np.isfinite(ratio) else 0.0
    csv("reliability_sim/closed_form", 0.0,
        f"sim={res.mttdl_years:.4f}y chain={chain:.4f}y ratio={ratio:.3f}")
    return {"sim_years": res.mttdl_years, "chain_years": chain,
            "ratio": ratio, "agreement": agreement, "losses": res.losses}


def _rebuild_window(fast: bool, root: Path) -> dict:
    stripes, block = (2, 1024) if fast else (4, 1024)
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=block,
                      coalesce_reads=True, io_stall_scale=0.05)
    store = StripeStore(root / "serve", cfg)
    payload = np.random.default_rng(3).integers(
        0, 256, stripes * cfg.k * block, dtype=np.uint8)
    store.put("blob", payload.tobytes())
    store.seal()
    rng = np.random.default_rng(9)
    requests = [(int(rng.integers(stripes)), int(rng.integers(cfg.k)))
                for _ in range(60 if fast else 180)]

    def drive():
        for sid, b in requests:
            store.read(sid, b, options=ServeOptions())

    drive()                                   # steady state
    steady = read_report(store, reset=True)
    victim = store.stripes[0].node_of_block[0]
    store.fail_node(victim)
    drive()                                   # inside the rebuild window
    window = read_report(store, reset=True)
    tele = store.repair_all(options=RepairOptions())
    store.revive_node(victim)
    drive()                                   # after rebuild completes
    after = read_report(store, reset=True)
    out = {
        "steady_p99_ms": steady.p99_ms, "window_p99_ms": window.p99_ms,
        "after_p99_ms": after.p99_ms,
        "window_degraded_reads": window.degraded_reads,
        "window_local_decode_fraction": window.local_decode_fraction,
        "window_coalescing_ratio": window.coalescing_ratio,
        "repair_blocks_read": tele["blocks_read"],
    }
    csv("reliability_sim/rebuild_window", 0.0,
        f"p99 steady={steady.p99_ms:.3f}ms window={window.p99_ms:.3f}ms "
        f"local={window.local_decode_fraction:.2f} "
        f"coalesce={window.coalescing_ratio:.2f}")
    return out


def _calibration(fast: bool, root: Path) -> dict:
    cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=2048)
    tele = measure_repair_bandwidth(root, cfg, objects=3 if fast else 5)
    rel = calibrated(_REL, min(tele["gbps"], 1.0))  # cap: accelerated env
    res = _sim("cp-azure", 6, 2, 2, trials=150 if fast else 400,
               horizon=4000.0, seed=23, rel=rel)
    csv("reliability_sim/calibrated", 0.0,
        f"measured={tele['gbps']:.4f}Gbps mttdl={res.mttdl_years:.3f}y")
    return {"measured_gbps": tele["gbps"],
            "repair_bytes_read": tele["bytes_read"],
            "mttdl_years_at_measured_bw": res.mttdl_years}


def run(fast: bool = False) -> dict:
    import tempfile

    out = {}
    out["schemes"] = _scheme_rows(fast)
    out["closed_form"] = _closed_form(fast)
    with tempfile.TemporaryDirectory() as td:
        out["rebuild_window"] = _rebuild_window(fast, Path(td))
        out["calibration"] = _calibration(fast, Path(td))
    return out
