"""Experiment 4 (Fig 10): file-level repair optimization — degraded reads
fetch only the byte ranges a file needs vs whole blocks. Files are sampled
from a heavy-tailed size distribution (the FB-2010 trace regime: many small
files, few large)."""
from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.ftx.stripestore import StoreConfig, StripeStore

from ._util import csv


def run(fast: bool = False) -> dict:
    nfiles = 30 if fast else 100
    rng = np.random.default_rng(3)
    # log-uniform sizes 5 KB .. 4 MB (paper: 5 KB .. 30 MB)
    sizes = np.exp(rng.uniform(np.log(5e3), np.log(4e6), nfiles)).astype(int)
    tmp = tempfile.mkdtemp(prefix="bench_fl_")
    out = {}
    try:
        cfg = StoreConfig(scheme="azure", k=6, r=2, p=2, block_size=1 << 20)
        store = StripeStore(tmp, cfg)
        for i, sz in enumerate(sizes):
            store.put(f"f{i}", rng.integers(0, 256, sz, dtype=np.uint8)
                      .tobytes())
        store.seal()
        store.save_manifest()
        node = store.stripes[0].node_of_block[0]
        store.fail_node(node)

        def degraded_bytes(file_level: bool):
            total = 0
            for i, sz in enumerate(sizes):
                store.telemetry.reset()
                if file_level:
                    store.get(f"f{i}")
                else:
                    # block-level baseline: read whole blocks of the plan
                    meta = store.objects[f"f{i}"]
                    down = store._down_blocks(meta.sid)
                    span = range(meta.block, min(
                        meta.block + 1 + (meta.offset + meta.size - 1)
                        // cfg.block_size, store.cfg.k))
                    for b in span:
                        if b in down:
                            from repro.core.repair import single_repair_plan

                            plan = single_repair_plan(store.scheme, b)
                            for src in plan.reads:
                                store._read_block(meta.sid, src)
                        else:
                            store._read_block(meta.sid, b)
                total += store.telemetry.bytes_read
            return total

        b_file = degraded_bytes(True)
        b_block = degraded_bytes(False)
        saving = 1.0 - b_file / max(b_block, 1)
        out["bytes_file_level"] = int(b_file)
        out["bytes_block_level"] = int(b_block)
        out["read_saving"] = round(saving, 4)
        csv("filelevel/degraded_read", 0.0,
            f"file={b_file / 1e6:.1f}MB block={b_block / 1e6:.1f}MB "
            f"saving={saving:.1%}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return out
