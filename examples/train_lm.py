"""End-to-end driver: train a reduced qwen2.5 for a few hundred steps with
CP-LRC erasure-coded checkpoints and a mid-run host failure + restore.

PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys

sys.argv = [sys.argv[0], "--arch", "qwen2.5-3b", "--steps",
            sys.argv[sys.argv.index("--steps") + 1]
            if "--steps" in sys.argv else "120",
            "--batch", "8", "--seq", "128", "--ckpt-every", "40",
            "--kill-host", "2", "--lr", "3e-3"]

from repro.launch.train import main

if __name__ == "__main__":
    main()
