"""Cluster repair demo (mini Experiment 1/3): fail nodes against a live
store, watch CP-LRC repair bandwidth vs Azure LRC.

PYTHONPATH=src python examples/repair_demo.py
"""
import shutil
import tempfile

import numpy as np

from repro.ftx.stripestore import StoreConfig, StripeStore

rng = np.random.default_rng(0)
for scheme in ("azure", "cp-azure", "cp-uniform"):
    tmp = tempfile.mkdtemp()
    cfg = StoreConfig(scheme=scheme, k=12, r=2, p=2, block_size=64 * 1024)
    store = StripeStore(tmp, cfg)
    for i in range(12):
        store.put(f"o{i}", rng.integers(0, 256, cfg.block_size - 64,
                                        dtype=np.uint8).tobytes())
    store.seal()
    results = {}
    # single failures: a data node, a local-parity node, the G_r node
    for label, block in (("data", 0), ("local-parity", store.scheme.k),
                         ("G_r", store.scheme.n - 1)):
        node = store.stripes[0].node_of_block[block]
        store.fail_node(node)
        tele = store.repair_all()
        store.revive_node(node)
        results[label] = tele["blocks_read"]
    # the cascading two-failure case: D1 + L1
    st = store.stripes[0]
    for b in (0, store.scheme.k):
        store.fail_node(st.node_of_block[b])
    tele = store.repair_all()
    for b in (0, store.scheme.k):
        store.revive_node(st.node_of_block[b])
    results["D1+L1"] = tele["blocks_read"]
    print(f"{scheme:11s} blocks read -> {results}")
    shutil.rmtree(tmp, ignore_errors=True)
