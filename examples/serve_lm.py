"""Batched serving demo: continuous batching over a shared KV cache.

PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_model
from repro.serve.engine import ServeEngine

api = get_model("qwen2.5-3b", smoke=True)
engine = ServeEngine(api, max_batch=4, max_len=128)
engine.load(api.init_params(jax.random.key(0)))

rng = np.random.default_rng(0)
reqs = [engine.submit(rng.integers(0, 500, int(rng.integers(4, 24))),
                      max_new=8) for _ in range(10)]
t0 = time.time()
steps = 0
while any(not r.done for r in reqs):
    live = engine.step()
    steps += 1
dt = time.time() - t0
toks = sum(len(r.out_tokens) for r in reqs)
print(f"{len(reqs)} requests, {toks} tokens in {steps} engine steps "
      f"({dt:.1f}s, {toks / dt:.1f} tok/s on CPU smoke config)")
for r in reqs[:3]:
    print(f"  req{r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
