"""Placement-policy demo: how block placement + stripe scheduling change
repair locality (DESIGN.md §9).

Builds one store per block-placement policy (repro.dist.topology) on an
80-node / 8-domain fleet, fails a node, and repairs twice on an 8-device
mesh: once with the locality-aware stripe scheduler and once with the
contiguous stripe->device-shard assignment. The table shows the realized
shard-local read fraction per (policy, schedule) — identical rebuilt
bytes, very different traffic:

* contiguous arcs: every stripe of a pattern group lives on the same
  nodes — nothing to schedule, uplift exactly 1x;
* round_robin: blocks disperse over all domains — locality capped at 1/D
  for any assignment;
* spread (copyset-style): each stripe's blocks concentrate in ~2 domains —
  the scheduler routes each stripe to a domain that owns its blocks.

PYTHONPATH=src python examples/placement_demo.py
"""
import os

# Force an 8-virtual-device CPU topology before jax initializes.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import shutil                                              # noqa: E402
import tempfile                                            # noqa: E402

import numpy as np                                         # noqa: E402

import jax                                                 # noqa: E402

from repro.dist.sharding import with_rules                 # noqa: E402
from repro.dist.topology import POLICIES, Topology         # noqa: E402
from repro.ftx import (StoreConfig, StripeStore,           # noqa: E402
                       repair_failed_nodes)

S, B, NODES, DOMAINS = 640, 1024, 80, 8
topo = Topology(num_nodes=NODES, num_domains=DOMAINS, spread_width=2, seed=7)
mesh = jax.make_mesh((8, 1), ("data", "model"))
payload = np.random.default_rng(0).integers(0, 256, S * 6 * B,
                                            dtype=np.uint8).tobytes()

print(f"{NODES} nodes / {DOMAINS} domains, {S} stripes, 8-device mesh")
print(f"{'policy':12s} {'scheduled':>10s} {'contiguous':>11s} {'uplift':>7s}")
for policy in POLICIES:
    fracs = {}
    for schedule in ("locality", "none"):
        tmp = tempfile.mkdtemp()
        cfg = StoreConfig(scheme="cp-azure", k=6, r=2, p=2, block_size=B,
                          batch_stripes=8, pipeline_window=8,
                          placement_policy=policy, stripe_schedule=schedule)
        store = StripeStore(tmp, cfg, num_nodes=NODES, topology=topo)
        store.put("blob", payload)
        store.seal()
        node = store.stripes[0].node_of_block[0]
        with with_rules(mesh):
            report = repair_failed_nodes(store, [node])
        fracs[schedule] = report.local_read_fraction
        shutil.rmtree(tmp, ignore_errors=True)
    uplift = fracs["locality"] / max(fracs["none"], 1e-9)
    print(f"{policy:12s} {fracs['locality']:10.3f} {fracs['none']:11.3f} "
          f"{uplift:6.2f}x")
