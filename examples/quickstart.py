"""Quickstart: build CP-LRCs, inspect repair plans, run a real repair.

PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import make_scheme, metrics
from repro.core.codec import StripeCodec
from repro.core.repair import multi_repair_plan, single_repair_plan

print("== CP-Azure (24,2,2) vs Azure LRC (24,2,2) ==")
cp = make_scheme("cp-azure", 24, 2, 2)
az = make_scheme("azure", 24, 2, 2)

gr = cp.n - 1  # the last global parity, G_r
for name, s in (("azure", az), ("cp-azure", cp)):
    plan = single_repair_plan(s, gr)
    print(f"{name:9s} repair G_r: read {plan.cost} blocks via {plan.method}")

d1, l1 = 0, cp.k
plan = multi_repair_plan(cp, [d1, l1])
print(f"cp-azure  repair D1+L1: {plan.cost} blocks, all_local={plan.all_local}"
      f" (paper: 13 vs 24 for Azure)")

print("\n== metrics (paper Table III, P5 column) ==")
for name, s in (("azure", az), ("cp-azure", cp)):
    print(f"{name:9s} ADRC={metrics.adrc(s):6.2f} ARC1={metrics.arc1(s):6.2f}")

print("\n== bytes-level repair through the JAX/Pallas codec ==")
codec = StripeCodec(make_scheme("cp-azure", 6, 2, 2))
rng = np.random.default_rng(0)
data = rng.integers(0, 256, (6, 1024), dtype=np.uint8)
stripe = np.asarray(codec.encode(data))
lost = {0, 7}  # D1 and L2
avail = {i: stripe[i] for i in range(codec.scheme.n) if i not in lost}
rebuilt, plan = codec.repair_multi(lost, avail)
ok = all((np.asarray(rebuilt[b]) == stripe[b]).all() for b in lost)
print(f"lost D1+L2 -> read {plan.cost} blocks, bit-exact={ok}")
